"""gemma3-1b — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt] 26L d_model=1152 4H (kv=1) d_ff=6912 vocab=262144.
Pattern (5 local + 1 global) repeating; local window 512.
"""

import dataclasses

from repro.config import (FAMILY_DENSE, ModelConfig, ProbeConfig,
                          pattern_local_global)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family=FAMILY_DENSE,
    source="[hf:google/gemma-3-1b-pt]",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    layer_kinds=pattern_local_global(26, local=5, glob=1),
    sliding_window=512,
    rope_theta=1_000_000.0,
    embed_scale=True,
    probe=ProbeConfig(tap_layer=9),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="gemma3-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    layer_kinds=pattern_local_global(2, local=1, glob=1),
    sliding_window=16,
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
