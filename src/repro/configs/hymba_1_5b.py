"""hymba-1.5b — hybrid-head model: parallel attention + Mamba heads per layer.

[arXiv:2411.13676] 32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001
ssm_state=16.  Attention heads use a sliding window (Hymba uses SWA for all
but 3 layers; we model the SWA regime, which is what makes it long-context).
"""

import dataclasses

from repro.config import FAMILY_HYBRID, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=FAMILY_HYBRID,
    source="[arXiv:2411.13676]",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    sliding_window=1024,        # hymba SWA window
    probe=ProbeConfig(tap_layer=11),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="hymba-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    ssm_state=8,
    ssm_head_dim=32,
    sliding_window=16,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
