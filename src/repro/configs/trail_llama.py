"""trail-llama — the paper's serving model at reproducible scale.

The paper serves Llama3-8B-instruct (32L, d_model=4096). Offline and on CPU
we train/serve a ~100M Llama-style decoder with the same probe design
(tap at the 11/32 fractional depth -> layer 4 of 12).
"""

import dataclasses

from repro.config import FAMILY_DENSE, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="trail-llama",
    family=FAMILY_DENSE,
    source="[arXiv:2404 TRAIL eval model, reduced]",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    probe=ProbeConfig(tap_layer=4, hidden=512, num_bins=10, max_len=512),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="trail-llama-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
