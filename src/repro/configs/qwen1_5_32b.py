"""qwen1.5-32b — dense MHA decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B] 64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064.
"""

import dataclasses

from repro.config import FAMILY_DENSE, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family=FAMILY_DENSE,
    source="[hf:Qwen/Qwen1.5-0.5B]",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    tie_embeddings=False,
    probe=ProbeConfig(tap_layer=22),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
