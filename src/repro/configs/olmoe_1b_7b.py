"""olmoe-1b-7b — 64-expert top-8 MoE.

[arXiv:2409.02060] 16L d_model=2048 16H (kv=16) d_ff=1024 vocab=50304.
"""

import dataclasses

from repro.config import FAMILY_MOE, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family=FAMILY_MOE,
    source="[arXiv:2409.02060]",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,                  # per-expert hidden
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    tie_embeddings=False,
    probe=ProbeConfig(tap_layer=6),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="olmoe-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=64,
    vocab_size=512,
    num_experts=4,
    experts_per_token=2,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
