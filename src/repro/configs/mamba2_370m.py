"""mamba2-370m — pure SSM (state-space duality), attention-free.

[arXiv:2405.21060] 48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128.
"""

import dataclasses

from repro.config import FAMILY_SSM, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family=FAMILY_SSM,
    source="[arXiv:2405.21060]",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,            # unused (attention-free)
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    use_rope=False,
    probe=ProbeConfig(tap_layer=24),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="mamba2-smoke",
    num_layers=2,
    d_model=128,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=32,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
