"""arctic-480b — 128-expert top-2 MoE with a dense residual MLP path.

[hf:Snowflake/snowflake-arctic-base] 35L d_model=7168 56H (kv=8) d_ff=4864
vocab=32000, MoE 128e top-2, dense-MoE hybrid residual.
"""

import dataclasses

from repro.config import FAMILY_MOE, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family=FAMILY_MOE,
    source="[hf:Snowflake/snowflake-arctic-base]",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                  # per-expert hidden
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,    # arctic's dense residual path
    probe=ProbeConfig(tap_layer=12),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="arctic-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    num_experts=4,
    experts_per_token=2,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
