"""whisper-tiny — encoder-decoder audio model; conv/mel frontend is a stub.

[arXiv:2212.04356] 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
``input_specs`` provides precomputed frame embeddings (1500, 384) per request.
"""

import dataclasses

from repro.config import FAMILY_AUDIO, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family=FAMILY_AUDIO,
    source="[arXiv:2212.04356]",
    num_layers=4,                # decoder layers
    num_encoder_layers=4,
    encoder_seq=1500,            # 30s of audio at 50 frames/s (stub embeddings)
    cross_attention=True,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    use_rope=False,              # whisper uses learned positions; we use rope=False + learned
    tie_embeddings=True,
    probe=ProbeConfig(tap_layer=2),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="whisper-smoke",
    num_layers=2,
    num_encoder_layers=2,
    encoder_seq=64,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=0,
    d_ff=256,
    vocab_size=512,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
