"""gemma2-9b — dense, alternating local/global attention, logit softcaps.

[arXiv:2408.00118] 42L d_model=3584 16H (kv=8) d_ff=14336 vocab=256000.
Window 4096; attn softcap 50.0; final softcap 30.0.
"""

import dataclasses

from repro.config import (FAMILY_DENSE, ModelConfig, ProbeConfig,
                          pattern_local_global)

CONFIG = ModelConfig(
    name="gemma2-9b",
    family=FAMILY_DENSE,
    source="[arXiv:2408.00118]",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_kinds=pattern_local_global(42, local=1, glob=1),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    embed_scale=True,
    probe=ProbeConfig(tap_layer=14),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="gemma2-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    layer_kinds=pattern_local_global(2, local=1, glob=1),
    sliding_window=16,
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
