"""paligemma-3b — VLM; SigLIP vision tower is a stub (prefix patch embeddings).

[arXiv:2407.07726] 18L d_model=2048 8H (kv=1, MQA) d_ff=16384 vocab=257216.
"""

import dataclasses

from repro.config import FAMILY_VLM, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family=FAMILY_VLM,
    source="[arXiv:2407.07726]",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    num_prefix_tokens=256,       # 224px / 14px SigLIP patches (stub)
    embed_scale=True,
    probe=ProbeConfig(tap_layer=9),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="paligemma-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    num_prefix_tokens=16,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
