"""granite-3-8b — dense GQA decoder.

[hf:ibm-granite/granite-3.0-2b-base] 40L d_model=4096 32H (kv=8) d_ff=12800
vocab=49155.
"""

import dataclasses

from repro.config import FAMILY_DENSE, ModelConfig, ProbeConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family=FAMILY_DENSE,
    source="[hf:ibm-granite/granite-3.0-2b-base]",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    probe=ProbeConfig(tap_layer=14),   # mid-stack, paper's 11/32 ratio
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="granite-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    layer_kinds=(),
    probe=ProbeConfig(tap_layer=0, hidden=32, num_bins=5, max_len=64),
)
