"""Per-architecture configs. Each module exports CONFIG (full) and SMOKE (reduced)."""
