"""Deterministic fault injection for the cluster layer.

A `FaultSchedule` is a seeded, immutable description of what goes wrong
and when — replica crashes (with optional recovery), slowdown/straggler
windows, and transient submit failures. The `Router` consults it every
loop iteration (step-level health checks) and reacts: crashed replicas
are drained and their unfinished requests redispatched to survivors
with capped exponential backoff under a retry budget; stragglers are
excluded from dispatch while degraded; flaky submits redirect the
arrival to another replica (also charged against the retry budget).

Everything is driven by *virtual* time (the replicas' simulated clocks)
and a seeded RNG, so a chaos run is exactly reproducible: same schedule
+ same seed + same trace => byte-identical results.

The CLI encodes a schedule as a comma-separated ``--chaos`` spec,
parsed by `parse_chaos`::

    crash:R@T            replica R dies at time T (never recovers)
    crash:R@T-U          ...and recovers, empty, at time U
    slow:R@T-U*F         replica R runs F x slower in [T, U)
    flaky:R@T-U%P        submits to R fail w.p. P in [T, U)

e.g. ``--chaos crash:1@30,slow:0@10-20*4``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

#: recover_at value meaning "never recovers".
NEVER = math.inf


@dataclass(frozen=True)
class ReplicaCrash:
    """One replica failure: dead from ``at`` until ``recover_at``.

    Attributes:
        replica: index of the replica that fails.
        at: virtual time of the crash (enforced at the first megastep
            boundary at or after it).
        recover_at: virtual time the replica rejoins, empty (KV pool
            reclaimed, no requests); `NEVER` (the default) = permanent.
    """

    replica: int
    at: float
    recover_at: float = NEVER

    def __post_init__(self):
        if self.recover_at <= self.at:
            raise ValueError(
                f"recover_at {self.recover_at} must be after at {self.at}")


@dataclass(frozen=True)
class SlowdownWindow:
    """A straggler window: ``replica`` runs ``factor`` x slower.

    In ``[start, end)`` megastep times dilate; the router also excludes
    the replica from dispatch while degraded.
    """

    replica: int
    start: float
    end: float
    factor: float = 4.0

    def __post_init__(self):
        if self.factor <= 0:
            raise ValueError(f"slowdown factor must be positive: "
                             f"{self.factor}")
        if self.end <= self.start:
            raise ValueError(f"empty slowdown window [{self.start}, "
                             f"{self.end})")


@dataclass(frozen=True)
class FlakySubmit:
    """Transient submit failures on one replica.

    A dispatch to ``replica`` during ``[start, end)`` fails with
    probability ``fail_rate`` (seeded draw); the router retries the
    arrival elsewhere.
    """

    replica: int
    start: float
    end: float
    fail_rate: float = 0.5

    def __post_init__(self):
        if not 0.0 <= self.fail_rate <= 1.0:
            raise ValueError(f"fail_rate must be in [0, 1]: "
                             f"{self.fail_rate}")
        if self.end <= self.start:
            raise ValueError(f"empty flaky window [{self.start}, "
                             f"{self.end})")


@dataclass(frozen=True)
class FaultSchedule:
    """The full, immutable chaos plan for one cluster run.

    Attributes:
        crashes: `ReplicaCrash` tuple (at most one per replica — a
            crash-recover-crash sequence is not modeled).
        slowdowns: `SlowdownWindow` tuple (overlapping windows on one
            replica multiply).
        flaky: `FlakySubmit` tuple.
        seed: seed for the transient-failure draws (the router builds
            its RNG from it, so submit-failure outcomes are independent
            of every engine/workload stream).
    """

    crashes: tuple = ()
    slowdowns: tuple = ()
    flaky: tuple = ()
    seed: int = 0

    def __post_init__(self):
        seen = set()
        for c in self.crashes:
            if c.replica in seen:
                raise ValueError(
                    f"replica {c.replica} has multiple crash entries")
            seen.add(c.replica)

    def crash_for(self, replica: int) -> ReplicaCrash | None:
        """The crash entry for ``replica``, or None."""
        for c in self.crashes:
            if c.replica == replica:
                return c
        return None

    def slow_factor(self, replica: int, t: float) -> float:
        """Combined slowdown for ``replica`` at ``t`` (1.0 = healthy).

        Overlapping windows multiply.
        """
        f = 1.0
        for w in self.slowdowns:
            if w.replica == replica and w.start <= t < w.end:
                f *= w.factor
        return f

    def degraded(self, replica: int, t: float) -> bool:
        """True while ``replica`` is inside any slowdown window.

        The router excludes degraded replicas from dispatch.
        """
        return self.slow_factor(replica, t) != 1.0

    def flaky_rate(self, replica: int, t: float) -> float:
        """Submit-failure probability for ``replica`` at time ``t``.

        Independent windows compose: fail if any window fails.
        """
        ok = 1.0
        for w in self.flaky:
            if w.replica == replica and w.start <= t < w.end:
                ok *= 1.0 - w.fail_rate
        return 1.0 - ok


def parse_chaos(spec: str, seed: int = 0) -> FaultSchedule:
    """Parse a ``--chaos`` CLI spec into a `FaultSchedule`.

    Grammar (comma-separated entries)::

        crash:R@T | crash:R@T-U | slow:R@T-U*F | flaky:R@T-U%P

    Raises ValueError with a one-line actionable message on any
    malformed entry (the serve CLI surfaces it as an exit-2 error).
    """
    crashes: list[ReplicaCrash] = []
    slowdowns: list[SlowdownWindow] = []
    flaky: list[FlakySubmit] = []
    for raw in spec.split(","):
        entry = raw.strip()
        if not entry:
            continue
        try:
            kind, rest = entry.split(":", 1)
            rep_s, when = rest.split("@", 1)
            rep = int(rep_s)
            if kind == "crash":
                if "-" in when:
                    at_s, rec_s = when.split("-", 1)
                    crashes.append(ReplicaCrash(rep, float(at_s),
                                                float(rec_s)))
                else:
                    crashes.append(ReplicaCrash(rep, float(when)))
            elif kind == "slow":
                window, factor_s = when.split("*", 1)
                start_s, end_s = window.split("-", 1)
                slowdowns.append(SlowdownWindow(rep, float(start_s),
                                                float(end_s),
                                                float(factor_s)))
            elif kind == "flaky":
                window, rate_s = when.split("%", 1)
                start_s, end_s = window.split("-", 1)
                flaky.append(FlakySubmit(rep, float(start_s), float(end_s),
                                         float(rate_s)))
            else:
                raise ValueError(f"unknown fault kind {kind!r}")
        except ValueError as e:
            raise ValueError(
                f"bad --chaos entry {entry!r}: {e} (expected "
                "crash:R@T[-U], slow:R@T-U*F, or flaky:R@T-U%P)") from e
    return FaultSchedule(crashes=tuple(crashes), slowdowns=tuple(slowdowns),
                         flaky=tuple(flaky), seed=seed)


__all__ = ["FaultSchedule", "ReplicaCrash", "SlowdownWindow", "FlakySubmit",
           "parse_chaos", "NEVER"]
