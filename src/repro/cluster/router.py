"""Cluster router: dispatch streaming arrivals across N replica engines.

Each replica is a full `Engine` (its own SPRPT-LP scheduler, KV
accounting, and virtual clock) driven through the incremental
``submit()``/``step()`` API. The router runs a virtual-time event loop:

* a pending arrival is dispatched once every busy replica's clock has
  reached its arrival time (so the routing decision observes replica
  state *at* — never before — the arrival);
* otherwise the replica furthest behind in virtual time executes one
  engine step, advancing the cluster frontier.

Dispatch policies (``RouterConfig.policy``):

* ``round-robin`` — cyclic, state-blind (the baseline).
* ``jsq``         — join-shortest-queue by unfinished request count.
* ``pow2``        — power-of-two-choices: sample two replicas, join the
                    shorter queue (Mitzenmacher's classic load balancer).
* ``jspw``        — join-shortest-predicted-work over each replica's live
                    TRAIL per-token remaining-length predictions plus
                    remaining prefill work (`Engine.backlog`). This is the
                    paper's probe signal lifted to the cluster layer (cf.
                    proxy-model routing, arXiv:2404.08509). Because every
                    replica schedules with SPRPT internally, longer jobs
                    yield to a new arrival — so when the router has a
                    ``size_predictor`` (prompt-only r0 estimate, the
                    paper's BERT/probe signal), each replica's predictions
                    are truncated at the arrival's own size estimate:
                    join-shortest *interfering* predicted work. Without a
                    size predictor the raw backlog sum is used (the
                    FCFS-replica signal). Backlog ties break on KV
                    headroom (`Engine.kv_headroom`): a replica near its
                    memory budget pays future preemptions for every
                    long-context request it accepts.
* ``prefix-affinity`` — join the replica whose KV prefix cache holds the
                    longest prefix of the arrival's prompt
                    (`Engine.cached_prefix_tokens`): the linked pages
                    skip prefill compute entirely and shrink the shared
                    footprint. Replicas tying on affinity (including the
                    0-hit case) fall back to the full ``jspw`` rule, so
                    with prefix caching disabled the policy degrades to
                    exactly ``jspw``.

Prefill/decode disaggregation (``RouterConfig.prefill_replicas`` = P > 0):
replicas ``[0, P)`` run ``prefill_only`` engines and the rest decode.
Arrivals (and failover retries) dispatch into the prefill pool under the
configured policy; each completed prefill is exported as a `KVHandoff`
(paged KV pages, one batched host-bounce per request) and shipped to the
decode replica with the least predicted work *including in-flight
handoffs* (transfer-aware JSPW). The transfer charges
`CostModel.kv_transfer_time` as delayed availability on the router's
virtual clock — decode megasteps keep running underneath, so shipping
overlaps compute instead of stalling the batch. With P = 0 (default) the
loop is byte-identical to the colocated router.

Resilience (optional, via a `repro.cluster.faults.FaultSchedule`): the
router health-checks the fleet at every loop boundary — crashed replicas
are drained (their paged KV fully reclaimed) and their unfinished
requests redispatched to survivors with capped exponential backoff under
a per-request retry budget; straggler replicas are excluded from
dispatch while degraded; transient submit failures fail over to another
replica at the same instant. Retried requests keep their original
arrival timestamp, so completion latency and TTFT stay user-perceived.
Without a schedule the loop is byte-identical to the fault-free path.
"""

from __future__ import annotations

import copy
import heapq
import random
from dataclasses import dataclass, field

import numpy as np

from repro.cluster.faults import NEVER, FaultSchedule
from repro.core.scheduler import SchedEntry
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request

#: Dispatch policies understood by `Router`.
ROUTER_POLICIES = ("round-robin", "jsq", "pow2", "jspw", "prefix-affinity")


@dataclass
class RouterConfig:
    """Cluster-level knobs.

    Attributes:
        n_replicas: number of replica engines.
        policy: dispatch policy — one of `ROUTER_POLICIES`.
        seed: RNG seed for the ``pow2`` replica sampler (dispatch is
            deterministic given the seed and the arrival stream).
        backlog_unit: ``tokens`` (raw predicted-token backlog, the
            default) | ``seconds`` (tokens ÷ each replica's
            `CostModel.decode_token_rate`, via `Engine.backlog_seconds`).
            Seconds is the unit that stays meaningful once replicas run
            on heterogeneous hardware; with identical replicas the two
            units rank identically, so `jspw` dispatch is unchanged.
        max_retries: failover retry budget per request — how many times
            a request drained from a crashed replica (or bounced by a
            transient submit failure) is redispatched before it is
            declared lost.
        retry_backoff_s: base of the capped exponential backoff between
            failover redispatches (the k-th retry waits
            ``min(retry_backoff_s * 2**(k-1), retry_backoff_cap_s)``).
        retry_backoff_cap_s: the backoff cap.
        prefill_replicas: disaggregated topology — the first P replicas
            are a prefill pool (``EngineConfig.prefill_only``) and the
            remaining ``n_replicas - P`` a decode pool; completed
            prefills ship their paged KV prefill→decode as `KVHandoff`
            batches. 0 (the default) keeps every replica colocated,
            byte-identical to the pre-disaggregation router.
    """

    n_replicas: int = 2
    policy: str = "round-robin"
    seed: int = 0
    backlog_unit: str = "tokens"
    max_retries: int = 2
    retry_backoff_s: float = 0.5
    retry_backoff_cap_s: float = 8.0
    prefill_replicas: int = 0


@dataclass
class ClusterStats:
    """Aggregated results of one cluster run.

    Attributes:
        latencies: completion times (finish - arrival) across all replicas.
        ttfts: time-to-first-token across all replicas.
        dispatch_counts: requests dispatched per replica.
        replica_summaries: each replica's `EngineStats.summary()` dict.
        makespan: max replica virtual clock at drain.
        event_log: the replicas' metrics-layer event streams merged into
            one time-ordered `repro.metrics.EventLog` (None unless the
            replicas were built with event logs). Feed it to
            `repro.metrics.rollup` for cluster-wide TTFT/TBT/completion
            percentiles and SLO attainment.
        n_requests: arrival-stream size (the goodput denominator).
        n_retries: failover redispatches performed across the run.
        n_lost: requests dropped after exhausting the retry budget.
        n_crashes: replica crash events applied.
        n_handoffs: prefill→decode KV handoffs delivered (disagg mode).
        handoff_pages: KV pages shipped across all handoffs.
        leaked_pages: per-replica ``BlockManager.used_pages()`` at drain
            — all zeros on a clean run (the zero-leak invariant the
            disagg benchmark gates on; contig replicas report 0).
    """

    latencies: list = field(default_factory=list)
    ttfts: list = field(default_factory=list)
    dispatch_counts: list = field(default_factory=list)
    replica_summaries: list = field(default_factory=list)
    makespan: float = 0.0
    event_log: object = None
    n_requests: int = 0
    n_retries: int = 0
    n_lost: int = 0
    n_crashes: int = 0
    n_handoffs: int = 0
    handoff_pages: int = 0
    leaked_pages: list = field(default_factory=list)

    def summary(self) -> dict:
        """Aggregate cluster metrics into the benchmark-facing dict."""
        lat = sorted(self.latencies)
        tt = sorted(self.ttfts)
        return {
            "mean_latency": float(np.mean(lat)) if lat else 0.0,
            "median_latency": lat[len(lat) // 2] if lat else 0.0,
            "p99_latency": lat[int(len(lat) * 0.99)] if lat else 0.0,
            "mean_ttft": float(np.mean(tt)) if tt else 0.0,
            "median_ttft": tt[len(tt) // 2] if tt else 0.0,
            "finished": len(lat),
            "dispatch_counts": list(self.dispatch_counts),
            "preemptions": sum(s["preemptions"]
                               for s in self.replica_summaries),
            "peak_batch": max((s["peak_batch"]
                               for s in self.replica_summaries), default=0),
            "prefilled_tokens": sum(s.get("prefilled_tokens", 0)
                                    for s in self.replica_summaries),
            "prefix_hit_tokens": sum(s.get("prefix_hit_tokens", 0)
                                     for s in self.replica_summaries),
            "predictor_time_s": sum(s.get("predictor_time_s", 0.0)
                                    for s in self.replica_summaries),
            "predictor_calls": sum(s.get("predictor_calls", 0)
                                   for s in self.replica_summaries),
            "makespan": self.makespan,
            "retries": self.n_retries,
            "lost": self.n_lost,
            "replica_crashes": self.n_crashes,
            "cancelled": sum(s.get("cancelled", 0)
                             for s in self.replica_summaries),
            "timeouts": sum(s.get("timeouts", 0)
                            for s in self.replica_summaries),
            "shed": sum(s.get("shed", 0)
                        for s in self.replica_summaries),
            "handoffs": self.n_handoffs,
            "handoff_pages": self.handoff_pages,
            "leaked_pages": sum(self.leaked_pages),
            # served-to-completion fraction of the arrival stream —
            # crashes, sheds, timeouts, and lost requests all count
            # against it
            "goodput": (len(lat) / self.n_requests
                        if self.n_requests else 0.0),
        }


class Router:
    """Dispatches a request stream across replica engines in virtual time.

    The router owns nothing about scheduling *within* a replica — that is
    the engine's SPRPT-LP job. It only decides *which* replica an arrival
    joins, then keeps all replica clocks loosely synchronized by always
    stepping the laggard.
    """

    def __init__(self, replicas: list[Engine], rc: RouterConfig,
                 size_predictor=None, faults: FaultSchedule | None = None,
                 event_log=None):
        """Wrap pre-built replica engines under one dispatch policy.

        Args:
            replicas: the engines (length must equal ``rc.n_replicas``).
            rc: cluster-level configuration.
            size_predictor: optional predictor whose ``initial(req)``
                gives a prompt-only output-length estimate for ``jspw``
                truncation (see module docstring). It must be a separate
                instance from any replica's predictor so router draws
                never perturb engine prediction streams.
            faults: optional `FaultSchedule` — deterministic crash /
                straggler / flaky-submit injection; None (the default)
                runs fault-free with zero overhead in the loop.
            event_log: optional router-owned `repro.metrics.EventLog`
                for cluster-level events (``replica_down`` /
                ``replica_up`` / ``retry`` / lost-request ``cancel``);
                merged into `merged_event_log()`.
        """
        if rc.policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {rc.policy!r}; "
                             f"choose from {ROUTER_POLICIES}")
        if rc.backlog_unit not in ("tokens", "seconds"):
            raise ValueError(f"unknown backlog_unit {rc.backlog_unit!r}; "
                             "choose 'tokens' or 'seconds'")
        if len(replicas) != rc.n_replicas:
            raise ValueError(f"{len(replicas)} replicas != "
                             f"n_replicas={rc.n_replicas}")
        if rc.prefill_replicas:
            if not 0 < rc.prefill_replicas < rc.n_replicas:
                raise ValueError(
                    f"prefill_replicas={rc.prefill_replicas} must leave "
                    f"at least one decode replica (n={rc.n_replicas})")
            for i, eng in enumerate(replicas):
                if bool(eng.ecfg.prefill_only) != (i < rc.prefill_replicas):
                    raise ValueError(
                        f"disagg topology: replicas[:{rc.prefill_replicas}]"
                        f" must be prefill_only and the rest decode "
                        f"(replica {i} mismatched)")
        for c in (faults.crashes if faults is not None else ()):
            if not 0 <= c.replica < rc.n_replicas:
                raise ValueError(f"fault schedule names replica "
                                 f"{c.replica} (cluster has "
                                 f"{rc.n_replicas})")
        self.replicas = replicas
        self.rc = rc
        self.size_predictor = size_predictor
        self.faults = faults
        self.events = event_log
        self._rr_next = 0
        self._rng = random.Random(rc.seed)
        # dedicated stream for transient-submit draws: fault outcomes
        # must not perturb the pow2 sampler (and vice versa)
        self._fault_rng = random.Random(faults.seed if faults is not None
                                        else 0)
        self._alive = [True] * rc.n_replicas
        self._crashed = [False] * rc.n_replicas   # crash already applied
        self._retryq: list[tuple[float, int, Request]] = []
        self._retry_seq = 0
        # in-flight KV handoffs: (t_ready, seq, dst, pred_tokens, handoff)
        self._handoffq: list[tuple] = []
        self._handoff_seq = 0
        self._inflight: dict[int, float] = {}   # dst -> queued pred tokens
        self.n_retries = 0
        self.n_lost = 0
        self.n_crashes = 0
        self.n_handoffs = 0
        self.handoff_pages = 0
        self.dispatch_counts = [0] * rc.n_replicas
        self.dispatch_log: list[tuple[int, int]] = []   # (rid, replica)

    # -- dispatch policies ------------------------------------------------
    def _queue_key(self, i: int) -> tuple:
        return (self.replicas[i].queue_len(), i)

    def _candidates(self, t: float, exclude=()) -> list[int]:
        """Replica indices eligible for dispatch at time ``t``.

        Alive, not excluded, and (fault mode) not inside a straggler
        window — unless every alive replica is degraded, in which case
        slow beats nowhere. In a disaggregated topology arrivals (and
        retries) only ever dispatch into the prefill pool.
        """
        pool = (range(self.rc.prefill_replicas)
                if self.rc.prefill_replicas else range(len(self.replicas)))
        alive = [i for i in pool
                 if self._alive[i] and i not in exclude]
        if self.faults is None:
            return alive
        healthy = [i for i in alive if not self.faults.degraded(i, t)]
        return healthy or alive

    def _pick(self, req: Request, cands: list[int] | None = None) -> int:
        """Choose the replica index for one arrival (policy decision).

        ``cands`` restricts the choice (fault mode: alive, non-degraded
        replicas); None = all replicas, and every policy below reduces
        exactly to its pre-resilience behavior in that case.
        """
        pol = self.rc.policy
        n = len(self.replicas)
        if cands is None:
            cands = list(range(n))
        if pol == "round-robin":
            # cyclic over the eligible set: advance the cursor until it
            # lands on a candidate (identical to the legacy cyclic scan
            # when every replica is eligible)
            for _ in range(n):
                i = self._rr_next
                self._rr_next = (self._rr_next + 1) % n
                if i in cands:
                    return i
            return cands[0]
        if pol == "jsq":
            return min(cands, key=self._queue_key)
        if pol == "pow2":
            if len(cands) == 1:
                return cands[0]
            # full fleet keeps the legacy range(n) draw so fault-free
            # dispatch streams stay byte-identical
            pool = range(n) if len(cands) == n else cands
            a, b = self._rng.sample(pool, 2)
            return min(a, b, key=self._queue_key)
        # the size estimate is drawn once per dispatch (predictor streams
        # are stateful), shared by every replica's key below
        r_hat = (self.size_predictor.initial(req)
                 if self.size_predictor is not None else None)
        if pol == "prefix-affinity":
            # longest cached prompt prefix wins; ties (notably 0-hit
            # everywhere, or caching disabled) fall back to jspw
            hits = {i: self.replicas[i].cached_prefix_tokens(req.prompt)
                    for i in cands}
            best = max(hits.values())
            tied = [i for i in cands if hits[i] == best]
            return min(tied, key=lambda i: self._jspw_key(i, r_hat))
        # jspw: live predicted-work backlog — truncated at the arrival's
        # own size estimate when available (SRPT-interfering work) — with
        # KV headroom, queue length, then index as tie-breaks
        return min(cands, key=lambda i: self._jspw_key(i, r_hat))

    def _jspw_key(self, i: int, r_hat: float | None) -> tuple:
        """The jspw ordering for one replica.

        Predicted interfering work (in ``rc.backlog_unit`` units —
        estimated seconds divide tokens by the replica's own service
        rate, the heterogeneous-hardware form), then (on ties) most KV
        headroom, shortest queue, lowest index.
        """
        eng = self.replicas[i]
        work = (eng.backlog_seconds(truncate=r_hat)
                if self.rc.backlog_unit == "seconds"
                else eng.backlog(truncate=r_hat))
        return (work, -eng.kv_headroom(), eng.queue_len(), i)

    # -- disaggregation: prefill→decode KV handoffs -----------------------
    def _decode_key(self, i: int, r_hat: float | None) -> tuple:
        """Transfer-aware jspw for the decode pool.

        `_jspw_key` plus the predicted tokens of handoffs already queued
        toward replica ``i`` but not yet imported — without them, every
        handoff in one drain pass would pile onto the same
        momentarily-idle replica.
        """
        eng = self.replicas[i]
        inflight = self._inflight.get(i, 0.0)
        if self.rc.backlog_unit == "seconds":
            work = (eng.backlog_seconds(truncate=r_hat)
                    + inflight / eng.cost.decode_token_rate())
        else:
            work = eng.backlog(truncate=r_hat) + inflight
        return (work, -eng.kv_headroom(), eng.queue_len(), i)

    def _pick_decode(self, handoff, t: float) -> int:
        """Choose the decode replica for one handoff.

        Alive, preferring non-degraded; -1 when the decode pool is
        entirely down.
        """
        cands = [i for i in range(self.rc.prefill_replicas,
                                  len(self.replicas)) if self._alive[i]]
        if self.faults is not None:
            healthy = [i for i in cands
                       if not self.faults.degraded(i, t)]
            cands = healthy or cands
        if not cands:
            return -1
        return min(cands, key=lambda i: self._decode_key(
            i, handoff.pred_tokens))

    def _drain_handoffs(self):
        """Export parked prefill-complete requests toward decode.

        Runs at every loop boundary (before the busy scan), so a prefill
        replica holding only parked work is drained rather than stalling
        the virtual-time frontier.
        """
        for i in range(self.rc.prefill_replicas):
            eng = self.replicas[i]
            if not self._alive[i]:
                continue
            for rid in eng.handoff_ready():
                h = eng.export_request(rid)
                j = self._pick_decode(h, eng.now)
                if j < 0:
                    # decode pool entirely down: failover (progress lost,
                    # re-dispatches into the prefill pool after backoff)
                    self._requeue(h.req, eng.now)
                    continue
                t_ready = eng.now + eng.cost.kv_transfer_time(h.nbytes)
                work = h.pred_tokens or 0.0
                self._inflight[j] = self._inflight.get(j, 0.0) + work
                heapq.heappush(self._handoffq,
                               (t_ready, self._handoff_seq, j, work, h))
                self._handoff_seq += 1
                self.n_handoffs += 1
                self.handoff_pages += h.n_pages

    def _deliver_handoff(self):
        """Pop the due handoff and import it on its destination.

        A destination that crashed while the transfer was in flight
        sends the request through the normal failover path instead.
        """
        t_r, _, j, work, h = heapq.heappop(self._handoffq)
        self._inflight[j] = self._inflight.get(j, 0.0) - work
        if self._alive[j]:
            self.replicas[j].import_request(h, t=t_r)
            self.dispatch_counts[j] += 1
            self.dispatch_log.append((h.req.rid, j))
        else:
            self._requeue(h.req, t_r)

    def dispatch(self, req: Request, t: float | None = None) -> int:
        """Route one arrival to a replica and submit it there.

        In fault mode the pick is restricted to alive, non-degraded
        replicas and the submit may transiently fail (seeded draw);
        failures charge the retry budget and fail over to another
        replica immediately when one exists, else requeue with backoff.
        Returns the replica index, or -1 if the request could not be
        placed (requeued or lost).
        """
        t = req.arrival if t is None else t
        tried: set[int] = set()
        while True:
            cands = self._candidates(t, exclude=tried)
            if not cands:
                self._defer_or_drop(req, t)
                return -1
            i = self._pick(req, cands if (self.faults is not None
                                          or len(cands)
                                          != len(self.replicas))
                           else None)
            if (self.faults is not None and self._fault_rng.random()
                    < self.faults.flaky_rate(i, t)):
                # transient submit failure: fail over to another replica
                # (same instant), charged against the retry budget
                tried.add(i)
                if not self._charge_retry(req, t):
                    return -1
                continue
            self.replicas[i].submit(req)
            self.dispatch_counts[i] += 1
            self.dispatch_log.append((req.rid, i))
            return i

    # -- fault machinery --------------------------------------------------
    def _apply_faults(self, t_ref: float):
        """Step-level health check at cluster time ``t_ref``.

        Applies due crashes (drain + requeue the dead replica's
        requests) and due recoveries. A busy replica crashes at its
        first megastep boundary at/after the scheduled time; an idle
        one when the cluster frontier passes it.
        """
        if self.faults is None:
            return
        for i, eng in enumerate(self.replicas):
            c = self.faults.crash_for(i)
            if c is None:
                continue
            if (self._alive[i] and not self._crashed[i]
                    and c.at <= max(eng.now, t_ref)):
                t_c = max(eng.now, c.at)
                self._crashed[i] = True
                self._alive[i] = False
                self.n_crashes += 1
                drained = eng.crash(t_c)
                if self.events is not None:
                    self.events.emit(t_c, -1, "replica_down", i)
                for req in drained:
                    self._requeue(req, t_c)
            elif (self._crashed[i] and not self._alive[i]
                    and c.recover_at <= t_ref):
                self._alive[i] = True
                eng.revive(c.recover_at)
                if self.events is not None:
                    self.events.emit(c.recover_at, -1, "replica_up", i)

    def _charge_retry(self, req: Request, t_fail: float) -> bool:
        """Spend one retry.

        False when the budget is exhausted (the request is dropped and
        counted lost).
        """
        if req.retries >= self.rc.max_retries:
            self.n_lost += 1
            if self.events is not None:
                # the arrival may never have reached any engine's log;
                # emit it (rollup dedups per-rid) so goodput sees the
                # loss, then the terminal cancel
                self.events.emit(req.arrival, req.rid, "arrival")
                self.events.emit(max(t_fail, req.arrival), req.rid,
                                 "cancel")
            return False
        req.retries += 1
        self.n_retries += 1
        if self.events is not None:
            self.events.emit(max(t_fail, req.arrival), req.rid, "retry",
                             req.retries)
        return True

    def _requeue(self, req: Request, t_fail: float):
        """Failover path: reset progress and requeue with backoff.

        Capped exponential backoff; the original arrival is preserved,
        so completion latency stays user-perceived.
        """
        if not self._charge_retry(req, t_fail):
            return
        backoff = min(self.rc.retry_backoff_s * 2 ** (req.retries - 1),
                      self.rc.retry_backoff_cap_s)
        self._reset_for_retry(req)
        heapq.heappush(self._retryq,
                       (t_fail + backoff, self._retry_seq, req))
        self._retry_seq += 1

    @staticmethod
    def _reset_for_retry(req: Request):
        """Wipe engine-side progress for a clean re-prefill.

        The survivor re-prefills from scratch (its prefix cache makes
        that cheap for warm prompts). The original ``arrival`` and any
        already-streamed first-token time are kept — metrics stay
        user-perceived.
        """
        req.generated = []
        req.entry = SchedEntry(rid=req.rid, arrival=req.arrival,
                               prompt_len=len(req.prompt))
        req.posterior = None
        req.tap_sum = None
        req.tap_cnt = 0
        req.slot = -1
        req.finish_time = -1.0

    def _defer_or_drop(self, req: Request, t: float):
        """Handle an arrival with no eligible replica.

        Waits for the next scheduled recovery when one exists (not
        charged as a retry), else the request is lost.
        """
        recoveries = []
        if self.faults is not None:
            for i in range(len(self.replicas)):
                if self._alive[i]:
                    continue
                c = self.faults.crash_for(i)
                if c is not None and c.recover_at != NEVER:
                    recoveries.append(c.recover_at)
        t_rec = min((r for r in recoveries if r > t), default=None)
        if t_rec is not None:
            heapq.heappush(self._retryq, (t_rec, self._retry_seq, req))
            self._retry_seq += 1
            return
        self.n_lost += 1
        if self.events is not None:
            self.events.emit(req.arrival, req.rid, "arrival")
            self.events.emit(max(t, req.arrival), req.rid, "cancel")

    # -- virtual-time event loop ------------------------------------------
    def run(self, requests: list[Request]) -> ClusterStats:
        """Drive the whole arrival stream to completion.

        Arrivals (original stream merged with failover retries) are
        consumed in time order; between dispatches, the busy replica
        with the smallest virtual clock steps, with due faults applied
        at every boundary. The loop ends when every alive replica is
        drained and no arrival or retry remains.
        """
        pending = sorted(requests, key=lambda r: r.arrival)
        q = 0
        while True:
            if self.rc.prefill_replicas:
                # export parked prefills first: a prefill replica whose
                # every request is parked would otherwise pin the
                # frontier forever (it has work but its clock is idle)
                self._drain_handoffs()
            busy = [e for i, e in enumerate(self.replicas)
                    if self._alive[i] and e.has_work()]
            # next event: original arrival vs. failover retry vs. due
            # KV handoff (delivered with priority on ties — the import
            # must land before a same-instant routing decision observes
            # the destination)
            t_arr = pending[q].arrival if q < len(pending) else None
            t_rty = self._retryq[0][0] if self._retryq else None
            t_hnd = self._handoffq[0][0] if self._handoffq else None
            t_next = min((t for t in (t_arr, t_rty, t_hnd)
                          if t is not None), default=None)
            if t_next is not None:
                frontier = min((e.now for e in busy), default=t_next)
                if t_next <= frontier:
                    # cluster time has reached the arrival: fire any
                    # fault due by now (idle replicas included) before
                    # the routing decision observes the fleet
                    self._apply_faults(t_next)
                    if t_hnd is not None and t_hnd <= t_next:
                        self._deliver_handoff()
                        continue
                    if t_rty is not None and (t_arr is None
                                              or t_rty <= t_arr):
                        _, _, req = heapq.heappop(self._retryq)
                    else:
                        req = pending[q]
                        q += 1
                    self.dispatch(req, t_next)
                    continue
            if not busy:
                break
            lag = min(busy, key=lambda e: e.now)
            if self.faults is not None:
                self._apply_faults(lag.now)
                if not lag.alive:       # the laggard just crashed
                    continue
                idx = self.replicas.index(lag)
                lag.set_slowdown(self.faults.slow_factor(idx, lag.now))
            lag.step()

        stats = ClusterStats(dispatch_counts=list(self.dispatch_counts),
                             n_requests=len(requests),
                             n_retries=self.n_retries,
                             n_lost=self.n_lost,
                             n_crashes=self.n_crashes,
                             n_handoffs=self.n_handoffs,
                             handoff_pages=self.handoff_pages,
                             leaked_pages=[
                                 eng.blocks.used_pages()
                                 if eng.blocks is not None else 0
                                 for eng in self.replicas])
        for eng in self.replicas:
            stats.latencies.extend(eng.stats.latencies)
            stats.ttfts.extend(eng.stats.ttfts)
            stats.replica_summaries.append(eng.stats.summary())
            stats.makespan = max(stats.makespan, eng.now)
        stats.event_log = self.merged_event_log()
        return stats

    def merged_event_log(self):
        """Merge the replicas' event logs into one time-ordered log.

        Returns None when no replica records events. Per-request event
        ordering survives the merge because each request lives on
        exactly one replica. Delegates to ``EventLog.merge_all`` — one
        concatenate-and-sort over all replicas instead of a re-sort per
        pairwise merge, with the merge key defined in exactly one place.
        """
        logs = [eng.events for eng in self.replicas
                if getattr(eng, "events", None) is not None]
        if self.events is not None and len(self.events):
            logs.append(self.events)
        if not logs:
            return None
        from repro.metrics.events import EventLog
        return EventLog.merge_all(logs)


def run_cluster(cfg, requests, *, router_policy: str = "round-robin",
                n_replicas: int = 2, seed: int = 0,
                predictor_factory=None, size_predictor=None,
                record_events: bool = False,
                backlog_unit: str = "tokens",
                faults: FaultSchedule | None = None,
                max_retries: int = 2,
                prefill_replicas: int = 0,
                **engine_kwargs) -> ClusterStats:
    """Serve ``requests`` on an N-replica cluster (the `run_policy` twin).

    Args:
        cfg: the `ModelConfig` every replica serves.
        requests: the shared arrival stream (deep-copied, as in
            ``run_policy``).
        router_policy: one of `ROUTER_POLICIES`.
        n_replicas: replica count.
        seed: base seed; replica i uses ``seed + i`` so sim-mode RNG and
            oracle-probe noise streams are independent across replicas.
        predictor_factory: optional ``f(replica_index) -> PredictorBase``;
            default gives each replica its own oracle predictor.
        size_predictor: router-side prompt-only size estimator for the
            ``jspw`` policy. Defaults to a fresh `OraclePredictor` on a
            dedicated seed (sim mode's stand-in for the paper's
            prompt-phase probe); pass a `ProbePredictor` in real mode.
        record_events: give each replica a metrics-layer `EventLog`; the
            merged stream lands in ``ClusterStats.event_log``.
        backlog_unit: ``tokens`` | ``seconds`` — see `RouterConfig`.
        faults: optional `FaultSchedule` (or a ``--chaos`` spec via
            `repro.cluster.faults.parse_chaos`) — deterministic replica
            crash / straggler / flaky-submit injection with router
            failover. None (the default) is byte-identical to the
            pre-resilience fault-free path.
        max_retries: per-request failover retry budget (see
            `RouterConfig`).
        prefill_replicas: first ``P`` replicas become a dedicated
            prefill pool (``prefill_only=True`` engines); the rest
            decode. 0 (the default) is the byte-identical colocated
            path. Requires a paged KV layout so finished prefills can
            ship their pages.
        **engine_kwargs: forwarded to `EngineConfig` (policy, c_limit,
            max_batch, mem_budget, kv_layout, predictor, ...). A
            ``predictor`` strategy spec selects every replica's
            length-prediction strategy (each replica builds its own
            instance on its own seed) *and* the router's default
            ``size_predictor``, so dispatch and scheduling see the same
            prediction quality. Rank-only strategies provide no
            magnitudes: the router then uses the raw (prior-based)
            backlog with no truncation.

    Returns:
        The aggregated `ClusterStats`.
    """
    if record_events:
        from repro.metrics.events import EventLog
    replicas = []
    for i in range(n_replicas):
        kw = dict(engine_kwargs)
        if prefill_replicas and i < prefill_replicas:
            kw["prefill_only"] = True
        ecfg = EngineConfig(seed=seed + i, **kw)
        pred = predictor_factory(i) if predictor_factory else None
        replicas.append(Engine(cfg, ecfg, predictor=pred,
                               event_log=EventLog() if record_events
                               else None))
    if size_predictor is None and router_policy in ("jspw",
                                                    "prefix-affinity"):
        spec = engine_kwargs.get("predictor", "")
        if spec:
            from repro.serving.predictors import make_predictor
            cand = make_predictor(spec, cfg.probe, seed=seed + 4242)
            # ordinal scores cannot truncate a token backlog — rank-only
            # routing falls back to the raw prior-based backlog sum
            if getattr(cand, "provides_magnitude", True):
                size_predictor = cand
        else:
            from repro.serving.predictors import OraclePredictor
            size_predictor = OraclePredictor(cfg.probe, seed=seed + 4242)
    router = Router(replicas, RouterConfig(n_replicas=n_replicas,
                                           policy=router_policy, seed=seed,
                                           backlog_unit=backlog_unit,
                                           max_retries=max_retries,
                                           prefill_replicas=prefill_replicas),
                    size_predictor=size_predictor, faults=faults,
                    event_log=EventLog() if record_events else None)
    return router.run(copy.deepcopy(requests))
