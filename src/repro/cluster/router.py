"""Cluster router: dispatch streaming arrivals across N replica engines.

Each replica is a full `Engine` (its own SPRPT-LP scheduler, KV
accounting, and virtual clock) driven through the incremental
``submit()``/``step()`` API. The router runs a virtual-time event loop:

* a pending arrival is dispatched once every busy replica's clock has
  reached its arrival time (so the routing decision observes replica
  state *at* — never before — the arrival);
* otherwise the replica furthest behind in virtual time executes one
  engine step, advancing the cluster frontier.

Dispatch policies (``RouterConfig.policy``):

* ``round-robin`` — cyclic, state-blind (the baseline).
* ``jsq``         — join-shortest-queue by unfinished request count.
* ``pow2``        — power-of-two-choices: sample two replicas, join the
                    shorter queue (Mitzenmacher's classic load balancer).
* ``jspw``        — join-shortest-predicted-work over each replica's live
                    TRAIL per-token remaining-length predictions plus
                    remaining prefill work (`Engine.backlog`). This is the
                    paper's probe signal lifted to the cluster layer (cf.
                    proxy-model routing, arXiv:2404.08509). Because every
                    replica schedules with SPRPT internally, longer jobs
                    yield to a new arrival — so when the router has a
                    ``size_predictor`` (prompt-only r0 estimate, the
                    paper's BERT/probe signal), each replica's predictions
                    are truncated at the arrival's own size estimate:
                    join-shortest *interfering* predicted work. Without a
                    size predictor the raw backlog sum is used (the
                    FCFS-replica signal). Backlog ties break on KV
                    headroom (`Engine.kv_headroom`): a replica near its
                    memory budget pays future preemptions for every
                    long-context request it accepts.
* ``prefix-affinity`` — join the replica whose KV prefix cache holds the
                    longest prefix of the arrival's prompt
                    (`Engine.cached_prefix_tokens`): the linked pages
                    skip prefill compute entirely and shrink the shared
                    footprint. Replicas tying on affinity (including the
                    0-hit case) fall back to the full ``jspw`` rule, so
                    with prefix caching disabled the policy degrades to
                    exactly ``jspw``.
"""

from __future__ import annotations

import copy
import random
from dataclasses import dataclass, field

import numpy as np

from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request

#: Dispatch policies understood by `Router`.
ROUTER_POLICIES = ("round-robin", "jsq", "pow2", "jspw", "prefix-affinity")


@dataclass
class RouterConfig:
    """Cluster-level knobs.

    Attributes:
        n_replicas: number of replica engines.
        policy: dispatch policy — one of `ROUTER_POLICIES`.
        seed: RNG seed for the ``pow2`` replica sampler (dispatch is
            deterministic given the seed and the arrival stream).
        backlog_unit: ``tokens`` (raw predicted-token backlog, the
            default) | ``seconds`` (tokens ÷ each replica's
            `CostModel.decode_token_rate`, via `Engine.backlog_seconds`).
            Seconds is the unit that stays meaningful once replicas run
            on heterogeneous hardware; with identical replicas the two
            units rank identically, so `jspw` dispatch is unchanged.
    """

    n_replicas: int = 2
    policy: str = "round-robin"
    seed: int = 0
    backlog_unit: str = "tokens"


@dataclass
class ClusterStats:
    """Aggregated results of one cluster run.

    Attributes:
        latencies: completion times (finish - arrival) across all replicas.
        ttfts: time-to-first-token across all replicas.
        dispatch_counts: requests dispatched per replica.
        replica_summaries: each replica's `EngineStats.summary()` dict.
        makespan: max replica virtual clock at drain.
        event_log: the replicas' metrics-layer event streams merged into
            one time-ordered `repro.metrics.EventLog` (None unless the
            replicas were built with event logs). Feed it to
            `repro.metrics.rollup` for cluster-wide TTFT/TBT/completion
            percentiles and SLO attainment.
    """

    latencies: list = field(default_factory=list)
    ttfts: list = field(default_factory=list)
    dispatch_counts: list = field(default_factory=list)
    replica_summaries: list = field(default_factory=list)
    makespan: float = 0.0
    event_log: object = None

    def summary(self) -> dict:
        """Aggregate cluster metrics into the benchmark-facing dict."""
        lat = sorted(self.latencies)
        tt = sorted(self.ttfts)
        return {
            "mean_latency": float(np.mean(lat)) if lat else 0.0,
            "median_latency": lat[len(lat) // 2] if lat else 0.0,
            "p99_latency": lat[int(len(lat) * 0.99)] if lat else 0.0,
            "mean_ttft": float(np.mean(tt)) if tt else 0.0,
            "median_ttft": tt[len(tt) // 2] if tt else 0.0,
            "finished": len(lat),
            "dispatch_counts": list(self.dispatch_counts),
            "preemptions": sum(s["preemptions"]
                               for s in self.replica_summaries),
            "peak_batch": max((s["peak_batch"]
                               for s in self.replica_summaries), default=0),
            "prefilled_tokens": sum(s.get("prefilled_tokens", 0)
                                    for s in self.replica_summaries),
            "prefix_hit_tokens": sum(s.get("prefix_hit_tokens", 0)
                                     for s in self.replica_summaries),
            "predictor_time_s": sum(s.get("predictor_time_s", 0.0)
                                    for s in self.replica_summaries),
            "predictor_calls": sum(s.get("predictor_calls", 0)
                                   for s in self.replica_summaries),
            "makespan": self.makespan,
        }


class Router:
    """Dispatches a request stream across replica engines in virtual time.

    The router owns nothing about scheduling *within* a replica — that is
    the engine's SPRPT-LP job. It only decides *which* replica an arrival
    joins, then keeps all replica clocks loosely synchronized by always
    stepping the laggard.
    """

    def __init__(self, replicas: list[Engine], rc: RouterConfig,
                 size_predictor=None):
        """Wrap pre-built replica engines under one dispatch policy.

        Args:
            replicas: the engines (length must equal ``rc.n_replicas``).
            rc: cluster-level configuration.
            size_predictor: optional predictor whose ``initial(req)``
                gives a prompt-only output-length estimate for ``jspw``
                truncation (see module docstring). It must be a separate
                instance from any replica's predictor so router draws
                never perturb engine prediction streams.
        """
        if rc.policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {rc.policy!r}; "
                             f"choose from {ROUTER_POLICIES}")
        if rc.backlog_unit not in ("tokens", "seconds"):
            raise ValueError(f"unknown backlog_unit {rc.backlog_unit!r}; "
                             "choose 'tokens' or 'seconds'")
        if len(replicas) != rc.n_replicas:
            raise ValueError(f"{len(replicas)} replicas != "
                             f"n_replicas={rc.n_replicas}")
        self.replicas = replicas
        self.rc = rc
        self.size_predictor = size_predictor
        self._rr_next = 0
        self._rng = random.Random(rc.seed)
        self.dispatch_counts = [0] * rc.n_replicas
        self.dispatch_log: list[tuple[int, int]] = []   # (rid, replica)

    # -- dispatch policies ------------------------------------------------
    def _queue_key(self, i: int) -> tuple:
        return (self.replicas[i].queue_len(), i)

    def _pick(self, req: Request) -> int:
        """Choose the replica index for one arrival (policy decision)."""
        pol = self.rc.policy
        n = len(self.replicas)
        if pol == "round-robin":
            i = self._rr_next
            self._rr_next = (self._rr_next + 1) % n
            return i
        if pol == "jsq":
            return min(range(n), key=self._queue_key)
        if pol == "pow2":
            if n == 1:
                return 0
            a, b = self._rng.sample(range(n), 2)
            return min(a, b, key=self._queue_key)
        # the size estimate is drawn once per dispatch (predictor streams
        # are stateful), shared by every replica's key below
        r_hat = (self.size_predictor.initial(req)
                 if self.size_predictor is not None else None)
        if pol == "prefix-affinity":
            # longest cached prompt prefix wins; ties (notably 0-hit
            # everywhere, or caching disabled) fall back to jspw
            hits = [self.replicas[i].cached_prefix_tokens(req.prompt)
                    for i in range(n)]
            best = max(hits)
            cands = [i for i in range(n) if hits[i] == best]
            return min(cands, key=lambda i: self._jspw_key(i, r_hat))
        # jspw: live predicted-work backlog — truncated at the arrival's
        # own size estimate when available (SRPT-interfering work) — with
        # KV headroom, queue length, then index as tie-breaks
        return min(range(n), key=lambda i: self._jspw_key(i, r_hat))

    def _jspw_key(self, i: int, r_hat: float | None) -> tuple:
        """The jspw ordering for one replica: predicted interfering work
        (in ``rc.backlog_unit`` units — estimated seconds divide tokens
        by the replica's own service rate, the heterogeneous-hardware
        form), then (on ties) most KV headroom, shortest queue, lowest
        index."""
        eng = self.replicas[i]
        work = (eng.backlog_seconds(truncate=r_hat)
                if self.rc.backlog_unit == "seconds"
                else eng.backlog(truncate=r_hat))
        return (work, -eng.kv_headroom(), eng.queue_len(), i)

    def dispatch(self, req: Request) -> int:
        """Route one arrival to a replica and submit it there."""
        i = self._pick(req)
        self.replicas[i].submit(req)
        self.dispatch_counts[i] += 1
        self.dispatch_log.append((req.rid, i))
        return i

    # -- virtual-time event loop ------------------------------------------
    def run(self, requests: list[Request]) -> ClusterStats:
        """Drive the whole arrival stream to completion.

        Arrivals are consumed in time order; between dispatches, the busy
        replica with the smallest virtual clock steps. The loop ends when
        every replica is drained.
        """
        pending = sorted(requests, key=lambda r: r.arrival)
        q = 0
        while True:
            busy = [e for e in self.replicas if e.has_work()]
            if q < len(pending):
                t_arr = pending[q].arrival
                frontier = min((e.now for e in busy), default=t_arr)
                if t_arr <= frontier:
                    self.dispatch(pending[q])
                    q += 1
                    continue
            if not busy:
                break
            lag = min(busy, key=lambda e: e.now)
            lag.step()

        stats = ClusterStats(dispatch_counts=list(self.dispatch_counts))
        for eng in self.replicas:
            stats.latencies.extend(eng.stats.latencies)
            stats.ttfts.extend(eng.stats.ttfts)
            stats.replica_summaries.append(eng.stats.summary())
            stats.makespan = max(stats.makespan, eng.now)
        stats.event_log = self.merged_event_log()
        return stats

    def merged_event_log(self):
        """Merge the replicas' event logs into one time-ordered log.

        Returns None when no replica records events. Per-request event
        ordering survives the merge because each request lives on
        exactly one replica. Delegates to ``EventLog.merge_all`` — one
        concatenate-and-sort over all replicas instead of a re-sort per
        pairwise merge, with the merge key defined in exactly one place.
        """
        logs = [eng.events for eng in self.replicas
                if getattr(eng, "events", None) is not None]
        if not logs:
            return None
        from repro.metrics.events import EventLog
        return EventLog.merge_all(logs)


def run_cluster(cfg, requests, *, router_policy: str = "round-robin",
                n_replicas: int = 2, seed: int = 0,
                predictor_factory=None, size_predictor=None,
                record_events: bool = False,
                backlog_unit: str = "tokens",
                **engine_kwargs) -> ClusterStats:
    """Serve ``requests`` on an N-replica cluster (the `run_policy` twin).

    Args:
        cfg: the `ModelConfig` every replica serves.
        requests: the shared arrival stream (deep-copied, as in
            ``run_policy``).
        router_policy: one of `ROUTER_POLICIES`.
        n_replicas: replica count.
        seed: base seed; replica i uses ``seed + i`` so sim-mode RNG and
            oracle-probe noise streams are independent across replicas.
        predictor_factory: optional ``f(replica_index) -> PredictorBase``;
            default gives each replica its own oracle predictor.
        size_predictor: router-side prompt-only size estimator for the
            ``jspw`` policy. Defaults to a fresh `OraclePredictor` on a
            dedicated seed (sim mode's stand-in for the paper's
            prompt-phase probe); pass a `ProbePredictor` in real mode.
        record_events: give each replica a metrics-layer `EventLog`; the
            merged stream lands in ``ClusterStats.event_log``.
        backlog_unit: ``tokens`` | ``seconds`` — see `RouterConfig`.
        **engine_kwargs: forwarded to `EngineConfig` (policy, c_limit,
            max_batch, mem_budget, kv_layout, predictor, ...). A
            ``predictor`` strategy spec selects every replica's
            length-prediction strategy (each replica builds its own
            instance on its own seed) *and* the router's default
            ``size_predictor``, so dispatch and scheduling see the same
            prediction quality. Rank-only strategies provide no
            magnitudes: the router then uses the raw (prior-based)
            backlog with no truncation.

    Returns:
        The aggregated `ClusterStats`.
    """
    if record_events:
        from repro.metrics.events import EventLog
    replicas = []
    for i in range(n_replicas):
        ecfg = EngineConfig(seed=seed + i, **engine_kwargs)
        pred = predictor_factory(i) if predictor_factory else None
        replicas.append(Engine(cfg, ecfg, predictor=pred,
                               event_log=EventLog() if record_events
                               else None))
    if size_predictor is None and router_policy in ("jspw",
                                                    "prefix-affinity"):
        spec = engine_kwargs.get("predictor", "")
        if spec:
            from repro.serving.predictors import make_predictor
            cand = make_predictor(spec, cfg.probe, seed=seed + 4242)
            # ordinal scores cannot truncate a token backlog — rank-only
            # routing falls back to the raw prior-based backlog sum
            if getattr(cand, "provides_magnitude", True):
                size_predictor = cand
        else:
            from repro.serving.predictors import OraclePredictor
            size_predictor = OraclePredictor(cfg.probe, seed=seed + 4242)
    router = Router(replicas, RouterConfig(n_replicas=n_replicas,
                                           policy=router_policy, seed=seed,
                                           backlog_unit=backlog_unit),
                    size_predictor=size_predictor)
    return router.run(copy.deepcopy(requests))
