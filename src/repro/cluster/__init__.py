"""Multi-replica cluster serving: a router over N engines.

A `Router` dispatches a shared arrival stream across N independent
`Engine` replicas in virtual time. The paper evaluates SPRPT-LP on a single instance; its companion work
(Mitzenmacher & Shahout, arXiv:2503.07545) frames prediction-based
scheduling as a multi-server queueing problem. This package supplies the
multi-server half: `Router` (dispatch policies, including
join-shortest-predicted-work over live TRAIL predictions) and
`run_cluster` (the `run_policy` analogue for N replicas).
"""

from repro.cluster.router import (ROUTER_POLICIES, ClusterStats, Router,
                                  RouterConfig, run_cluster)

__all__ = ["ROUTER_POLICIES", "ClusterStats", "Router", "RouterConfig",
           "run_cluster"]
