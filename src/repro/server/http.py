"""Minimal HTTP/1.1 + SSE wire helpers (stdlib-only, one request/conn).

Deliberately not a general web server: just enough protocol for the
front door. One request per connection (``Connection: close``), bounded
header and body sizes, JSON responses, and server-sent-event framing for
token streams. Anything malformed raises `HttpError`, which carries the
status code the handler should answer with — invalid input is a 4xx,
never a traceback on the wire.
"""

from __future__ import annotations

import asyncio
import json

MAX_BODY_BYTES = 1 << 20     # 1 MiB JSON bodies are already absurd here

_REASON = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 413: "Payload Too Large",
    429: "Too Many Requests", 500: "Internal Server Error",
}


class HttpError(Exception):
    """Protocol or validation failure with the status code to send."""

    def __init__(self, status: int, detail: str):
        super().__init__(detail)
        self.status = status
        self.detail = detail


async def read_request(reader) -> tuple[str, str, dict, bytes] | None:
    """Parse one HTTP request; ``None`` on a clean EOF before any bytes.

    Returns ``(method, path, headers, body)`` with header names
    lower-cased and the path stripped of any query string. Raises
    `HttpError` on malformed framing or oversized payloads (the
    stream-reader limit bounds the header block).
    """
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None
        raise HttpError(400, "truncated request head") from None
    except asyncio.LimitOverrunError:
        raise HttpError(400, "request head too large") from None
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
        raise HttpError(400, f"bad request line {lines[0]!r}")
    method, target = parts[0].upper(), parts[1]
    headers: dict[str, str] = {}
    for line in lines[1:]:
        if not line:
            continue
        name, sep, value = line.partition(":")
        if not sep:
            raise HttpError(400, f"bad header line {line!r}")
        headers[name.strip().lower()] = value.strip()
    length = headers.get("content-length", "0")
    if not length.isdigit():
        raise HttpError(400, f"bad Content-Length {length!r}")
    n = int(length)
    if n > MAX_BODY_BYTES:
        raise HttpError(413, f"body of {n} bytes exceeds {MAX_BODY_BYTES}")
    body = await reader.readexactly(n) if n else b""
    return method, target.split("?", 1)[0], headers, body


def response(status: int, payload: dict, *,
             extra: dict | None = None) -> bytes:
    """Build one complete JSON response (headers + body) as bytes."""
    body = json.dumps(payload, sort_keys=True).encode()
    lines = [f"HTTP/1.1 {status} {_REASON.get(status, 'Unknown')}",
             "Content-Type: application/json",
             f"Content-Length: {len(body)}",
             "Connection: close"]
    for name, value in (extra or {}).items():
        lines.append(f"{name}: {value}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode() + body


def sse_preamble() -> bytes:
    """Start a streaming response: SSE headers, no Content-Length."""
    return (b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-store\r\n"
            b"Connection: close\r\n\r\n")


def sse_event(payload: dict) -> bytes:
    """Frame one SSE chunk: ``data: <json>`` plus the blank-line end."""
    return b"data: " + json.dumps(payload, sort_keys=True).encode() + b"\n\n"
