"""The serving front door: admit continuously, stream tokens, shed load.

Request lifecycle (docs/ARCHITECTURE.md has the long-form version):

1. a socket delivers ``POST /v1/generate`` (JSON: token ids or lengths);
2. the door checks the predicted-work admission watermark — over it, the
   answer is ``429`` with a ``Retry-After`` derived from
   ``Engine.backlog_seconds()``;
3. otherwise the request is stamped with the current virtual time and
   handed to ``Engine.submit()``; a background task steps the engine
   whenever its clock lags wall time (scaled by ``time_scale``);
4. each megastep's per-request events flow through ``Engine.on_token``
   into the handler's ``asyncio.Queue`` and out as SSE ``data:`` chunks,
   ending with exactly one terminal event (``finish`` | ``timeout`` |
   ``shed`` | ``cancel``);
5. a client that disconnects mid-stream is cancelled inside the engine
   (``Engine.cancel(rid, "cancel")``), releasing its KV footprint.

``GET /healthz`` reports clock/backlog/queue depth; ``GET /metrics``
serves a live `repro.metrics.rollup` of the attached event log.
"""

from __future__ import annotations

import asyncio
import json
import math
from contextlib import suppress
from dataclasses import dataclass
from random import Random

from repro.metrics.rollup import rollup
from repro.server import http
from repro.serving.request import Request
from repro.serving.workload import (
    WorkloadConfig,
    sample_output_length,
    sample_prompt_length,
)

TERMINAL_KINDS = ("finish", "cancel", "timeout", "shed")


@dataclass(frozen=True)
class ServerConfig:
    """Front-door knobs: transport and pacing, never scheduling.

    Engine-side behaviour (policy, watermarks for *shedding*, batch
    shape) stays in `EngineConfig`.

    Attributes:
        host: interface to bind.
        port: TCP port to bind (0 = let the OS pick; see
            ``EngineServer.port`` for the bound value).
        time_scale: virtual seconds the engine clock advances per wall
            second. 1.0 serves in real time; large values time-warp the
            sim clock so tests and smoke runs finish quickly.
        max_tokens_cap: upper bound accepted for ``max_tokens``.
        admit_watermark: predicted-token backlog (``Engine.backlog()``,
            pending included) above which the door answers 429 +
            Retry-After instead of admitting. 0 falls back to the
            engine's ``shed_watermark`` — note the engine also *sheds*
            over that mark, so a dedicated (usually higher) door value
            keeps 429s and sheds distinguishable.
        vocab: vocabulary for synthesizing prompt tokens from
            ``prompt_tokens`` counts.
        seed: seed for the server's prompt/output sampling streams.
    """

    host: str = "127.0.0.1"
    port: int = 8100
    time_scale: float = 1.0
    max_tokens_cap: int = 512
    admit_watermark: float = 0.0
    vocab: int = 32000
    seed: int = 0


def _parse_generate(body: bytes, scfg: ServerConfig) -> dict:
    """Validate a generate body into a plain dict of request fields.

    Accepts ``prompt`` (a token-id list) or ``prompt_tokens`` (a count
    the server synthesizes content for; both absent = server-sampled
    length), plus optional ``max_tokens`` / ``out_tokens`` /
    ``timeout_s`` / ``tenant``. Raises `HttpError` (400) on anything
    malformed, so invalid input never escapes as a traceback.
    """
    try:
        obj = json.loads(body.decode() or "{}")
    except (UnicodeDecodeError, json.JSONDecodeError):
        raise _bad("body is not valid JSON")
    if not isinstance(obj, dict):
        raise _bad("body must be a JSON object")
    prompt = obj.get("prompt")
    if prompt is not None and (not isinstance(prompt, list) or not prompt
                               or not all(isinstance(t, int)
                                          for t in prompt)):
        raise _bad("prompt must be a non-empty list of token ids")
    out: dict = {"prompt": prompt}
    for key, default, lo in (("prompt_tokens", 0, 1),
                             ("max_tokens", 512, 1), ("out_tokens", 0, 1)):
        value = obj.get(key, default)
        if not isinstance(value, int) or (value != default and value < lo):
            raise _bad(f"{key} must be an int >= {lo}")
        out[key] = min(value, scfg.max_tokens_cap) if value else value
    if prompt is not None and obj.get("prompt_tokens"):
        raise _bad("pass prompt or prompt_tokens, not both")
    timeout_s = obj.get("timeout_s", 0.0)
    if not isinstance(timeout_s, (int, float)) or timeout_s < 0:
        raise _bad("timeout_s must be a number >= 0")
    tenant = obj.get("tenant", "")
    if not isinstance(tenant, str):
        raise _bad("tenant must be a string")
    out.update(timeout_s=float(timeout_s), tenant=tenant)
    return out


def _bad(detail: str) -> http.HttpError:
    """Shorthand for the 400 validation error."""
    return http.HttpError(400, detail)


class EngineServer:
    """One engine behind one asyncio TCP listener.

    The caller constructs the engine (policy, watermark, deadlines,
    event log) and hands it over; the server owns the listener, the
    pacing task and the rid counter. Use ``await start()`` then either
    ``await serve_forever()`` (CLI) or keep the loop for tests and
    ``await close()`` when done.
    """

    def __init__(self, engine, scfg: ServerConfig | None = None):
        self.engine = engine
        self.scfg = scfg or ServerConfig()
        self.port = self.scfg.port          # rebound after start()
        self.n_accepted = 0
        self.n_rejected = 0
        self._rid = 0
        self._wake = asyncio.Event()
        self._server = None
        self._task = None
        self._t0 = 0.0
        self._loop = None
        self._wc = WorkloadConfig(
            n_requests=0, request_rate=1.0, vocab=self.scfg.vocab,
            seed=self.scfg.seed)
        self._len_rng = Random(f"{self.scfg.seed}:server:lens")
        self._content_rng = Random(f"{self.scfg.seed}:server:content")

    # -- lifecycle -----------------------------------------------------
    async def start(self):
        """Bind the listener and launch the engine pacing task."""
        self._loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(
            self._handle, self.scfg.host, self.scfg.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._t0 = self._loop.time()
        self._task = self._loop.create_task(self._drive())

    async def serve_forever(self):
        """Serve until cancelled (the ``--serve`` CLI path)."""
        async with self._server:
            await self._server.serve_forever()

    async def close(self):
        """Stop the pacing task and close the listener."""
        if self._task is not None:
            self._task.cancel()
            with suppress(asyncio.CancelledError):
                await self._task
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    def vnow(self) -> float:
        """Wall time since start, scaled onto the engine's clock."""
        return (self._loop.time() - self._t0) * self.scfg.time_scale

    async def _drive(self):
        """Step the engine whenever its clock lags (scaled) wall time.

        Idle engines park on an event set by each accepted request, so
        an empty server burns no CPU; a busy engine megasteps as fast as
        the pacing allows and yields between steps so handler coroutines
        can flush their queues onto the sockets.
        """
        eng = self.engine
        scale = self.scfg.time_scale
        while True:
            if not eng.has_work():
                self._wake.clear()
                await self._wake.wait()
                continue
            lag = eng.now - self.vnow()
            if lag > 0:
                await asyncio.sleep(min(lag / scale, 0.05))
                continue
            eng.step()
            await asyncio.sleep(0)

    # -- request handling ----------------------------------------------
    async def _handle(self, reader, writer):
        """Serve one connection: route, answer, close."""
        try:
            try:
                parsed = await http.read_request(reader)
            except http.HttpError as e:
                writer.write(http.response(e.status, {"error": e.detail}))
                await writer.drain()
                return
            if parsed is None:
                return
            method, path, _headers, body = parsed
            if method == "GET" and path == "/healthz":
                writer.write(http.response(200, self._health()))
                await writer.drain()
            elif method == "GET" and path == "/metrics":
                writer.write(http.response(200, self._metrics()))
                await writer.drain()
            elif method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, body)
            else:
                writer.write(http.response(
                    404, {"error": f"no route {method} {path}"}))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            with suppress(Exception):
                writer.close()
                await writer.wait_closed()

    def _health(self) -> dict:
        """Snapshot for ``GET /healthz``."""
        eng = self.engine
        return {
            "now": round(eng.now, 6), "vnow": round(self.vnow(), 6),
            "backlog_tokens": round(eng.backlog(), 3),
            "queue_len": eng.queue_len(),
            "accepted": self.n_accepted, "rejected_429": self.n_rejected,
        }

    def _metrics(self) -> dict:
        """Live rollup for ``GET /metrics`` (needs an attached log)."""
        if self.engine.events is None:
            return {"error": "engine has no event log attached"}
        return rollup(self.engine.events)

    def _retry_after(self) -> int:
        """Whole wall-seconds a 429'd client should wait before retry."""
        wall = self.engine.backlog_seconds() / self.scfg.time_scale
        return max(1, math.ceil(wall))

    def _materialize(self, spec: dict) -> tuple[list[int], int]:
        """Turn a validated generate spec into (prompt tokens, out len).

        Missing pieces are sampled from the server's seeded streams —
        prompt content for ``prompt_tokens`` requests, and the oracle
        output length (sim mode's synthetic EOS) when the client does
        not pin ``out_tokens``.
        """
        prompt = spec["prompt"]
        if prompt is None:
            n = spec["prompt_tokens"] or sample_prompt_length(
                self._len_rng, self._wc)
            prompt = [self._content_rng.randrange(self.scfg.vocab)
                      for _ in range(n)]
        out_len = spec["out_tokens"] or sample_output_length(
            self._len_rng, self._wc)
        return prompt, out_len

    async def _generate(self, reader, writer, body: bytes):
        """Admit one generate request and stream its events as SSE."""
        eng = self.engine
        try:
            spec = _parse_generate(body, self.scfg)
        except http.HttpError as e:
            writer.write(http.response(e.status, {"error": e.detail}))
            await writer.drain()
            return
        wm = self.scfg.admit_watermark or eng.ecfg.shed_watermark
        if wm > 0 and eng.backlog() > wm:
            retry = self._retry_after()
            self.n_rejected += 1
            writer.write(http.response(
                429, {"error": "overloaded", "retry_after_s": retry},
                extra={"Retry-After": str(retry)}))
            await writer.drain()
            return
        rid, self._rid = self._rid, self._rid + 1
        arrival = max(self.vnow(), eng.now)
        prompt, out_len = self._materialize(spec)
        req = Request(rid, arrival, prompt,
                      max_new_tokens=spec["max_tokens"],
                      true_out_len=out_len, tenant=spec["tenant"],
                      deadline_s=spec["timeout_s"])
        queue: asyncio.Queue = asyncio.Queue()
        eng.on_token(rid, lambda t, kind, v: queue.put_nowait((t, kind, v)))
        eng.submit(req)
        self.n_accepted += 1
        self._wake.set()
        writer.write(http.sse_preamble())
        writer.write(http.sse_event(
            {"event": "accepted", "rid": rid, "t": round(arrival, 6)}))
        await writer.drain()
        eof = self._loop.create_task(self._watch_eof(reader))
        try:
            await self._stream(writer, queue, eof, rid)
        except (ConnectionResetError, BrokenPipeError):
            eng.cancel(rid, "cancel")
        finally:
            eof.cancel()
            with suppress(asyncio.CancelledError):
                await eof
            eng.off_token(rid)

    async def _stream(self, writer, queue, eof, rid: int):
        """Relay queued events to the socket until a terminal kind.

        Watches the connection's read side concurrently: EOF before the
        terminal event means the client went away, which cancels the
        request inside the engine.
        """
        while True:
            get = self._loop.create_task(queue.get())
            done, _ = await asyncio.wait(
                {get, eof}, return_when=asyncio.FIRST_COMPLETED)
            if get not in done:
                get.cancel()
                with suppress(asyncio.CancelledError):
                    await get
                self.engine.cancel(rid, "cancel")
                return
            t, kind, value = get.result()
            payload = {"t": round(t, 6), "event": kind}
            if kind == "tokens":
                payload["n"] = int(value)
            writer.write(http.sse_event(payload))
            await writer.drain()
            if kind in TERMINAL_KINDS:
                return

    @staticmethod
    async def _watch_eof(reader):
        """Resolve once the peer half-closes (ignores stray bytes)."""
        while True:
            chunk = await reader.read(4096)
            if not chunk:
                return
