"""Online serving front door: an asyncio HTTP/SSE server over the engine.

Stdlib-only (``asyncio`` plus a minimal HTTP/1.1 + SSE layer in
`repro.server.http`) — no web framework. `EngineServer` owns one
steppable `Engine`, paces it against wall time (optionally time-warped),
admits socket requests continuously via ``Engine.submit()``, and streams
each request's token events back as SSE chunks through the O(1)
``Engine.on_token`` subscription added for exactly this purpose.
Backpressure reuses the existing machinery: the predicted-work admission
watermark answers 429 + Retry-After at the door, per-request deadlines
become engine timeouts, and a dropped socket flows through
``Engine.cancel()``.
"""

from repro.server.app import EngineServer, ServerConfig

__all__ = ["EngineServer", "ServerConfig"]
