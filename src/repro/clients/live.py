"""Live-socket closed-loop clients for the HTTP/SSE front door.

The wall-clock twin of `repro.clients.pool.run_closed_loop`: the same
`ClientPoolConfig`, the same per-client seeded draw streams, but each
user is an asyncio coroutine speaking real HTTP to a running
`repro.server.EngineServer`. Think times, timeouts and backoffs are
divided by ``time_scale`` so a time-warped server is driven at the
matching wall rate, and recorded times are scaled back onto the virtual
clock so `PoolStats.summary` reads in the same units as the in-process
driver. A 429 answer counts as a ``shed`` failure and is retried after
the server's ``Retry-After`` (still charged against the retry budget);
a client-side timeout drops the connection, which the server turns into
``Engine.cancel()``.
"""

from __future__ import annotations

import asyncio
import json

from repro.clients.pool import (
    ClientPoolConfig,
    ClientRecord,
    PoolStats,
    backoff_s,
    client_rngs,
    pool_workload,
    shared_prefix,
    think_draw,
)
from repro.serving.workload import sample_output_length, sample_prompt_length

TERMINAL_EVENTS = ("finish", "cancel", "timeout", "shed")


async def _read_headers(reader) -> tuple[int, dict]:
    """Read a response's status line and headers (lower-cased names)."""
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split(" ")[1])
    headers: dict[str, str] = {}
    for line in lines[1:]:
        name, sep, value = line.partition(":")
        if sep:
            headers[name.strip().lower()] = value.strip()
    return status, headers


async def _attempt(host: str, port: int, payload: dict, rec: ClientRecord,
                   clock) -> tuple[str, float]:
    """Run one generate attempt; returns (terminal kind, retry_after_s).

    ``clock()`` maps wall time onto the recording clock. Streams SSE
    events into ``rec`` until the terminal event; a 429 returns
    ``("shed", retry_after_s)`` without touching the record times.
    """
    body = json.dumps(payload).encode()
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Type: application/json\r\n"
                      f"Content-Length: {len(body)}\r\n"
                      f"Connection: close\r\n\r\n").encode() + body)
        await writer.drain()
        status, headers = await _read_headers(reader)
        if status == 429:
            return "shed", float(headers.get("retry-after", "1"))
        if status != 200:
            return "cancel", 0.0
        while True:
            line = await reader.readline()
            if not line:
                return "cancel", 0.0          # server went away mid-stream
            line = line.strip()
            if not line.startswith(b"data: "):
                continue
            event = json.loads(line[len(b"data: "):])
            kind = event.get("event", "")
            if kind == "first_token":
                rec.t_first_token = clock()
            elif kind == "tokens":
                rec.tokens += int(event.get("n", 0))
            if kind in TERMINAL_EVENTS:
                return kind, 0.0
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass


async def run_live_pool(host: str, port: int, cfg: ClientPoolConfig, *,
                        time_scale: float = 1.0) -> PoolStats:
    """Drive a live front door with ``cfg.n_clients`` socket users.

    Returns the same `PoolStats` shape as the in-process driver, with
    record times in virtual seconds (wall elapsed × ``time_scale``).
    Wall-clock scheduling makes this driver non-deterministic — it is
    the integration/smoke path, not the benchmark path.
    """
    stats = PoolStats()
    wc = pool_workload(cfg)
    prefix = shared_prefix(cfg)
    loop = asyncio.get_running_loop()
    t0 = loop.time()

    def clock() -> float:
        return (loop.time() - t0) * time_scale

    async def one_user(c: int) -> None:
        think_rng, len_rng, content_rng = client_rngs(cfg, c)
        for turn in range(cfg.requests_per_client):
            await asyncio.sleep(think_draw(cfg, think_rng, turn)
                                / time_scale)
            p_len = sample_prompt_length(len_rng, wc)
            out_len = sample_output_length(len_rng, wc)
            body = [content_rng.randrange(cfg.vocab) for _ in range(p_len)]
            payload = {"prompt": prefix + body, "out_tokens": out_len,
                       "max_tokens": cfg.max_new_tokens,
                       "timeout_s": cfg.timeout_s, "tenant": f"c{c}"}
            rec = ClientRecord(client=c, turn=turn, rid=-1,
                               t_first_issue=clock())
            stats.records.append(rec)
            await _one_request(c, payload, rec)

    async def _one_request(c: int, payload: dict, rec: ClientRecord):
        attempt = 0
        while True:
            rec.t_issue, rec.t_first_token, rec.tokens = clock(), -1.0, 0
            try:
                coro = _attempt(host, port, payload, rec, clock)
                if cfg.timeout_s > 0:
                    kind, retry_after = await asyncio.wait_for(
                        coro, cfg.timeout_s / time_scale)
                else:
                    kind, retry_after = await coro
            except asyncio.TimeoutError:
                kind, retry_after = "timeout", 0.0
            except OSError:
                kind, retry_after = "cancel", 0.0
            if kind == "finish":
                rec.outcome, rec.t_done = "finish", clock()
                return
            stats.failures[kind] = stats.failures.get(kind, 0) + 1
            rec.fail_kind = kind
            if attempt >= cfg.max_retries:
                rec.outcome, rec.t_done = "lost", clock()
                return
            attempt += 1
            rec.retries = attempt
            wait = max(backoff_s(cfg, attempt), retry_after)
            await asyncio.sleep(wait / time_scale)

    await asyncio.gather(*(one_user(c) for c in range(cfg.n_clients)))
    stats.makespan = clock()
    return stats
