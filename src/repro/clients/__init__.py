"""Closed-loop client pools: think-time users driving engines or servers.

The open-loop side of the repo (``repro.traces``, the workload
scenarios) fixes every arrival time in advance. This package is the
reactive counterpart the paper's interactive setting implies: each
simulated user issues a request, *waits* for it to finish (or time out,
or be shed), thinks for a while, and only then issues the next — so the
offered load self-throttles with system latency. Two drivers share one
config and one record format: `run_closed_loop` steps an in-process
engine on its virtual clock (deterministic, benchmark-grade), and
`run_live_pool` speaks HTTP/SSE to a live `repro.server` front door
over real sockets (wall-clock, smoke/integration-grade).
"""

from repro.clients.live import run_live_pool
from repro.clients.pool import (
    ClientPoolConfig,
    ClientRecord,
    PoolStats,
    run_closed_loop,
)

__all__ = [
    "ClientPoolConfig",
    "ClientRecord",
    "PoolStats",
    "run_closed_loop",
    "run_live_pool",
]
