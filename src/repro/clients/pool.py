"""Closed-loop client pools over an in-process engine (virtual time).

A pool of ``n_clients`` simulated users runs against one steppable
`Engine` on the engine's own clock, using the same virtual-time loop the
cluster `Router` uses: the next client action is dispatched once the
engine clock reaches it, otherwise the engine takes one megastep. Every
random draw comes from string-seeded per-client streams (the
``workload.py`` convention), so a fixed seed reproduces the run
byte-for-byte — arrival times, lengths, retries and all — which is what
lets ``benchmarks/serve_live.py`` pin its cells.

User model: think (exponential) → issue → wait for a terminal stream
event → repeat. Requests grouped into sessions draw a longer
``session_gap_s`` think time at session boundaries. A request that ends
in ``timeout`` / ``shed`` / ``cancel`` is retried with exponential
backoff while the retry budget lasts; a request that exhausts the budget
is recorded with outcome ``lost`` and the user moves on to their next
turn (so every pool issues exactly ``n_clients * requests_per_client``
logical requests regardless of outcome).
"""

from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field

from repro.serving.request import Request
from repro.serving.workload import (
    WorkloadConfig,
    sample_output_length,
    sample_prompt_length,
)

# a logical request ends in exactly one of these
TERMINAL_OUTCOMES = ("finish", "lost")
# terminal stream-event kinds that count against the retry budget
FAIL_KINDS = ("timeout", "shed", "cancel")


@dataclass(frozen=True)
class ClientPoolConfig:
    """Knobs for one closed-loop pool (shared by both drivers).

    Attributes:
        n_clients: number of concurrent simulated users.
        requests_per_client: logical requests each user issues in total.
        think_time_s: mean exponential think time between a user's
            requests (0 = reissue immediately).
        session_len: requests per session; after each full session the
            user thinks for ``session_gap_s`` instead of
            ``think_time_s``. 0 disables session structure.
        session_gap_s: mean exponential gap between sessions.
        timeout_s: per-request completion budget, mapped onto
            ``Request.deadline_s`` (in-process) or the HTTP request
            timeout (live). 0 = no timeout.
        max_retries: attempts allowed *after* the first for a failed
            request; exhaustion records the request as ``lost``.
        retry_backoff_s: base retry backoff, doubling per attempt.
        prefix_len: tokens of a pool-shared system prompt prepended to
            every request (drawn once per pool) — the shared-prefix
            workload the prefix cache serves.
        prompt_mean: lognormal location for prompt lengths (tokens).
        prompt_sigma: lognormal sigma for prompt lengths.
        out_median: lognormal median for output lengths (tokens).
        out_sigma: lognormal sigma for output lengths.
        max_out: output-length clip (the paper's 512-token range).
        max_new_tokens: generation cap stamped on each request.
        vocab: vocabulary for random prompt-token content.
        seed: master seed; every stream derives from it by name.
        rid_base: first request id to assign (offset for multi-pool use).
    """

    n_clients: int = 8
    requests_per_client: int = 4
    think_time_s: float = 2.0
    session_len: int = 0
    session_gap_s: float = 0.0
    timeout_s: float = 0.0
    max_retries: int = 0
    retry_backoff_s: float = 1.0
    prefix_len: int = 0
    prompt_mean: float = 44.0
    prompt_sigma: float = 0.6
    out_median: float = 48.0
    out_sigma: float = 1.0
    max_out: int = 512
    max_new_tokens: int = 512
    vocab: int = 32000
    seed: int = 0
    rid_base: int = 0


@dataclass
class ClientRecord:
    """One logical request as one simulated user experienced it.

    Times are on the driving clock (engine-virtual seconds in-process;
    wall seconds scaled by ``time_scale`` for the live driver). A
    retried request keeps one record: ``t_first_issue`` anchors the
    user-perceived completion, ``t_issue`` is the last attempt.
    """

    client: int
    turn: int
    rid: int
    t_first_issue: float
    t_issue: float = 0.0
    t_first_token: float = -1.0
    t_done: float = -1.0
    tokens: int = 0
    retries: int = 0
    outcome: str = ""
    fail_kind: str = ""

    def ttft(self) -> float:
        """First-token latency of the successful attempt (seconds)."""
        return self.t_first_token - self.t_issue

    def completion(self) -> float:
        """User-perceived completion: finish minus first issue (s)."""
        return self.t_done - self.t_first_issue

    def tbt(self) -> float:
        """Mean time between tokens after the first (seconds)."""
        if self.tokens <= 1 or self.t_first_token < 0 or self.t_done < 0:
            return 0.0
        return (self.t_done - self.t_first_token) / (self.tokens - 1)


def _dist(xs: list[float]) -> dict:
    """Summarize a sample as mean/p50/p90/p99 (nearest-rank, 6 dp)."""
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
    s = sorted(xs)

    def pct(q: float) -> float:
        return s[min(len(s) - 1, int(q / 100.0 * len(s)))]

    return {"mean": round(sum(s) / len(s), 6), "p50": round(pct(50), 6),
            "p90": round(pct(90), 6), "p99": round(pct(99), 6)}


@dataclass
class PoolStats:
    """What one pool run produced: per-request records plus totals."""

    records: list[ClientRecord] = field(default_factory=list)
    failures: dict = field(default_factory=dict)
    makespan: float = 0.0

    def summary(self) -> dict:
        """Roll the records up into a JSON-ready closed-loop summary."""
        recs = self.records
        fin = [r for r in recs if r.outcome == "finish"]
        comp = [r.completion() for r in fin]
        ttfts = [r.ttft() for r in fin if r.t_first_token >= 0]
        tbts = [r.tbt() for r in fin if r.tokens > 1]
        return {
            "issued": len(recs),
            "finished": len(fin),
            "lost": sum(1 for r in recs if r.outcome == "lost"),
            "retries": sum(r.retries for r in recs),
            "failures": {k: self.failures[k] for k in sorted(self.failures)},
            "makespan_s": round(self.makespan, 6),
            "goodput_rps": (round(len(fin) / self.makespan, 6)
                            if self.makespan > 0 else 0.0),
            "completion_s": _dist(comp),
            "ttft_s": _dist(ttfts),
            "tbt_s": _dist(tbts),
        }


def pool_workload(cfg: ClientPoolConfig) -> WorkloadConfig:
    """Build the `WorkloadConfig` view of a pool's length distributions.

    Lets both drivers reuse ``workload.sample_prompt_length`` /
    ``sample_output_length`` so a closed-loop pool draws lengths from
    the same clipped lognormals as the open-loop scenarios.
    """
    return WorkloadConfig(
        n_requests=0, request_rate=1.0, prompt_mean=cfg.prompt_mean,
        prompt_sigma=cfg.prompt_sigma, out_median=cfg.out_median,
        out_sigma=cfg.out_sigma, max_out=cfg.max_out, vocab=cfg.vocab,
        seed=cfg.seed)


def shared_prefix(cfg: ClientPoolConfig) -> list[int]:
    """Draw the pool's shared system-prompt tokens (empty if disabled)."""
    if cfg.prefix_len <= 0:
        return []
    rng = random.Random(f"{cfg.seed}:pool:prefix")
    return [rng.randrange(cfg.vocab) for _ in range(cfg.prefix_len)]


def client_rngs(cfg: ClientPoolConfig, c: int) -> tuple:
    """Per-client (think, lengths, content) streams, seeded by name.

    Each stream is consumed only by its own client in turn order, so the
    draw sequence — hence the whole pool — is invariant under request
    interleaving and identical between the in-process and live drivers.
    """
    return (random.Random(f"{cfg.seed}:client:{c}:think"),
            random.Random(f"{cfg.seed}:client:{c}:lens"),
            random.Random(f"{cfg.seed}:client:{c}:content"))


def think_draw(cfg: ClientPoolConfig, rng: random.Random, turn: int) -> float:
    """Draw the think time before a client's ``turn``-th request.

    Session boundaries (every ``session_len`` turns, including the gap
    before turn 0 of later sessions) draw from ``session_gap_s``.
    """
    mean = cfg.think_time_s
    if (cfg.session_len > 0 and cfg.session_gap_s > 0 and turn > 0
            and turn % cfg.session_len == 0):
        mean = cfg.session_gap_s
    return rng.expovariate(1.0 / mean) if mean > 0 else 0.0


def backoff_s(cfg: ClientPoolConfig, attempt: int) -> float:
    """Exponential backoff before retry ``attempt`` (1-based)."""
    return cfg.retry_backoff_s * (2.0 ** (attempt - 1))


def run_closed_loop(engine, cfg: ClientPoolConfig) -> PoolStats:
    """Drive one engine with a closed-loop pool on its virtual clock.

    Uses `Engine.on_token` for terminal detection (no event-log
    scanning) and the router's dispatch rule: issue the next client
    action once the engine clock reaches it, otherwise megastep. The
    engine must be freshly constructed (or ``_reset_stream()``); the
    caller owns any attached `EventLog`.
    """
    stats = PoolStats()
    wc = pool_workload(cfg)
    prefix = shared_prefix(cfg)
    rngs = [client_rngs(cfg, c) for c in range(cfg.n_clients)]
    heap: list = []   # (t, seq, record) — record.rid < 0 marks a fresh turn
    seq = 0
    next_rid = cfg.rid_base

    def push(t: float, rec: ClientRecord):
        nonlocal seq
        heapq.heappush(heap, (t, seq, rec))
        seq += 1

    def schedule_turn(c: int, turn: int, t_now: float):
        if turn >= cfg.requests_per_client:
            return
        t = t_now + think_draw(cfg, rngs[c][0], turn)
        push(t, ClientRecord(client=c, turn=turn, rid=-1, t_first_issue=t))

    def on_event(t: float, kind: str, value: float, rec: ClientRecord):
        if kind == "first_token":
            rec.t_first_token = t
            return
        if kind == "tokens":
            rec.tokens += int(value)
            return
        if kind == "finish":
            rec.outcome, rec.t_done = "finish", t
            schedule_turn(rec.client, rec.turn + 1, t)
            return
        # timeout / shed / cancel: retry while the budget lasts
        stats.failures[kind] = stats.failures.get(kind, 0) + 1
        rec.fail_kind = kind
        if rec.retries < cfg.max_retries:
            rec.retries += 1
            push(t + backoff_s(cfg, rec.retries), rec)
        else:
            rec.outcome, rec.t_done = "lost", t
            schedule_turn(rec.client, rec.turn + 1, t)

    def issue(t: float, rec: ClientRecord):
        nonlocal next_rid
        c = rec.client
        if rec.rid < 0:                       # first attempt: draw the turn
            _, lens, content = rngs[c]
            p_len = sample_prompt_length(lens, wc)
            rec.tokens = 0
            rec._out_len = sample_output_length(lens, wc)
            rec._body = [content.randrange(cfg.vocab) for _ in range(p_len)]
            stats.records.append(rec)
        rec.rid, next_rid = next_rid, next_rid + 1
        rec.t_issue, rec.t_first_token, rec.tokens = t, -1.0, 0
        req = Request(rec.rid, t, prefix + rec._body,
                      max_new_tokens=cfg.max_new_tokens,
                      true_out_len=rec._out_len, tenant=f"c{c}",
                      deadline_s=cfg.timeout_s)
        engine.on_token(rec.rid,
                        lambda et, kind, v, r=rec: on_event(et, kind, v, r))
        engine.submit(req)

    for c in range(cfg.n_clients):
        schedule_turn(c, 0, 0.0)
    while heap or engine.has_work():
        if heap and (not engine.has_work() or heap[0][0] <= engine.now):
            t, _, rec = heapq.heappop(heap)
            issue(t, rec)
        else:
            engine.step()
    stats.makespan = engine.now
    return stats
