"""Mixture-of-Experts MLP with shard-local sort-based capacity dispatch.

TPU adaptation notes (vs. GPU grouped-GEMM MoE):
  * Static shapes everywhere: tokens route into an (E, C, d) buffer with
    per-shard capacity C = ceil(T_local * k / E * capacity_factor); overflow
    tokens fall back to the identity path (dropped-token semantics).
  * Dispatch is argsort + scatter (no E-times dense compute) and is
    SHARD-LOCAL (§Perf iteration, EXPERIMENTS.md): tokens are viewed as
    (n_shards, T_local, d) with the shard dim pinned to the batch mesh axes,
    so the sort/scatter never crosses devices; expert buffers are explicitly
    hinted (shard dim -> batch axes, expert dim -> "model"), and the expert
    einsum generates the canonical data<->expert exchange.
  * ``moe_mlp_dense`` (one-hot, E-times compute) kept as oracle/ablation.

Router aux loss follows Switch: aux = E * sum_e f_e * p_e.

§Perf A/B: REPRO_MOE_GLOBAL_DISPATCH=1 restores global-token dispatch
(the pre-hillclimb baseline: GSPMD replicates the dispatch buffers on every
device — arctic-480b train_4k measured 240 GB/dev, 191 s collective).
"""

from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.hints import BATCH, data_shards, hint
from repro.models.layers import dense_init, pdtype

_GLOBAL_DISPATCH = os.environ.get("REPRO_MOE_GLOBAL_DISPATCH", "") == "1"


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    """Per-expert token slot budget for ``n_tokens`` routed tokens."""
    cap = math.ceil(n_tokens * cfg.experts_per_token / cfg.num_experts
                    * cfg.capacity_factor)
    return max(cap, 4)


def init_moe(key, cfg: ModelConfig):
    """Initialize router + stacked expert MLP params."""
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    return {
        "router": dense_init(ks[0], d, E, dt, scale=scale),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) * ff ** -0.5).astype(dt),
    }


def _route(cfg: ModelConfig, p, hf):
    """hf: (D,t,d) -> (probs (D,t,k), idx (D,t,k), aux scalar)."""
    logits = hf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs_full = jax.nn.softmax(logits, axis=-1)                  # (D,t,E)
    top_p, top_i = jax.lax.top_k(probs_full, cfg.experts_per_token)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    E = cfg.num_experts
    onehot = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
    f = jnp.mean(onehot, axis=1)                                  # (D,E)
    pbar = jnp.mean(probs_full, axis=1)
    aux = jnp.mean(E * jnp.sum(f * pbar, axis=-1))
    return top_p, top_i, aux


def moe_mlp(cfg: ModelConfig, p, h):
    """h: (B,S,d) -> (out (B,S,d), aux loss scalar)."""
    B, S, d = h.shape
    T = B * S
    shards = 1 if _GLOBAL_DISPATCH else data_shards()
    if shards > 1 and B % shards == 0:
        # NOTE (§Perf, refuted hypothesis): explicitly hinting the dispatch
        # buffers (xe/g/ye -> (BATCH, "model")) was tried and made things
        # 5x WORSE (arctic train 87.6 -> 419 GB/dev, 72 -> 291 s coll):
        # the constraints conflict with the scatter producers and GSPMD
        # inserts full rematerializations. vmap + boundary hints only.
        hf = hint(h.reshape(shards, T // shards, d), BATCH)
        out, aux = jax.vmap(lambda x: _moe_tokens(cfg, p, x))(hf)
        out = hint(out, BATCH)
        return out.reshape(B, S, d), jnp.mean(aux)
    out, aux = _moe_tokens(cfg, p, h.reshape(T, d))
    return out.reshape(B, S, d), aux


def _moe_tokens(cfg: ModelConfig, p, hf):
    """Sort-based dispatch over one token shard. hf: (t,d) -> ((t,d), aux)."""
    t, d = hf.shape
    top_p, top_i, aux = _route(cfg, p, hf[None])
    top_p, top_i = top_p[0], top_i[0]
    k = cfg.experts_per_token
    E = cfg.num_experts
    C = expert_capacity(cfg, t)

    # ---- sort-based dispatch -------------------------------------------------
    flat_e = top_i.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_w = top_p.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_tok[order], flat_w[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k) - starts[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)      # E*C = overflow bin

    buf = jnp.zeros((E * C + 1, d), hf.dtype)
    buf = buf.at[slot].set(hf[st])
    xe = buf[:E * C].reshape(E, C, d)

    # ---- expert compute (batched over E; shards over "model") -----------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(hf.dtype)))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(hf.dtype))
    ye = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(hf.dtype))

    # ---- combine ---------------------------------------------------------------
    yflat = jnp.concatenate([ye.reshape(E * C, d),
                             jnp.zeros((1, d), hf.dtype)], axis=0)
    contrib = (yflat[slot] * sw[:, None].astype(hf.dtype)
               * keep[:, None].astype(hf.dtype))
    out = jnp.zeros((t, d), hf.dtype).at[st].add(contrib)
    return out, aux


def moe_mlp_dense(cfg: ModelConfig, p, h):
    """One-hot dense-dispatch oracle (every expert computes every token,
    compute = E/k times the sparse path). Kept for tests/ablation."""
    B, S, d = h.shape
    T = B * S
    hf = h.reshape(T, d)
    top_p, top_i, aux = _route(cfg, p, hf[None])
    top_p, top_i = top_p[0], top_i[0]
    E = cfg.num_experts
    w = jnp.zeros((T, E), jnp.float32)
    rows = jnp.arange(T)[:, None]
    w = w.at[rows, top_i].set(top_p)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", hf, p["w_gate"].astype(h.dtype)))
    u = jnp.einsum("td,edf->tef", hf, p["w_up"].astype(h.dtype))
    ye = jnp.einsum("tef,efd->ted", g * u, p["w_down"].astype(h.dtype))
    out = jnp.einsum("ted,te->td", ye.astype(jnp.float32), w)
    return out.astype(h.dtype).reshape(B, S, d), aux
