"""Mamba2 SSD (state-space duality) block. [arXiv:2405.21060]

Train/prefill use the *chunked* SSD algorithm (matmul-rich, MXU-friendly —
this is the TPU adaptation of the paper's GPU scan): intra-chunk work is a
masked attention-like matmul, inter-chunk state is a short ``lax.scan`` over
chunks.  Decode is the O(1) recurrent update.

State layout per layer:
  ssm_state: (B, nh, hp, N)    — running SSD state
  conv_buf:  (B, W-1, C_conv)  — last W-1 pre-conv inputs (xBC channels)

The pure-jnp chunked scan here is the reference; ``repro.kernels.ssd_scan``
is the Pallas version with identical semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, pdtype


def ssm_dims(cfg: ModelConfig):
    """Derived SSM sizes: (inner dim, n_heads, conv channels)."""
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    bc = 2 * cfg.ssm_groups * cfg.ssm_state
    conv_ch = d_in + bc
    return d_in, nh, conv_ch


def init_ssm(key, cfg: ModelConfig):
    """Initialize one Mamba-2 style SSM mixer layer's params."""
    d = cfg.d_model
    d_in, nh, conv_ch = ssm_dims(cfg)
    zxbcdt = 2 * d_in + (conv_ch - d_in) + nh
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, zxbcdt, dt),
        "out_proj": dense_init(ks[1], d_in, d, dt, scale=d_in ** -0.5),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, conv_ch), jnp.float32)
                   * cfg.ssm_conv ** -0.5).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.zeros((nh,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(A_log) in (-1, 0]
        "D": jnp.ones((nh,), dt),
        "norm_scale": jnp.zeros((d_in,), dt),
    }


def _split_zxbcdt(cfg: ModelConfig, proj):
    d_in, nh, conv_ch = ssm_dims(cfg)
    bc = conv_ch - d_in
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + conv_ch]
    dt = proj[..., d_in + conv_ch:]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _causal_conv(p, xbc, conv_buf=None):
    """Depthwise causal conv, width W, via W shifted adds.

    xbc: (B,L,C); conv_buf: (B,W-1,C) history or None (zeros).
    Returns (out (B,L,C), new_buf (B,W-1,C)).
    """
    W = p["conv_w"].shape[0]
    B, L, C = xbc.shape
    if conv_buf is None:
        conv_buf = jnp.zeros((B, W - 1, C), xbc.dtype)
    ext = jnp.concatenate([conv_buf.astype(xbc.dtype), xbc], axis=1)  # (B, W-1+L, C)
    out = jnp.zeros((B, L, C), jnp.float32)
    for i in range(W):
        out = out + ext[:, i:i + L, :].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
    out = jax.nn.silu(out + p["conv_b"].astype(jnp.float32)).astype(xbc.dtype)
    new_buf = ext[:, L:, :] if L >= W - 1 else ext[:, -(W - 1):, :]
    return out, new_buf


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD reference. All float32 internally.

    x: (B,L,nh,hp); dt: (B,L,nh) (post-softplus); A: (nh,) negative;
    Bm/Cm: (B,L,N) (groups=1 shared across heads).
    Returns (y (B,L,nh,hp), final_state (B,nh,hp,N)).
    """
    Bsz, L, nh, hp = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    f32 = jnp.float32
    xr = x.reshape(Bsz, nc, chunk, nh, hp).astype(f32)
    dtr = dt.reshape(Bsz, nc, chunk, nh).astype(f32)
    Br = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cr = Cm.reshape(Bsz, nc, chunk, N).astype(f32)

    logdec = dtr * A                                   # (B,nc,Q,nh), <= 0
    cum = jnp.cumsum(logdec, axis=2)                   # inclusive cumsum

    # --- intra-chunk: masked attention-like matmul --------------------------
    CB = jnp.einsum("bctn,bcsn->bcts", Cr, Br)         # (B,nc,Q,Q)
    dec = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])   # (B,nc,t,s,nh)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = CB[..., None] * dec * dtr[:, :, None, :, :]
    M = jnp.where(tri[None, None, :, :, None], M, 0.0)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", M, xr)

    # --- chunk summaries -----------------------------------------------------
    dec_out = jnp.exp(cum[:, :, -1:, :] - cum)         # decay from s to chunk end
    Sc = jnp.einsum("bcsh,bcshp,bcsn->bchpn", dec_out * dtr, xr, Br)
    chunk_dec = jnp.exp(cum[:, :, -1, :])              # (B,nc,nh)

    # --- inter-chunk recurrence ----------------------------------------------
    if init_state is None:
        init_state = jnp.zeros((Bsz, nh, hp, N), f32)

    def step(S_prev, inp):
        """Inter-chunk recurrence: decay and add one chunk's state."""
        cd, Sc_c = inp                                  # (B,nh), (B,nh,hp,N)
        S = cd[:, :, None, None] * S_prev + Sc_c
        return S, S_prev

    xs = (jnp.moveaxis(chunk_dec, 1, 0), jnp.moveaxis(Sc, 1, 0))
    S_fin, S_prevs = jax.lax.scan(step, init_state.astype(f32), xs)
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)               # (B,nc,nh,hp,N)

    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp", Cr, jnp.exp(cum), S_prevs)
    y = (y_intra + y_inter).reshape(Bsz, L, nh, hp)
    return y.astype(x.dtype), S_fin


def ssm_forward(cfg: ModelConfig, p, h, state=None, conv_buf=None):
    """Full-sequence / chunk forward. h: (B,L,d).

    Returns (out (B,L,d), (new_state, new_conv_buf)).
    L must be a multiple of cfg.ssm_chunk (pad upstream if chunking).
    """
    d_in, nh, conv_ch = ssm_dims(cfg)
    N = cfg.ssm_groups * cfg.ssm_state
    hp = cfg.ssm_head_dim
    proj = h @ p["in_proj"].astype(h.dtype)
    z, xbc, dt_raw = _split_zxbcdt(cfg, proj)
    xbc, new_buf = _causal_conv(p, xbc, conv_buf)
    x = xbc[..., :d_in]
    Bm = xbc[..., d_in:d_in + N]
    Cm = xbc[..., d_in + N:]
    B_, L = h.shape[:2]
    x = x.reshape(B_, L, nh, hp)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    chunk = min(cfg.ssm_chunk, L)
    while L % chunk:          # chunk must divide L; fall back to smaller chunks
        chunk //= 2
    y, S_fin = ssd_chunked(x, dt, A, Bm, Cm, chunk, init_state=state)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B_, L, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # gated RMSNorm (mamba2)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * (1.0 + p["norm_scale"].astype(jnp.float32))
    out = y.astype(h.dtype) @ p["out_proj"].astype(h.dtype)
    return out, (S_fin, new_buf)


def ssm_decode_step(cfg: ModelConfig, p, h, state, conv_buf):
    """One-token recurrent update. h: (B,1,d); state: (B,nh,hp,N);
    conv_buf: (B,W-1,C_conv). Returns (out (B,1,d), (state, conv_buf))."""
    d_in, nh, conv_ch = ssm_dims(cfg)
    N = cfg.ssm_groups * cfg.ssm_state
    hp = cfg.ssm_head_dim
    B_ = h.shape[0]
    proj = h @ p["in_proj"].astype(h.dtype)             # (B,1,zxbcdt)
    z, xbc, dt_raw = _split_zxbcdt(cfg, proj)
    xbc_out, new_buf = _causal_conv(p, xbc, conv_buf)
    x = xbc_out[..., :d_in].reshape(B_, nh, hp)
    Bm = xbc_out[:, 0, d_in:d_in + N]                   # (B,N)
    Cm = xbc_out[:, 0, d_in + N:]                       # (B,N)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B,nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                 # (B,nh)
    x32 = x.astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x32, Bm.astype(jnp.float32))
    state = a[:, :, None, None] * state.astype(jnp.float32) + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * x32
    y = y.reshape(B_, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * (1.0 + p["norm_scale"].astype(jnp.float32))
    out = y.astype(h.dtype) @ p["out_proj"].astype(h.dtype)
    return out, (state, new_buf)


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers: int):
    """Zeroed per-layer decode state (SSM state + conv ring buffer)."""
    d_in, nh, conv_ch = ssm_dims(cfg)
    N = cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm_state": jnp.zeros((n_layers, batch, nh, cfg.ssm_head_dim, N), jnp.float32),
        "conv_buf": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
    }
