"""Shared primitives: norms, RoPE, MLPs, inits, softcap.

Parameters are plain nested dicts of jnp arrays; init functions return the
dict, apply functions take (params, inputs). Everything is dtype-disciplined:
params live in ``cfg.param_dtype``, compute happens in ``cfg.dtype`` with
float32 softmax/norm accumulations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def cdtype(cfg: ModelConfig):
    """Compute dtype of the model (``cfg.dtype``)."""
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    """Parameter dtype of the model (``cfg.param_dtype``)."""
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Inits
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    """Gaussian dense init, fan-in scaled unless ``scale`` is given."""
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    """Gaussian embedding-table init at the GPT-2 0.02 scale."""
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype):
    """Zero-init RMSNorm scale (gemma-style ``1 + scale`` gain)."""
    return {"scale": jnp.zeros((d,), dtype)}   # gemma-style (1 + scale)


def rms_norm(p, x, eps: float):
    """RMSNorm with float32 accumulation, cast back to ``x.dtype``."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(d: int, dtype):
    """Standard LayerNorm params (unit scale, zero bias)."""
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p, x, eps: float):
    """LayerNorm with float32 accumulation, cast back to ``x.dtype``."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def init_norm(cfg: ModelConfig, dtype):
    """Family-dispatched norm init (LayerNorm for audio, else RMSNorm)."""
    if cfg.family == "audio":          # whisper uses LayerNorm
        return init_layernorm(cfg.d_model, dtype)
    return init_rmsnorm(cfg.d_model, dtype)


def apply_norm(cfg: ModelConfig, p, x):
    """Family-dispatched norm application matching `init_norm`."""
    if cfg.family == "audio":
        return layer_norm(p, x, cfg.norm_eps)
    return rms_norm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_table(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: (..., P, H, hd); positions broadcastable to (..., P)."""
    hd = x.shape[-1]
    cos, sin = rope_table(positions, hd, theta)       # (..., P, hd/2)
    cos = cos[..., None, :]                            # (..., P, 1, hd/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated SiLU for llama-likes, GELU for whisper)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    """MLP params: biased up/down for audio, gated SiLU otherwise."""
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = pdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.family == "audio":
        return {"w_up": dense_init(k1, d, ff, dt),
                "b_up": jnp.zeros((ff,), dt),
                "w_down": dense_init(k2, ff, d, dt, scale=ff ** -0.5),
                "b_down": jnp.zeros((d,), dt)}
    return {"w_gate": dense_init(k1, d, ff, dt),
            "w_up": dense_init(k2, d, ff, dt),
            "w_down": dense_init(k3, ff, d, dt, scale=ff ** -0.5)}


def apply_mlp(cfg: ModelConfig, p, x):
    """Apply the MLP whose param layout `init_mlp` produced."""
    if "w_gate" not in p:
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)
    g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    return (g * u) @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(x, cap: float):
    """Soft-cap logits to (-cap, cap) via tanh; ``cap=0`` is identity."""
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def unembed(cfg: ModelConfig, params, h):
    """h: (..., d) -> logits (..., V), with optional final softcap."""
    w = params.get("unembed", params["embed"])
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32).T
    return softcap(logits, cfg.final_logit_softcap)


def embed_tokens(cfg: ModelConfig, params, tokens):
    """Look up token embeddings, optionally sqrt(d_model)-scaled."""
    h = params["embed"][tokens].astype(cdtype(cfg))
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h
