"""Per-layer blocks (dense / local / moe / ssm / hybrid) + run utilities.

A "run" is a maximal stretch of layers of identical kind (split additionally
at the probe tap boundary so the tap is always a run boundary). Each run's
parameters are stacked along a leading axis and executed with ``lax.scan``,
which keeps HLO size flat in depth for 40–64-layer configs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import (KIND_ATTN, KIND_HYBRID, KIND_LOCAL, KIND_MOE,
                          KIND_SSM, ModelConfig)
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_norm, init_mlp, init_norm, apply_mlp


# ---------------------------------------------------------------------------
# Run computation
# ---------------------------------------------------------------------------

MAX_PATTERN = 8          # longest repeating block we scan as one step


def _segment_runs(kinds: tuple[str, ...]) -> list[tuple[tuple[str, ...], int]]:
    """Greedy periodic decomposition of one segment.

    Returns runs of (pattern_kinds, n_blocks): a run executes
    ``pattern_kinds`` n_blocks times via one lax.scan (alternating-layer
    archs like gemma2's LGLG... become 21 two-layer blocks instead of 42
    unrolled layers — compile time stays flat in depth).
    """
    runs: list[tuple[tuple[str, ...], int]] = []
    i = 0
    n = len(kinds)
    while i < n:
        best_p, best_cover = 1, 1
        for p in range(1, min(MAX_PATTERN, n - i) + 1):
            pat = kinds[i:i + p]
            nb = 1
            while kinds[i + nb * p:i + (nb + 1) * p] == pat:
                nb += 1
            cover = nb * p
            # multi-layer patterns must actually repeat, else p=1 runs win
            if cover > best_cover and (p == 1 or nb >= 2):
                best_p, best_cover = p, cover
        nb = best_cover // best_p
        runs.append((tuple(kinds[i:i + best_p]), nb))
        i += best_cover
    return runs


def split_runs(cfg: ModelConfig) -> tuple[tuple[tuple[str, ...], int], ...]:
    """Periodic-pattern runs, split so tap_layer ends a segment."""
    tap = cfg.probe.tap_layer
    seg1 = cfg.layer_kinds[:tap + 1]
    seg2 = cfg.layer_kinds[tap + 1:]
    runs = _segment_runs(seg1)
    if seg2:
        runs += _segment_runs(seg2)
    return tuple(runs)


def run_layers(run) -> int:
    """Total layer count of one (kinds, n_blocks) run."""
    kinds, nb = run
    return len(kinds) * nb


def tap_run_index(cfg: ModelConfig) -> int:
    """Index of the run whose last layer is the probe tap."""
    runs = split_runs(cfg)
    n = 0
    for ri, run in enumerate(runs):
        n += run_layers(run)
        if n - 1 >= cfg.probe.tap_layer:
            return ri
    return len(runs) - 1


# ---------------------------------------------------------------------------
# Single-layer init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str, cross: bool = False):
    """Initialize one decoder block of the given layer kind."""
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    p: dict = {"norm1": init_norm(cfg, jnp.dtype(dt))}
    if kind == KIND_SSM:
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
        return p
    p["attn"] = attn.init_attention(ks[0], cfg)
    p["norm2"] = init_norm(cfg, jnp.dtype(dt))
    if kind == KIND_MOE:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
        if cfg.moe_dense_residual:
            p["mlp"] = init_mlp(ks[2], cfg)
    elif kind == KIND_HYBRID:
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg)
        p["mlp"] = init_mlp(ks[2], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    if cross:
        p["cross"] = attn.init_attention(ks[3], cfg)
        p["norm_cross"] = init_norm(cfg, jnp.dtype(dt))
    return p


def _kind_window(cfg: ModelConfig, kind: str) -> int:
    if kind == KIND_LOCAL:
        return cfg.sliding_window
    if kind == KIND_HYBRID:
        return cfg.sliding_window    # hymba SWA attention heads
    return 0


def _mlp_part(cfg: ModelConfig, kind: str, p, h):
    """Post-attention feed-forward (dense MLP / MoE / none). Returns (delta, aux)."""
    if kind == KIND_MOE:
        y, aux = moe_mod.moe_mlp(cfg, p["moe"], apply_norm(cfg, p["norm2"], h))
        if cfg.moe_dense_residual:
            y = y + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], h))
        return y, aux
    return apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], h)), jnp.float32(0)


def block_train(cfg: ModelConfig, kind: str, p, h, enc_out=None, positions=None):
    """Training-path block (no cache). Returns (h, aux)."""
    hn = apply_norm(cfg, p["norm1"], h)
    if kind == KIND_SSM:
        y, _ = ssm_mod.ssm_forward(cfg, p["ssm"], hn)
        return h + y, jnp.float32(0)
    window = _kind_window(cfg, kind)
    a = attn.self_attention_full(cfg, p["attn"], hn, window=window,
                                 positions=positions)
    if kind == KIND_HYBRID:
        s, _ = ssm_mod.ssm_forward(cfg, p["ssm"], hn)
        a = 0.5 * (a + s)
    h = h + a
    if enc_out is not None and "cross" in p:
        ck, cv = attn.cross_kv(cfg, p["cross"], enc_out)
        h = h + attn.cross_attention(cfg, p["cross"],
                                     apply_norm(cfg, p["norm_cross"], h),
                                     ck, cv)
    y, aux = _mlp_part(cfg, kind, p, h)
    return h + y, aux


def block_cached(cfg: ModelConfig, kind: str, p, h, cache_l, q_pos,
                 decode: bool = False, block_table=None,
                 use_kernels: bool = False):
    """Cached-path block (prefill chunk or decode). Returns (h, cache_l, aux).

    h: (B,S,d); q_pos: (B,S) absolute positions (-1 = inactive slot).
    ``block_table`` (B, pmax) routes K/V through the shared page pool when
    this run's cache is paged (pk/pv/pkpos leaves). ``use_kernels`` swaps
    the paged gather+attend reference for the Pallas flash-decode kernels
    (single-query for decode, multi-query for prefill chunks).
    """
    hn = apply_norm(cfg, p["norm1"], h)
    new_cache = dict(cache_l)
    if kind == KIND_SSM:
        if decode:
            y, (st, cb) = ssm_mod.ssm_decode_step(
                cfg, p["ssm"], hn, cache_l["ssm_state"], cache_l["conv_buf"])
        else:
            y, (st, cb) = ssm_mod.ssm_forward(
                cfg, p["ssm"], hn, state=cache_l["ssm_state"],
                conv_buf=cache_l["conv_buf"])
        new_cache["ssm_state"], new_cache["conv_buf"] = st, cb
        return h + y, new_cache, jnp.float32(0)

    window = _kind_window(cfg, kind)
    if "pk" in cache_l:
        kvcache = {k: cache_l[k] for k in ("pk", "pv", "pkpos")}
        a, kv_new = attn.self_attention_paged(cfg, p["attn"], hn, kvcache,
                                              q_pos, block_table,
                                              use_kernels=use_kernels)
    else:
        kv_keys = ("k", "v", "kpos", "k_scale", "v_scale")
        kvcache = {k: cache_l[k] for k in kv_keys if k in cache_l}
        a, kv_new = attn.self_attention_cached(cfg, p["attn"], hn, kvcache,
                                               q_pos, window=window)
    new_cache.update(kv_new)
    if kind == KIND_HYBRID:
        if decode:
            s, (st, cb) = ssm_mod.ssm_decode_step(
                cfg, p["ssm"], hn, cache_l["ssm_state"], cache_l["conv_buf"])
        else:
            s, (st, cb) = ssm_mod.ssm_forward(
                cfg, p["ssm"], hn, state=cache_l["ssm_state"],
                conv_buf=cache_l["conv_buf"])
        new_cache["ssm_state"], new_cache["conv_buf"] = st, cb
        a = 0.5 * (a + s)
    h = h + a
    if "cross" in p and "ck" in cache_l:
        h = h + attn.cross_attention(cfg, p["cross"],
                                     apply_norm(cfg, p["norm_cross"], h),
                                     cache_l["ck"], cache_l["cv"])
    y, aux = _mlp_part(cfg, kind, p, h)
    return h + y, new_cache, aux


# ---------------------------------------------------------------------------
# Per-run cache init
# ---------------------------------------------------------------------------

def init_run_cache(cfg: ModelConfig, kind: str, n_layers: int, batch: int,
                   max_len: int, enc_seq: int = 0, kv_layout: str = "contig",
                   num_pages: int = 0, page_size: int = 0):
    """Allocate the decode cache for one homogeneous layer run."""
    cache: dict = {}
    window = _kind_window(cfg, kind)
    if kind != KIND_SSM:
        # Windowed runs keep their ring buffers even under kv_layout="paged":
        # they are already bounded at the window, so paging buys nothing.
        if kv_layout == "paged" and not window:
            cache.update(attn.init_paged_kv_cache(cfg, num_pages, page_size,
                                                  n_layers))
        else:
            cache.update(attn.init_kv_cache(cfg, batch, max_len, n_layers,
                                            window=window))
    if kind in (KIND_SSM, KIND_HYBRID):
        cache.update(ssm_mod.init_ssm_state(cfg, batch, n_layers))
    if cfg.cross_attention and enc_seq:
        dt = jnp.dtype(cfg.dtype)
        cache["ck"] = jnp.zeros((n_layers, batch, enc_seq, cfg.num_kv_heads,
                                 cfg.head_dim), dt)
        cache["cv"] = jnp.zeros_like(cache["ck"])
    return cache
