"""Attention: GQA / MQA / MHA with unified position-based masking.

One code path serves training (no cache), chunked prefill, and single-token
decode.  All masks are derived from absolute positions:

  * query positions  ``q_pos``  (B, S)   — absolute index of each query token
  * key positions    ``k_pos``  (B, M)   — absolute index of each cache slot
                                           (-1 marks an empty slot)

Causality is ``k_pos <= q_pos``; sliding windows add ``k_pos > q_pos - W``.
Ring-buffer caches (sliding-window layers) therefore need no special-case
masking: the stored ``k_pos`` of an overwritten slot simply moves forward.

The reference path is pure jnp; ``repro.kernels`` provides Pallas TPU
kernels with identical semantics (``use_kernels`` flag on the model).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# §Perf A/B switch: REPRO_DECODE_CONCAT=1 restores the pre-hillclimb
# concat-based decode attention for baseline measurements.
_DECODE_CONCAT = os.environ.get("REPRO_DECODE_CONCAT", "") == "1"

from repro.config import ModelConfig
from repro.models.hints import BATCH, hint
from repro.models.layers import apply_rope, cdtype, dense_init, pdtype, softcap

NEG_INF = -2.0 ** 30   # large-negative instead of -inf: keeps softmax NaN-free
                       # for all-masked rows (empty cache slots at step 0)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    """Initialize q/k/v/o projection params (plus optional qkv bias)."""
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, qd, dt),
         "wk": dense_init(ks[1], d, kvd, dt),
         "wv": dense_init(ks[2], d, kvd, dt),
         "wo": dense_init(ks[3], qd, d, dt, scale=qd ** -0.5)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dt)
        p["bk"] = jnp.zeros((kvd,), dt)
        p["bv"] = jnp.zeros((kvd,), dt)
    return p


def project_qkv(cfg: ModelConfig, p, hq, hkv=None):
    """hq: (B,S,d) queries source; hkv: (B,M,d) keys/values source."""
    hkv = hq if hkv is None else hkv
    q = hq @ p["wq"].astype(hq.dtype)
    k = hkv @ p["wk"].astype(hq.dtype)
    v = hkv @ p["wv"].astype(hq.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    B = hq.shape[0]
    q = q.reshape(B, hq.shape[1], cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, hkv.shape[1], cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, hkv.shape[1], cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention with position masks (pure-jnp reference)
# ---------------------------------------------------------------------------

ATTEND_BLOCK_K = 1024          # KV block for the online-softmax path
ATTEND_DENSE_LIMIT = 1 << 24   # use dense scores below S*M of ~16M elements


def _mask(q_pos, k_pos, window: int, causal: bool):
    valid = (k_pos >= 0)[:, None, None, None, :]
    if causal:
        valid &= k_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window:
        valid &= (k_pos[:, None, None, None, :]
                  > (q_pos[:, None, None, :, None] - window))
    return valid


def attend(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, window: int = 0,
           causal: bool = True, k_scale=None, v_scale=None):
    """q: (B,S,H,hd); k/v: (B,M,KH,hd); q_pos: (B,S); k_pos: (B,M).

    Returns (B,S,H,hd). float32 softmax; GQA via head grouping. Large S*M
    takes the blocked online-softmax path (flash-attention schedule in pure
    jnp — O(S*block) memory instead of O(S*M); same semantics as the Pallas
    kernels). k_scale/v_scale: per-(token, kv-head) int8 dequant scales
    (lazy per-block dequant keeps the int8 memory win).
    """
    S, M = q.shape[1], k.shape[1]
    if S * M > ATTEND_DENSE_LIMIT and M > ATTEND_BLOCK_K:
        return _attend_blocked(cfg, q, k, v, q_pos, k_pos, window=window,
                               causal=causal, k_scale=k_scale,
                               v_scale=v_scale)
    return _attend_dense(cfg, q, k, v, q_pos, k_pos, window=window,
                         causal=causal, k_scale=k_scale, v_scale=v_scale)


def _deq(x, scale):
    x = x.astype(jnp.float32)
    if scale is not None:
        x = x * scale[..., None].astype(jnp.float32)
    return x


def _attend_dense(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, window: int,
                  causal: bool, k_scale=None, v_scale=None):
    B, S, H, hd = q.shape
    M, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, hd)
    if S > 1:   # shard the query-seq dim of the big score tensors (hints.py)
        qg = hint(qg, BATCH, "model")
    scores = jnp.einsum("bskgh,bmkh->bkgsm", qg.astype(jnp.float32),
                        _deq(k, k_scale)) * (hd ** -0.5)
    if S > 1:
        scores = hint(scores, BATCH, None, None, "model")
    scores = softcap(scores, cfg.attn_logit_softcap)
    valid = _mask(q_pos, k_pos, window, causal)
    scores = jnp.where(valid, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(scores - m), 0.0)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgsm,bmkh->bskgh", p / l, _deq(v, v_scale))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _attend_blocked(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, window: int,
                    causal: bool, block: int = ATTEND_BLOCK_K,
                    k_scale=None, v_scale=None):
    B, S, H, hd = q.shape
    M, KH = k.shape[1], k.shape[2]
    G = H // KH
    pad = (-M) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    nb = (M + pad) // block
    qg = q.reshape(B, S, KH, G, hd).astype(jnp.float32)
    qg = hint(qg, BATCH, "model")        # shard query-seq dim (hints.py)
    kb = jnp.moveaxis(k.reshape(B, nb, block, KH, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block, KH, hd), 1, 0)
    pb = jnp.moveaxis(k_pos.reshape(B, nb, block), 1, 0)
    if k_scale is not None:
        ksb = jnp.moveaxis(k_scale.reshape(B, nb, block, KH), 1, 0)
        vsb = jnp.moveaxis(v_scale.reshape(B, nb, block, KH), 1, 0)
    else:
        ksb = vsb = jnp.zeros((nb, B, 0, KH), jnp.float32)

    def body(carry, inp):
        """Online-softmax update over one KV block."""
        m_run, l_run, acc = carry
        kc, vc, pc, ksc, vsc = inp
        kc = _deq(kc, ksc if k_scale is not None else None)
        vc = _deq(vc, vsc if v_scale is not None else None)
        s = jnp.einsum("bskgh,bmkh->bkgsm", qg, kc)
        s = hint(s, BATCH, None, None, "model")
        s = s * (hd ** -0.5)
        s = softcap(s, cfg.attn_logit_softcap)
        valid = _mask(q_pos, pc, window, causal)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = (acc * alpha[..., None]
               + jnp.einsum("bkgsm,bmkh->bkgsh", p, vc.astype(jnp.float32)))
        return (m_new, l_new, acc), None

    m0 = hint(jnp.full((B, KH, G, S), NEG_INF, jnp.float32),
              BATCH, None, None, "model")
    l0 = hint(jnp.zeros((B, KH, G, S), jnp.float32),
              BATCH, None, None, "model")
    a0 = hint(jnp.zeros((B, KH, G, S, hd), jnp.float32),
              BATCH, None, None, "model")
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (kb, vb, pb, ksb, vsb))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Self-attention over a slot cache (prefill chunk / decode)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  window: int = 0):
    """A stacked cache for a run of ``n_layers`` identical layers.

    With ``cfg.kv_quant`` the K/V payload is int8 with per-(token, kv-head)
    float32 scales — 2x HBM vs bf16 (the §Perf fix for MHA decode shapes
    whose bf16 cache exceeds HBM, e.g. qwen1.5-32b decode_32k).
    """
    slots = min(window, max_len) if window else max_len
    shape = (n_layers, batch, slots, cfg.num_kv_heads, cfg.head_dim)
    cache = {"kpos": jnp.full((n_layers, batch, slots), -1, jnp.int32)}
    if cfg.kv_quant:
        cache["k"] = jnp.zeros(shape, jnp.int8)
        cache["v"] = jnp.zeros(shape, jnp.int8)
        cache["k_scale"] = jnp.zeros(shape[:4], jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:4], jnp.float32)
    else:
        dt = cdtype(cfg)
        cache["k"] = jnp.zeros(shape, dt)
        cache["v"] = jnp.zeros(shape, dt)
    return cache


def _quantize(x):
    """x: (B,S,KH,hd) -> (int8 values, per-(token,head) f32 scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    """Invert `quantize`: int8 values x per-(token,head) scales -> f32."""
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def cache_write(cache_l, k_new, v_new, positions, window: int):
    """Write S new entries per sequence into one layer's cache.

    cache_l: {"k": (B,slots,KH,hd), ...}; k_new: (B,S,KH,hd);
    positions: (B,S) absolute positions. Returns updated cache_l.
    """
    slots = cache_l["k"].shape[1]
    B = k_new.shape[0]
    slot_idx = positions % slots if window else positions
    # invalid (-1) or overflowing positions -> index `slots` (out of bounds),
    # dropped by scatter mode="drop": no special-case masking anywhere else.
    ok = positions >= 0
    if not window:
        ok &= positions < slots
    slot_idx = jnp.where(ok, slot_idx, slots)
    b_idx = jnp.arange(B)[:, None]
    out = dict(cache_l)
    if "k_scale" in cache_l:
        k_q, k_s = _quantize(k_new)
        v_q, v_s = _quantize(v_new)
        out["k"] = cache_l["k"].at[b_idx, slot_idx].set(k_q, mode="drop")
        out["v"] = cache_l["v"].at[b_idx, slot_idx].set(v_q, mode="drop")
        out["k_scale"] = cache_l["k_scale"].at[b_idx, slot_idx].set(
            k_s, mode="drop")
        out["v_scale"] = cache_l["v_scale"].at[b_idx, slot_idx].set(
            v_s, mode="drop")
    else:
        out["k"] = cache_l["k"].at[b_idx, slot_idx].set(
            k_new.astype(cache_l["k"].dtype), mode="drop")
        out["v"] = cache_l["v"].at[b_idx, slot_idx].set(
            v_new.astype(cache_l["v"].dtype), mode="drop")
    out["kpos"] = cache_l["kpos"].at[b_idx, slot_idx].set(
        positions, mode="drop")
    return out


def self_attention_cached(cfg: ModelConfig, p, h, cache_l, q_pos, *,
                          window: int = 0):
    """One layer of cached self-attention on a token chunk.

    h: (B,S,d); cache_l holds this layer's slots; q_pos: (B,S) absolute
    positions of the chunk tokens. Returns (out (B,S,d), new cache_l).
    """
    q, k, v = project_qkv(cfg, p, h)
    if cfg.use_rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)
    B, S = h.shape[:2]
    if S == 1 and not _DECODE_CONCAT:
        # Decode fast path (EXPERIMENTS.md §Perf): write the single token
        # FIRST, then attend over the updated cache in place. The concat
        # path below copies the whole cache every step (qwen decode_32k:
        # +50 GB/dev of transients). Safe at S=1: a ring slot overwritten by
        # the new token held a position <= q_pos - window, already masked.
        new_cache = cache_write(cache_l, k, v, q_pos, window)
        out = attend(cfg, q, new_cache["k"], new_cache["v"], q_pos,
                     new_cache["kpos"], window=window,
                     k_scale=new_cache.get("k_scale"),
                     v_scale=new_cache.get("v_scale"))
        return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(h.dtype), new_cache
    # Chunk path: attend over the PRE-write cache plus the fresh in-chunk
    # K/V. Writing first would let ring-buffer slots be clobbered by later
    # in-chunk tokens that earlier queries still need (and would
    # double-count global slots).
    if "k_scale" in cache_l:     # int8 cache: dequantize the prefix (chunk
        k_cache = dequantize(cache_l["k"], cache_l["k_scale"]).astype(k.dtype)
        v_cache = dequantize(cache_l["v"], cache_l["v_scale"]).astype(v.dtype)
    else:
        k_cache, v_cache = cache_l["k"], cache_l["v"]
    k_all = jnp.concatenate([k_cache, k], axis=1)
    v_all = jnp.concatenate([v_cache, v], axis=1)
    kpos_all = jnp.concatenate([cache_l["kpos"], q_pos], axis=1)
    out = attend(cfg, q, k_all, v_all, q_pos, kpos_all, window=window)
    new_cache = cache_write(cache_l, k, v, q_pos, window)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(h.dtype), new_cache


# ---------------------------------------------------------------------------
# Paged self-attention (block-table indirection over a shared page pool)
# ---------------------------------------------------------------------------

def init_paged_kv_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                        n_layers: int):
    """A paged cache for a run of ``n_layers`` global-attention layers.

    K/V live in a pool of ``num_pages`` fixed-size pages shared by every
    sequence; per-sequence block tables (held at the cache's top level)
    map logical page ``pos // page_size`` to a physical page. Physical
    page 0 is the null page: its ``pkpos`` stays -1, so block-table rows
    can point unallocated logical pages at it and masking does the rest.
    Leaves are named pk/pv/pkpos so the cached path can tell the layouts
    apart structurally (jit-safe — no static flags in the pytree).
    """
    if cfg.kv_quant:
        raise NotImplementedError("paged KV does not support kv_quant yet")
    shape = (n_layers, num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    return {
        "pkpos": jnp.full((n_layers, num_pages, page_size), -1, jnp.int32),
        "pk": jnp.zeros(shape, cdtype(cfg)),
        "pv": jnp.zeros(shape, cdtype(cfg)),
    }


def gather_pages(x_pages, block_table):
    """x_pages: (P, ps, ...); block_table: (B, pmax) -> (B, pmax*ps, ...).

    The gathered view is ordered by logical position (block tables map
    logical page i of a sequence to entry i), so downstream position
    masking sees a plain per-sequence cache."""
    g = x_pages[block_table]
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_cache_write(cache_l, k_new, v_new, positions, block_table):
    """Write S new entries per sequence through the block table.

    cache_l: {"pk": (P,ps,KH,hd), "pv": ..., "pkpos": (P,ps)};
    k_new/v_new: (B,S,KH,hd); positions: (B,S) absolute (-1 = inactive);
    block_table: (B, pmax). Writes resolving to the null page (0) or past
    the table are dropped, like the contiguous path's mode="drop"."""
    P, ps, KH, hd = cache_l["pk"].shape
    pmax = block_table.shape[1]
    pidx = positions // ps
    page_ids = jnp.take_along_axis(
        block_table, jnp.clip(pidx, 0, pmax - 1), axis=1)
    ok = (positions >= 0) & (pidx < pmax) & (page_ids > 0)
    flat = jnp.where(ok, page_ids * ps + positions % ps, P * ps)
    out = dict(cache_l)
    out["pk"] = cache_l["pk"].reshape(P * ps, KH, hd).at[flat].set(
        k_new.astype(cache_l["pk"].dtype), mode="drop").reshape(P, ps, KH, hd)
    out["pv"] = cache_l["pv"].reshape(P * ps, KH, hd).at[flat].set(
        v_new.astype(cache_l["pv"].dtype), mode="drop").reshape(P, ps, KH, hd)
    out["pkpos"] = cache_l["pkpos"].reshape(P * ps).at[flat].set(
        positions, mode="drop").reshape(P, ps)
    return out


def self_attention_paged(cfg: ModelConfig, p, h, cache_l, q_pos, block_table,
                         use_kernels: bool = False):
    """One layer of paged cached self-attention (global attention only).

    Same semantics as ``self_attention_cached`` with window=0, but K/V are
    read and written through the block table. Decode (S=1) writes first
    and attends over the updated pool (pages are request-exclusive, so no
    in-chunk clobber hazard exists); prefill chunks attend over the
    gathered prefix plus the fresh in-chunk K/V, then write.

    With ``use_kernels`` the gather+attend reference is replaced by the
    Pallas flash-decode kernels, which stream pages HBM->VMEM through the
    scalar-prefetched block table instead of materializing the gathered
    cache: single-query for decode, multi-query (write-first, one page
    stream for all S chunk tokens) for prefill chunks.
    """
    q, k, v = project_qkv(cfg, p, h)
    if cfg.use_rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)
    B, S = h.shape[:2]
    if use_kernels:
        from repro.kernels import ops
        new_cache = paged_cache_write(cache_l, k, v, q_pos, block_table)
        if S == 1:
            out = ops.paged_decode_attention(
                q[:, 0], new_cache["pk"], new_cache["pv"],
                new_cache["pkpos"], block_table, q_pos[:, 0],
                softcap=cfg.attn_logit_softcap)[:, None]
        else:
            out = ops.paged_decode_attention_multi(
                q, new_cache["pk"], new_cache["pv"], new_cache["pkpos"],
                block_table, q_pos, softcap=cfg.attn_logit_softcap)
        return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(h.dtype), new_cache
    if S == 1:
        new_cache = paged_cache_write(cache_l, k, v, q_pos, block_table)
        out = attend(cfg, q, gather_pages(new_cache["pk"], block_table),
                     gather_pages(new_cache["pv"], block_table), q_pos,
                     gather_pages(new_cache["pkpos"], block_table))
        return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(h.dtype), new_cache
    k_all = jnp.concatenate([gather_pages(cache_l["pk"], block_table), k],
                            axis=1)
    v_all = jnp.concatenate([gather_pages(cache_l["pv"], block_table), v],
                            axis=1)
    kpos_all = jnp.concatenate(
        [gather_pages(cache_l["pkpos"], block_table), q_pos], axis=1)
    out = attend(cfg, q, k_all, v_all, q_pos, kpos_all)
    new_cache = paged_cache_write(cache_l, k, v, q_pos, block_table)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(h.dtype), new_cache


def self_attention_full(cfg: ModelConfig, p, h, *, window: int = 0,
                        positions=None, causal: bool = True):
    """Training-path attention (no cache): full (causal) over (B,S,d)."""
    B, S = h.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = project_qkv(cfg, p, h)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = attend(cfg, q, k, v, positions, positions, window=window,
                 causal=causal)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(h.dtype)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder -> encoder output)
# ---------------------------------------------------------------------------

def cross_kv(cfg: ModelConfig, p, enc_out):
    """Precompute encoder K/V once per request batch. enc_out: (B,T,d)."""
    B, T = enc_out.shape[:2]
    k = (enc_out @ p["wk"].astype(enc_out.dtype))
    v = (enc_out @ p["wv"].astype(enc_out.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    k = k.reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def cross_attention(cfg: ModelConfig, p, h, ck, cv):
    """h: (B,S,d) decoder states; ck/cv: (B,T,KH,hd). Non-causal."""
    B, S = h.shape[:2]
    q = h @ p["wq"].astype(h.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    T = ck.shape[1]
    qp = jnp.zeros((B, S), jnp.int32)
    kp = jnp.zeros((B, T), jnp.int32)
    out = attend(cfg, q, ck, cv, qp, kp, causal=False)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(h.dtype)
