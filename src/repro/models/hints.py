"""Sharding hints: mesh-aware ``with_sharding_constraint`` that degrades to a
no-op outside a mesh context (smoke tests, single-device runs).

GSPMD's propagation leaves the big attention intermediates
(scores/accumulators, (B,KH,G,S,M)-shaped) replicated over the "model" axis,
which blows past HBM at train_4k/prefill_32k scale. Queries are independent
in attention, so we shard the *query-sequence* dim over "model" — softmax
rows stay device-local, no extra collectives inside the loop. MoE expert
buffers shard over "model" (expert parallelism).

The special token ``BATCH`` resolves to ("pod","data") or ("data",)
depending on the ambient mesh. Axes that do not divide the dim are dropped.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

BATCH = "__batch__"


def _abstract_mesh():
    """jax.sharding.get_abstract_mesh appeared in jax 0.4.38; older jax has
    no ambient-mesh query, so hints degrade to no-ops there."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    return get() if get is not None else None


def data_shards() -> int:
    """Number of batch-sharding ways in the ambient mesh (1 outside jit)."""
    am = _abstract_mesh()
    names = getattr(am, "axis_names", ())
    if not names:
        return 1
    sizes = dict(zip(names, am.shape.values() if hasattr(am.shape, "values")
                     else am.shape))
    n = 1
    for a in ("pod", "data"):
        n *= sizes.get(a, 1)
    return n


def hint(x, *spec):
    """Annotate ``x`` with a sharding hint when a mesh is active."""
    am = _abstract_mesh()
    names = getattr(am, "axis_names", ())
    if not names:
        return x
    sizes = dict(zip(names, am.shape.values() if hasattr(am.shape, "values")
                     else am.shape))
    full = tuple(spec) + (None,) * (x.ndim - len(spec))
    out = []
    for dim, ax in zip(x.shape, full):
        if ax == BATCH:
            ax = ("pod", "data") if "pod" in names else ("data",)
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        if not all(a in names for a in axes):
            out.append(None)
            continue
        size = 1
        for a in axes:
            size *= sizes[a]
        out.append(ax if (dim >= size and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(x, P(*out))
