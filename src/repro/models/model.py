"""Model factory: one composable bundle per architecture config.

``build_model(cfg)`` returns a :class:`Model` exposing:

  init(rng)                          -> params   (includes the TRAIL probe)
  init_cache(batch, max_len)         -> cache    (per-run KV / SSM state)
  forward_train(params, batch)       -> (loss, aux)   aux: {"tap": (B,S,d), ...}
  encode(params, enc_embeds)         -> enc_out       (enc-dec only)
  prefill_chunk(params, cache, ...)  -> (logits_last, cache, tap_sum, tap_cnt)
  decode_step(params, cache, ...)    -> (logits, cache, tap, probe_logits)

The decode step *fuses the paper's probe* (Section 3.1/3.2): the tap layer's
hidden state feeds the 2-layer MLP classifier inside the same jitted program
— the TPU-native replacement for vLLM's CPU-offloaded predictor.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import KIND_SSM, ModelConfig
from repro.core import predictor
from repro.models import attention as attn_mod
from repro.models import transformer as tfm
from repro.models.layers import (apply_norm, cdtype, embed_init, embed_tokens,
                                 init_norm, pdtype, unembed)

MAX_LEARNED_POS = 32768


class Model:
    """Stateless forward passes over a params dict for one ModelConfig."""

    def __init__(self, cfg: ModelConfig, use_kernels: bool = False):
        self.cfg = cfg
        self.use_kernels = use_kernels
        self.runs = tfm.split_runs(cfg)
        self.tap_run = tfm.tap_run_index(cfg)

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def init(self, rng) -> dict:
        """Initialize the full parameter dict (embed/blocks/probe/...)."""
        cfg = self.cfg
        keys = jax.random.split(rng, len(self.runs) + 6)
        dt = pdtype(cfg)
        params: dict[str, Any] = {
            "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": init_norm(cfg, dt),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(keys[1], cfg.vocab_size, cfg.d_model, dt)
        if not cfg.use_rope:
            params["pos_embed"] = embed_init(
                keys[2], min(MAX_LEARNED_POS, 1 << 16), cfg.d_model, dt)
        cross = cfg.cross_attention
        layer_params = []
        for ri, (kinds, nb) in enumerate(self.runs):
            sub = []
            for j, kind in enumerate(kinds):
                ks = jax.random.split(
                    jax.random.fold_in(keys[3], ri * 64 + j), nb)
                sub.append(jax.vmap(
                    lambda k, _kind=kind: tfm.init_block(
                        k, cfg, _kind, cross=cross))(ks))
            layer_params.append(tuple(sub))
        params["layers"] = tuple(layer_params)
        if cfg.num_encoder_layers:
            params["encoder"] = self._init_encoder(keys[4])
        params["probe"] = predictor.init_probe(keys[5], cfg.d_model, cfg.probe)
        return params

    def _init_encoder(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        enc_layers = jax.vmap(
            lambda k: tfm.init_block(k, cfg, "attn", cross=False))(
                jax.random.split(ks[0], cfg.num_encoder_layers))
        return {
            "pos": embed_init(ks[1], cfg.encoder_seq, cfg.d_model, pdtype(cfg)),
            "layers": enc_layers,
            "final_norm": init_norm(cfg, pdtype(cfg)),
        }

    # ------------------------------------------------------------------
    # Cache
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int,
                   kv_layout: str = "contig", page_size: int = 16) -> dict:
        """kv_layout="paged" stores global-attention K/V in a shared page
        pool (1 null page + batch * ceil(max_len/page_size) pages) behind a
        (batch, pages_per_seq) block table; windowed/SSM/cross state keeps
        the per-slot layout (already bounded)."""
        cfg = self.cfg
        cache: dict[str, Any] = {
            "lengths": jnp.zeros((batch,), jnp.int32),
        }
        num_pages = 0
        if kv_layout == "paged":
            pages_per_seq = -(-max_len // page_size)
            num_pages = 1 + batch * pages_per_seq      # page 0 = null page
            cache["block_table"] = jnp.zeros((batch, pages_per_seq),
                                             jnp.int32)
        for ri, (kinds, nb) in enumerate(self.runs):
            cache[f"run_{ri}"] = tuple(
                tfm.init_run_cache(cfg, kind, nb, batch, max_len,
                                   enc_seq=cfg.encoder_seq,
                                   kv_layout=kv_layout, num_pages=num_pages,
                                   page_size=page_size)
                for kind in kinds)
        return cache

    # ------------------------------------------------------------------
    # Embedding helpers
    # ------------------------------------------------------------------
    def _embed(self, params, tokens, positions):
        cfg = self.cfg
        h = embed_tokens(cfg, params, tokens)
        if not cfg.use_rope and "pos_embed" in params:
            table = params["pos_embed"]
            idx = jnp.clip(positions, 0, table.shape[0] - 1)
            h = h + table[idx].astype(h.dtype)
        return h

    # ------------------------------------------------------------------
    # Encoder (whisper; stub frontend supplies enc_embeds)
    # ------------------------------------------------------------------
    def encode(self, params, enc_embeds):
        """Run the non-causal encoder stack over frontend embeddings."""
        cfg = self.cfg
        enc = params["encoder"]
        h = enc_embeds.astype(cdtype(cfg))
        h = h + enc["pos"][None, : h.shape[1]].astype(h.dtype)

        def body(carry, p_l):
            """One encoder block: self-attention + MLP residuals."""
            hn = apply_norm(cfg, p_l["norm1"], carry)
            a = attn_mod.self_attention_full(cfg, p_l["attn"], hn, causal=False)
            carry = carry + a
            from repro.models.layers import apply_mlp
            carry = carry + apply_mlp(
                cfg, p_l["mlp"], apply_norm(cfg, p_l["norm2"], carry))
            return carry, None

        h, _ = jax.lax.scan(body, h, enc["layers"])
        return apply_norm(cfg, enc["final_norm"], h)

    def build_cross_cache(self, params, cache, enc_out):
        """Fill each run's ck/cv from the encoder output."""
        cfg = self.cfg
        new = dict(cache)
        for ri, (kinds, nb) in enumerate(self.runs):
            subs = []
            changed = False
            for j, sub in enumerate(new[f"run_{ri}"]):
                sub = dict(sub)
                if "ck" in sub:
                    p_run = params["layers"][ri][j]
                    ck, cv = jax.vmap(
                        lambda pl: attn_mod.cross_kv(
                            cfg, pl["cross"], enc_out))(p_run)
                    sub["ck"], sub["cv"] = ck, cv
                    changed = True
                subs.append(sub)
            if changed:
                new[f"run_{ri}"] = tuple(subs)
        return new

    # ------------------------------------------------------------------
    # Training forward
    # ------------------------------------------------------------------
    def forward_train(self, params, batch) -> tuple[jax.Array, dict]:
        """batch: {"tokens": (B,S), "labels": (B,S)} (+ enc/prefix embeds).

        Returns (loss, {"aux_loss", "tap" (B,S,d), "logits_sample"}).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h = self._embed(params, tokens, positions)
        labels = batch["labels"]

        prefix = batch.get("prefix_embeds")
        if prefix is not None:                      # VLM: vision prefix
            P = prefix.shape[1]
            h = jnp.concatenate([prefix.astype(h.dtype), h], axis=1)
            positions = jnp.broadcast_to(
                jnp.arange(P + S, dtype=jnp.int32), (B, P + S))
            labels = jnp.concatenate(
                [jnp.full((B, P), -1, labels.dtype), labels], axis=1)

        enc_out = None
        if "enc_embeds" in batch:                   # audio: encoder pass
            enc_out = self.encode(params, batch["enc_embeds"])

        tap = None
        aux_total = jnp.float32(0)
        for ri, (kinds, nb) in enumerate(self.runs):
            def body(carry, p_blk, _kinds=kinds):
                aux = jnp.float32(0)
                for j, kind in enumerate(_kinds):
                    carry, a = tfm.block_train(cfg, kind, p_blk[j], carry,
                                               enc_out=enc_out,
                                               positions=positions)
                    aux = aux + a
                return carry, aux
            if cfg.remat:
                body = jax.checkpoint(body)
            h, auxs = jax.lax.scan(body, h, params["layers"][ri])
            aux_total = aux_total + jnp.sum(auxs)
            if ri == self.tap_run:
                tap = h
        h = apply_norm(cfg, params["final_norm"], h)
        loss, n_tok = _chunked_ce(cfg, params, h, labels)
        aux = {"aux_loss": aux_total, "tap": tap, "n_tok": n_tok}
        total = loss + cfg.router_aux_weight * aux_total
        return total, aux

    def forward_all_taps(self, params, batch):
        """Profiling pass (paper Section 3.1 'we profile embeddings across
        all 32 layers'): returns hidden states after EVERY layer,
        shape (num_layers, B, S, d). Train-path semantics, no loss."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h = self._embed(params, tokens, positions)
        enc_out = None
        if "enc_embeds" in batch:
            enc_out = self.encode(params, batch["enc_embeds"])
        taps = []
        for ri, (kinds, nb) in enumerate(self.runs):
            def body(carry, p_blk, _kinds=kinds):
                outs = []
                for j, kind in enumerate(_kinds):
                    carry, _ = tfm.block_train(cfg, kind, p_blk[j], carry,
                                               enc_out=enc_out,
                                               positions=positions)
                    outs.append(carry)
                return carry, jnp.stack(outs)
            h, per_block = jax.lax.scan(body, h, params["layers"][ri])
            # (nb, p, B, S, d) -> (nb*p, B, S, d) in layer order
            taps.append(per_block.reshape((-1,) + per_block.shape[2:]))
        return jnp.concatenate(taps, axis=0)

    # ------------------------------------------------------------------
    # Cached forward (chunked prefill; decode is the S=1 case)
    # ------------------------------------------------------------------
    def _cached_trunk(self, params, cache, h, q_pos, decode: bool):
        cfg = self.cfg
        new_cache = dict(cache)
        tap = None
        aux_total = jnp.float32(0)
        block_table = cache.get("block_table")     # shared across all layers
        for ri, (kinds, nb) in enumerate(self.runs):
            def body(carry, xs, _kinds=kinds):
                p_blk, c_blk = xs
                new_blk = []
                aux = jnp.float32(0)
                for j, kind in enumerate(_kinds):
                    carry, c_new, a = tfm.block_cached(
                        cfg, kind, p_blk[j], carry, c_blk[j], q_pos,
                        decode=decode, block_table=block_table,
                        use_kernels=self.use_kernels)
                    new_blk.append(c_new)
                    aux = aux + a
                return carry, (tuple(new_blk), aux)
            h, (run_cache, auxs) = jax.lax.scan(
                body, h, (params["layers"][ri], cache[f"run_{ri}"]))
            new_cache[f"run_{ri}"] = run_cache
            aux_total = aux_total + jnp.sum(auxs)
            if ri == self.tap_run:
                tap = h
        return h, new_cache, tap, aux_total

    def prefill_chunk(self, params, cache, tokens, valid=None,
                      prefix_embeds=None, enc_embeds=None):
        """Process a chunk of prompt tokens for every active row.

        tokens: (B,C); valid: (B,C) bool (contiguous prefixes) or None.
        Returns (next_logits (B,V), cache, tap_sum (B,d), tap_cnt (B,)).
        """
        cfg = self.cfg
        B, C = tokens.shape
        if valid is None:
            valid = jnp.ones((B, C), bool)
        offsets = cache["lengths"]

        if enc_embeds is not None:
            enc_out = self.encode(params, enc_embeds)
            cache = self.build_cross_cache(params, cache, enc_out)

        q_pos = jnp.where(valid, offsets[:, None] + jnp.arange(C, dtype=jnp.int32),
                          -1)
        h = self._embed(params, tokens, q_pos)
        if prefix_embeds is not None:
            P = prefix_embeds.shape[1]
            ppos = offsets[:, None] + jnp.arange(P, dtype=jnp.int32)
            h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
            q_pos = jnp.concatenate(
                [ppos, jnp.where(valid, q_pos + P, -1)], axis=1)
            valid = jnp.concatenate([jnp.ones((B, P), bool), valid], axis=1)

        h, new_cache, tap, _ = self._cached_trunk(params, cache, h, q_pos,
                                                  decode=False)
        n_new = jnp.sum(valid, axis=1).astype(jnp.int32)
        new_cache["lengths"] = offsets + n_new

        # next-token logits from the last valid position of each row
        last_idx = jnp.maximum(jnp.sum(valid, axis=1) - 1, 0)
        h_last = h[jnp.arange(B), last_idx]
        h_last = apply_norm(cfg, params["final_norm"], h_last)
        logits = unembed(cfg, params, h_last)

        # paper: prompt-phase probe input = mean of prompt-token taps
        vmask = valid[..., None].astype(jnp.float32)
        tap_sum = jnp.sum(tap.astype(jnp.float32) * vmask, axis=1)
        return logits, new_cache, tap_sum, n_new

    def decode_step(self, params, cache, tokens, active=None):
        """One iteration: generate-one-token for every active row.

        tokens: (B,1) int32; active: (B,) bool. Fuses the probe classifier.
        Returns (logits (B,V), cache, tap (B,d), probe_logits (B,k)).
        """
        cfg = self.cfg
        B = tokens.shape[0]
        if active is None:
            active = jnp.ones((B,), bool)
        lengths = cache["lengths"]
        q_pos = jnp.where(active, lengths, -1)[:, None]
        h = self._embed(params, tokens, q_pos)
        h, new_cache, tap, _ = self._cached_trunk(params, cache, h, q_pos,
                                                  decode=True)
        # inactive rows must not mutate recurrent state (KV writes already
        # dropped via position -1; SSM state needs an explicit select)
        new_cache = _mask_recurrent(cache, new_cache, active)
        new_cache["lengths"] = lengths + active.astype(jnp.int32)
        hn = apply_norm(cfg, params["final_norm"], h[:, 0])
        logits = unembed(cfg, params, hn)
        tap = tap[:, 0]
        probe_logits = predictor.apply_probe(params["probe"], tap)
        return logits, new_cache, tap, probe_logits

    def decode_multi(self, params, cache, tokens, active=None, budget=None,
                     *, k: int = 1, eos_id: int = -1):
        """Decode megastep: ``k`` fused decode+probe steps under one
        ``lax.scan`` with on-device greedy sampling and per-row halting.

        The (B, vocab) logits never leave the device — each step argmaxes
        on device and feeds the winner back as the next query, so the host
        round-trip per megastep is O(B*k) token ids plus O(B*k*num_bins)
        probe posteriors instead of k transfers of O(B*vocab) logits.

        tokens: (B,1) int32 — last known token per row; active: (B,) bool;
        budget: (B,) int32 — max tokens each row may still emit (rows halt
        early on budget exhaustion or, when ``eos_id >= 0``, after emitting
        the EOS token; halted rows stop writing KV / advancing ``lengths``
        exactly like inactive rows). ``k`` and ``eos_id`` must be static
        under jit.

        Returns (tokens (B,k) int32 with -1 past each row's halt point,
        cache, probe_probs (B,k,num_bins) f32 softmax posteriors,
        n_emitted (B,) int32).
        """
        B = tokens.shape[0]
        if active is None:
            active = jnp.ones((B,), bool)
        if budget is None:
            budget = jnp.full((B,), k, jnp.int32)
        budget = jnp.minimum(budget.astype(jnp.int32), k)

        def step(carry, _):
            """One scanned decode step over the active rows."""
            cache, tok, emitted, halted = carry
            act = active & ~halted & (emitted < budget)
            logits, cache, _, probe_logits = self.decode_step(
                params, cache, tok, active=act)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if eos_id >= 0:
                halted = halted | (act & (nxt == eos_id))
            emitted = emitted + act.astype(jnp.int32)
            probs = jax.nn.softmax(probe_logits.astype(jnp.float32), axis=-1)
            tok_out = jnp.where(act, nxt, -1)
            tok_next = jnp.where(act, nxt, tok[:, 0])[:, None]
            return (cache, tok_next, emitted, halted), (tok_out, probs)

        carry0 = (cache, tokens, jnp.zeros((B,), jnp.int32),
                  jnp.zeros((B,), bool))
        (cache, _, emitted, _), (toks, probs) = jax.lax.scan(
            step, carry0, None, length=k)
        return (jnp.moveaxis(toks, 0, 1), cache,
                jnp.moveaxis(probs, 0, 1), emitted)


def _mask_recurrent(old_cache, new_cache, active):
    out = dict(new_cache)
    for key, run_new in new_cache.items():
        if not key.startswith("run_"):
            continue
        run_old = old_cache[key]
        merged_run = []
        for sub_new, sub_old in zip(run_new, run_old):
            merged = dict(sub_new)
            for leaf in ("ssm_state", "conv_buf"):
                if leaf in merged:
                    a = active.reshape(
                        (1, -1) + (1,) * (merged[leaf].ndim - 2))
                    merged[leaf] = jnp.where(a, merged[leaf], sub_old[leaf])
            merged_run.append(merged)
        out[key] = tuple(merged_run)
    return out


def _chunked_ce(cfg: ModelConfig, params, h, labels, chunk: int = 256):
    """Cross-entropy without materializing (B,S,V) logits: lax.scan over
    sequence chunks (vocab up to 262k makes full logits impossible at 4k seq).
    The body is remat'd so the backward holds one chunk's softmax at a time.
    Returns (mean loss over labels>=0, number of such tokens)."""
    B, S, d = h.shape
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    hs = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, xs):
        """Accumulate masked NLL over one rematerialized logit chunk."""
        hc, lc = xs
        logits = unembed(cfg, params, hc)                  # (B,chunk,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        nll = jnp.sum((lse - gold) * mask)
        return (acc[0] + nll, acc[1] + jnp.sum(mask)), None

    (nll, n), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ls))
    return nll / jnp.maximum(n, 1.0), n


@functools.lru_cache(maxsize=None)
def _build_cached(cfg: ModelConfig, use_kernels: bool) -> Model:
    return Model(cfg, use_kernels=use_kernels)


def build_model(cfg: ModelConfig, use_kernels: bool = False) -> Model:
    """Return the (cached) `Model` wrapper for ``cfg``."""
    return _build_cached(cfg, use_kernels)
