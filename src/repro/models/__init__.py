"""Model zoo: composable JAX definitions for all assigned architectures."""

from repro.models.model import build_model  # noqa: F401
