"""Checkpointing: flat-path .npz save/restore for parameter/optimizer pytrees.

Simple but real: path-keyed flattening survives refactors that preserve dict
structure, round-trips dtypes (bfloat16 included via a view trick), and
writes atomically (tmp + rename).
"""

from __future__ import annotations

import os
import tempfile

import jax
import numpy as np


def _flatten(tree, prefix=""):
    """Flatten a dict/list/tuple pytree into path-keyed leaves."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
        out[f"{prefix}__len__"] = np.asarray(len(tree))
        out[f"{prefix}__tuple__"] = np.asarray(isinstance(tree, tuple))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def save(path: str, tree) -> None:
    """Write a pytree to ``path`` as a flat .npz, atomically (tmp + rename)."""
    flat = {}
    for k, v in _flatten(tree).items():
        arr = np.asarray(v)
        if arr.dtype == jax.numpy.bfloat16:
            flat[k + "::bf16"] = arr.view(np.uint16)
        else:
            flat[k] = arr
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def restore(path: str):
    """Rebuild the nested structure from path keys."""
    import jax.numpy as jnp
    with np.load(path) as z:
        flat = {}
        for k in z.files:
            if k.endswith("::bf16"):
                flat[k[:-6]] = z[k].view(jnp.bfloat16)
            else:
                flat[k] = z[k]

    root: dict = {}
    meta: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        if parts[-1] in ("__len__", "__tuple__"):
            meta["/".join(parts[:-1]) + "/" + parts[-1]] = val
            continue
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node, prefix=""):
        """Recursively restore list/tuple nodes from their length markers."""
        if not isinstance(node, dict):
            return node
        n_key = f"{prefix}__len__"
        if n_key in meta:
            n = int(meta[n_key])
            seq = [fix(node[str(i)], f"{prefix}{i}/") for i in range(n)]
            return tuple(seq) if bool(meta[f"{prefix}__tuple__"]) else seq
        return {k: fix(v, f"{prefix}{k}/") for k, v in node.items()}

    return fix(root)
