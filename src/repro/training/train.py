"""Train loops: the LM trainer (for the ~100M serving model and the smoke
tests) and the probe trainer (the paper's Section 3.1 recipe).

``make_train_step(model, opt_cfg)`` builds the jit-able
(params, opt_state, batch) -> (params, opt_state, metrics) function the
launcher shards with pjit — the same function the multi-pod dry-run lowers.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ProbeConfig
from repro.core import predictor as probe_mod
from repro.core.bins import bin_index
from repro.training import optimizer as opt_mod


def make_loss_fn(model):
    """Wrap ``model.forward_train`` as a (params, batch) -> (loss, aux) fn."""
    def loss_fn(params, batch):
        """Differentiable loss closure over the model."""
        loss, aux = model.forward_train(params, batch)
        return loss, aux
    return loss_fn


def make_train_step(model, ocfg: opt_mod.AdamWConfig):
    """Build the jit-able (params, opt_state, batch) update function."""
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        """One forward/backward/AdamW step; returns updated state + metrics."""
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, om = opt_mod.update(ocfg, grads, opt_state, params)
        metrics = {"loss": loss, "aux_loss": aux["aux_loss"],
                   "n_tok": aux["n_tok"], **om}
        return params, opt_state, metrics

    return train_step


def train_lm(model, params, data_iter, ocfg: opt_mod.AdamWConfig,
             n_steps: int, log_every: int = 20, callback=None):
    """Single-host training loop (CPU-sized models / smoke tests)."""
    step_fn = jax.jit(make_train_step(model, ocfg))
    opt_state = opt_mod.init(ocfg, params)
    history = []
    for step, batch in enumerate(data_iter):
        if step >= n_steps:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items()
              if k in ("tokens", "labels", "enc_embeds", "prefix_embeds")}
        params, opt_state, m = step_fn(params, opt_state, jb)
        if step % log_every == 0 or step == n_steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = step
            history.append(rec)
            if callback:
                callback(rec)
    return params, opt_state, history


# ---------------------------------------------------------------------------
# Probe training (paper Section 3.1)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ProbeTrainConfig:
    """The paper's probe-training recipe knobs (Section 3.1)."""

    epochs: int = 30                # paper: 30 epochs
    batch: int = 32                 # paper: batch 32
    lr: float = 0.01                # paper: cosine 0.01 -> 0
    seed: int = 0


def train_probe(taps: np.ndarray, remaining: np.ndarray, pc: ProbeConfig,
                d_model: int, tc: ProbeTrainConfig = ProbeTrainConfig(),
                probe_params=None, log=None):
    """Train the probe MLP on harvested (tap, remaining) pairs.

    Returns (probe_params, history). CE over bins, AdamW, cosine annealing —
    the paper's recipe verbatim (Section 3.1 'Predictor architecture').
    """
    n = taps.shape[0]
    steps_per_epoch = max(n // tc.batch, 1)
    total = tc.epochs * steps_per_epoch
    ocfg = opt_mod.AdamWConfig(lr=tc.lr, warmup_steps=0, total_steps=total,
                               weight_decay=0.01, clip_norm=0.0)
    key = jax.random.key(tc.seed)
    if probe_params is None:
        probe_params = probe_mod.init_probe(key, d_model, pc)
    labels = np.asarray(bin_index(remaining, pc))

    @jax.jit
    def step_fn(p, o, x, y):
        """One probe minibatch step: CE-over-bins loss + AdamW update."""
        loss, grads = jax.value_and_grad(probe_mod.probe_loss)(p, x, y)
        p, o, _ = opt_mod.update(ocfg, grads, o, p)
        return p, o, loss

    opt_state = opt_mod.init(ocfg, probe_params)
    rng = np.random.default_rng(tc.seed)
    history = []
    for ep in range(tc.epochs):
        perm = rng.permutation(n)
        losses = []
        for i in range(steps_per_epoch):
            idx = perm[i * tc.batch:(i + 1) * tc.batch]
            probe_params, opt_state, loss = step_fn(
                probe_params, opt_state, jnp.asarray(taps[idx]),
                jnp.asarray(labels[idx]))
            losses.append(float(loss))
        acc = float(probe_mod.probe_accuracy(
            probe_params, jnp.asarray(taps[:4096]),
            jnp.asarray(labels[:4096])))
        rec = {"epoch": ep, "loss": float(np.mean(losses)), "acc": acc}
        history.append(rec)
        if log:
            log(rec)
    return probe_params, history


def probe_mae(probe_params, taps, remaining, pc: ProbeConfig,
              refine: bool = False) -> float:
    """Mean absolute error of expected-length predictions (Figure 2/3)."""
    from repro.core.bins import bin_means
    p = np.asarray(jax.nn.softmax(
        probe_mod.apply_probe(probe_params, jnp.asarray(taps)), -1))
    pred = p @ bin_means(pc)
    return float(np.mean(np.abs(pred - remaining)))
