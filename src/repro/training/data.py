"""Synthetic instruction-following data pipeline.

No Alpaca offline, so we synthesize sequences with the same *structure*:

    [BOS, prompt..., SEP, response..., EOS, PAD...]

Prompt/response lengths follow the workload distributions (right-skewed
lognormal, responses clipped at 512). To make output length *learnable* (so
the probe has signal, mirroring real models where the prompt statistically
determines response length), the response length is a deterministic-ish
function of visible prompt features: a small set of "topic" tokens at the
start of the prompt sets the response-length regime, plus noise. Response
tokens repeat topic-conditioned patterns so the tap embeddings carry state
about progress (giving the per-iteration probe something to read).

Yields batches:
  tokens    (B, S) int32
  labels    (B, S) int32   next-token targets, -1 on prompt/pad
  remaining (B, S) int32   remaining response tokens at each position,
                            -1 outside the response span (probe labels)
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

BOS, EOS, SEP, PAD = 1, 2, 3, 0
N_TOPICS = 8
TOPIC_BASE = 4                      # token ids [4, 4+N_TOPICS) are topics


@dataclass(frozen=True)
class DataConfig:
    """Shape and distribution knobs for the synthetic data stream."""

    vocab: int = 32000
    seq_len: int = 512
    batch: int = 8
    prompt_mean: float = 44.0
    prompt_sigma: float = 0.6
    out_sigma: float = 0.35         # within-topic length spread
    max_out: int = 448
    seed: int = 0


def topic_median_len(topic: int, dc: DataConfig) -> float:
    """Topic t's median response length: geometric ladder over [8, max_out]."""
    lo, hi = 8.0, float(dc.max_out)
    f = topic / max(N_TOPICS - 1, 1)
    return lo * (hi / lo) ** f


def sample_example(rng: np.random.Generator, dc: DataConfig):
    """Draw one (topic, prompt, response) example from the topic ladder."""
    topic = int(rng.integers(0, N_TOPICS))
    plen = int(np.clip(rng.lognormal(math.log(dc.prompt_mean),
                                     dc.prompt_sigma), 4, dc.seq_len // 3))
    rlen = int(np.clip(rng.lognormal(math.log(topic_median_len(topic, dc)),
                                     dc.out_sigma), 1, dc.max_out))
    room = dc.seq_len - plen - 3
    rlen = max(1, min(rlen, room))
    prompt = rng.integers(16, dc.vocab, size=plen)
    prompt[0] = TOPIC_BASE + topic
    # topic-conditioned periodic response (progress is decodable from context)
    period = 3 + topic
    resp = 16 + ((np.arange(rlen) % period) * 37 + topic * 101) % (dc.vocab - 16)
    return topic, prompt, resp


def batches(dc: DataConfig, n_batches: int):
    """Yield ``n_batches`` token/label/remaining batches (see module doc)."""
    rng = np.random.default_rng(dc.seed)
    for _ in range(n_batches):
        tokens = np.full((dc.batch, dc.seq_len), PAD, np.int32)
        labels = np.full((dc.batch, dc.seq_len), -1, np.int32)
        remaining = np.full((dc.batch, dc.seq_len), -1, np.int32)
        for b in range(dc.batch):
            _, prompt, resp = sample_example(rng, dc)
            seq = np.concatenate([[BOS], prompt, [SEP], resp, [EOS]])
            L = len(seq)
            tokens[b, :L] = seq
            # next-token labels over the response span (incl. EOS)
            start = 1 + len(prompt) + 1            # index of first resp token
            for i in range(start - 1, L - 1):
                labels[b, i] = seq[i + 1]
            # probe labels: remaining response tokens AFTER position i
            for i in range(start - 1, L - 1):
                remaining[b, i] = (L - 1) - (i + 1)
        yield {"tokens": tokens, "labels": labels, "remaining": remaining}


def harvest_probe_data(model, params, dc: DataConfig, n_batches: int):
    """Run forward_train, collect (tap embedding, remaining-length) pairs.

    This is the paper's profiling step (Section 3.1 "Focused profiling"):
    embeddings from the tap layer for every response token, paired with the
    count of remaining tokens.
    """
    import jax.numpy as jnp
    xs, ys = [], []
    for batch in batches(dc, n_batches):
        _, aux = model.forward_train(
            params, {"tokens": jnp.asarray(batch["tokens"]),
                     "labels": jnp.asarray(batch["labels"])})
        tap = np.asarray(aux["tap"], np.float32)           # (B,S,d)
        rem = batch["remaining"]
        mask = rem >= 0
        xs.append(tap[mask])
        ys.append(rem[mask])
    return np.concatenate(xs), np.concatenate(ys)
