"""Training substrate: optimizer, data pipeline, checkpointing, train loops."""
