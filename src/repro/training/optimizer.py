"""AdamW with warmup + cosine decay, from scratch (no optax offline).

Matches the paper's probe-training recipe (Section 3.1: AdamW, cosine
annealing from 0.01 to 0) and doubles as the LM trainer's optimizer.

Moments can be stored in bfloat16 (``moment_dtype``) — on the arctic-480b
dry-run this is what keeps master params + moments within v5e HBM
(EXPERIMENTS.md section Dry-run).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    """AdamW hyperparameters plus the warmup/cosine schedule shape."""

    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.0
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(cfg: AdamWConfig, params):
    """Fresh optimizer state: zero moments (in ``moment_dtype``) + step 0."""
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def global_norm(tree):
    """L2 norm over every leaf of a pytree (float32 accumulation)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.float32(1.0)
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        """One leaf's AdamW update in float32 master arithmetic."""
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mhat = mu32 / c1
        vhat = nu32 / c2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if p.ndim >= 2 and cfg.weight_decay:    # no decay on norms/biases
            step_ = step_ + cfg.weight_decay * p32
        new_p = (p32 - lr * step_).astype(p.dtype)
        return new_p, mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "step": step,
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
