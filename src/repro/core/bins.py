"""Length-bin geometry (paper Section 3.1).

k equal-width bins over [0, max_len]; bin i covers
[max_len*i/k, max_len*(i+1)/k) with mean m_i = (b_i + b_{i+1})/2.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.config import ProbeConfig


def bin_edges(pc: ProbeConfig) -> np.ndarray:
    """Equal-width bin edges b_0..b_k over [0, max_len]."""
    return np.linspace(0.0, pc.max_len, pc.num_bins + 1)


def bin_means(pc: ProbeConfig) -> np.ndarray:
    """Bin midpoints m_i = (b_i + b_{i+1}) / 2 (the prediction values)."""
    e = bin_edges(pc)
    return (e[:-1] + e[1:]) / 2.0


def bin_index(lengths, pc: ProbeConfig):
    """Map remaining-length values to bin ids (clipped into range)."""
    idx = jnp.floor_divide(jnp.asarray(lengths), pc.bin_width).astype(jnp.int32)
    return jnp.clip(idx, 0, pc.num_bins - 1)


def log_bin_edges(pc: ProbeConfig) -> np.ndarray:
    """Beyond-paper: logarithmic bins (paper Section 6 future work)."""
    e = np.geomspace(1.0, pc.max_len, pc.num_bins)
    return np.concatenate([[0.0], e])


def bin_index_log(lengths, pc: ProbeConfig):
    """Map remaining-length values to logarithmic bin ids."""
    e = log_bin_edges(pc)
    idx = jnp.searchsorted(jnp.asarray(e[1:-1]), jnp.asarray(lengths),
                           side="right")
    return jnp.clip(idx, 0, pc.num_bins - 1).astype(jnp.int32)
