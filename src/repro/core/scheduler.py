"""SPRPT with limited preemption (paper Section 3.3) + baseline policies.

Rank function (Appendix C):

    rank(x, r, a) = r - a   if a < a0 = floor(C * r0)
                    -inf    otherwise  (non-preemptable: pinned to the batch)

where r0 is the *initial* prediction (prompt-phase probe) that fixes the
preemption budget, and the live rank uses the refined per-iteration
prediction when available (TRAIL) or r0 - a (TRAIL-BERT).

The scheduler is iteration-level: it is consulted after every decode
iteration and returns the set of requests to run next, subject to a batch
slot limit and a KV-memory budget.  Memory accounting is delegated to a
``bytes_fn(entry) -> int`` callback so the engine can supply the arch-aware
cost (dense KV grows with age; SSM state is O(1); sliding-window caches
clamp at the window — see DESIGN.md section 4).

Tail-aware extensions (both off by default; zero knobs are byte-identical):

* **Rank aging** (``age_boost`` > 0): the prediction-based ranks
  (trail / srpt / trail-bert / rank) subtract ``age_boost`` rank units
  per second a request has been in the system *beyond an* ``age_delay``
  *grace window*:

      aged rank = rank - age_boost * max(waited - age_delay, 0)

  Inside the window ordering is pure SRPT (the mean-optimal regime);
  past it a request's rank falls linearly without bound, so it cannot
  starve behind an endless stream of shorter arrivals (cf. the
  max-waiting-time starvation prevention of "Efficient LLM Scheduling
  by Learning to Rank", arXiv:2408.15792). The hinge matters: a boost
  applied uniformly from arrival shifts every queued rank at the same
  rate, so *relative* order between two waiting entries never changes
  — only the hinge lets a starving request actually catch up.
  Algebraically, once entries i and j are both past the grace window,
  i outranks j as soon as
  ``waited_i - waited_j > (base_i - base_j) / age_boost`` — the boost
  is a dial from pure SRPT ordering (0) toward FCFS (∞), which is
  exactly the direction the completion-p99 inversion on correlated
  traces calls for.
* **Deadline-aware limited preemption** (``deadline_slack`` > 0): the
  paper's C-limit makes a request non-preemptable after ``floor(C*r0)``
  *served tokens*; the deadline-slack rule generalizes it to wall-clock
  urgency — a RUNNING request whose absolute deadline
  (`SchedEntry.deadline_at`) is within ``deadline_slack`` seconds is
  pinned (rank -inf) under every preemptive policy, so near-deadline
  work is never descheduled into a discard-and-recompute it cannot
  afford.

Policies:
  fcfs        — arrival order, never preempt (vanilla vLLM)
  sjf         — shortest *initial* prediction first among waiting;
                running jobs are never preempted (vLLM-SJF_BERT)
  srpt        — SPRPT, unlimited preemption (TRAIL with C=1)
  trail       — SPRPT-LP with refined predictions (the paper's system)
  trail-bert  — SPRPT-LP with static prompt-only predictions
  rank        — learning-to-rank (Fu et al., arXiv:2408.15792): order
                the queue by an ordinal score with NO magnitude
                semantics (a rank-only predictor's output). Unlimited
                preemption like srpt, but — unlike every
                prediction-based policy above — the score is never used
                arithmetically: no preemption budget a0 (that needs
                floor(C*r0) in tokens) and no megastep lookahead
                pinning (that compares pred_remaining to a token
                count). With scores that are any strictly monotone
                transform of true remaining length, the selected batch
                is identical to srpt's.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from enum import Enum

NEG_INF = float("-inf")

POLICIES = ("fcfs", "sjf", "srpt", "trail", "trail-bert", "mlfq", "rank")

#: Policies whose ranks are ordinal only — select_batch never interprets
#: rank values as token counts for these (no lookahead pinning).
ORDINAL_POLICIES = ("mlfq", "rank")

#: Policies whose ranks age with waiting time under ``age_boost`` (the
#: prediction-ordered ones; fcfs is already arrival-ordered, sjf/mlfq are
#: the fixed related-work baselines).
AGED_POLICIES = ("trail", "srpt", "trail-bert", "rank")

# FastServe-style MLFQ (Wu et al. 2023, the paper's related-work baseline):
# priority queues by quantum thresholds on served tokens; a request demotes
# one level each time it exhausts its quantum. Prediction-free.
MLFQ_QUANTA = (16, 64, 256, 1024)


def mlfq_level(age: int) -> int:
    """MLFQ priority level for a job that has been served ``age`` tokens."""
    served = 0
    for lvl, q in enumerate(MLFQ_QUANTA):
        served += q
        if age < served:
            return lvl
    return len(MLFQ_QUANTA)


class ReqState(Enum):
    """Request lifecycle states the scheduler distinguishes."""

    WAITING = "waiting"      # never started (no cache footprint)
    RUNNING = "running"      # in the current batch
    PREEMPTED = "preempted"  # started, kicked out, cache discarded
    FINISHED = "finished"
    CANCELLED = "cancelled"  # terminated early (user cancel / deadline /
                             # load shed); cache fully released. Terminal
                             # like FINISHED: ``select_batch`` lists the
                             # live states explicitly, so cancelled
                             # entries can never be scheduled again.


@dataclass
class SchedEntry:
    """Host-side scheduling metadata for one request."""

    rid: int
    arrival: float
    prompt_len: int
    r0: float = 0.0               # initial predicted output length
    pred_remaining: float = 0.0   # refined predicted remaining length
    age: int = 0                  # output tokens generated so far
    c_limit: float = 0.8          # the paper's C
    state: ReqState = ReqState.WAITING
    prefill_done: int = 0         # chunked-prefill progress (tokens)
    prefill_left: float = 0.0     # remaining prefill work (tokens) counted
                                  # into prediction-based ranks; the engine
                                  # populates it only when cross-request
                                  # prefix caching is on (a cached prefix
                                  # shrinks remaining work, so SRPT-style
                                  # ranks must see prefill too). Default 0
                                  # keeps ranks byte-identical.
    finish_len: int = 0           # ground-truth output length (oracle/sim)
    preemptions: int = 0
    first_token_time: float = -1.0
    finish_time: float = -1.0
    deadline_at: float = 0.0      # absolute completion deadline on the
                                  # engine clock (arrival + deadline_s);
                                  # 0 = none. Drives the deadline-slack
                                  # non-preemption rule in rank().

    @property
    def a0(self) -> int:
        """The preemption budget floor(C * r0) (paper Section 3.3)."""
        return math.floor(self.c_limit * max(self.r0, 0.0))

    @property
    def preemptable(self) -> bool:
        """True while the request is within its preemption budget."""
        return self.age < self.a0

    def rank(self, policy: str, *, now: float = 0.0, age_boost: float = 0.0,
             age_delay: float = 0.0, deadline_slack: float = 0.0) -> float:
        """Policy rank (lower runs first; -inf = pinned to the batch).

        The tail-aware knobs default to zero, where the returned value is
        byte-identical to the pre-aging scheduler (both terms are gated,
        not merely multiplied by zero):

        Args:
            policy: the scheduling policy (see `POLICIES`).
            now: the engine clock — only read when a knob is active.
            age_boost: rank units subtracted per second waited beyond the
                grace window (`AGED_POLICIES` only); starvation-free for
                any value > 0.
            age_delay: grace window in seconds before aging starts —
                ordering stays pure SRPT inside it. Only read when
                ``age_boost`` > 0.
            deadline_slack: a RUNNING request whose ``deadline_at`` is
                within this many seconds is pinned (-inf) under every
                preemptive policy — deadline-aware limited preemption.
        """
        if (deadline_slack > 0.0 and policy not in ("fcfs", "sjf")
                and self.deadline_at > 0.0
                and self.state is ReqState.RUNNING
                and self.deadline_at - now <= deadline_slack):
            return NEG_INF           # pinned: inside the deadline slack
        if policy == "fcfs":
            return self.arrival
        if policy == "sjf":
            return self.r0
        if policy == "mlfq":
            return float(mlfq_level(self.age))     # FCFS tiebreak inside level
        if policy == "rank":
            # ordinal score straight from a rank-only predictor: compared,
            # never added/subtracted between entries — prefill_left (a
            # token count) cannot fold into a scale-free score. Aging
            # still applies below: the boost defines the starvation bound
            # in score units per second, a property of the dial rather
            # than of the score's magnitude semantics.
            r = self.pred_remaining
        # prediction-based remaining-time ranks; prefill_left folds the
        # (cache-aware) remaining prefill work into "remaining time" so a
        # request whose prompt prefix is already resident ranks ahead of
        # an equal-output request that still owes its whole prefill
        elif policy == "trail-bert":
            r = self.r0 - self.age + self.prefill_left
        elif policy in ("trail", "srpt"):
            r = self.pred_remaining + self.prefill_left
        else:
            raise ValueError(f"unknown policy {policy!r}")
        if (policy in ("trail", "trail-bert")
                and self.state is ReqState.RUNNING and not self.preemptable):
            return NEG_INF           # pinned: past the preemption budget
        if age_boost > 0.0:
            # rank aging: past the grace window the rank falls linearly
            # with waiting time, so any request eventually undercuts any
            # finite rank (inside the window ordering stays pure SRPT)
            r -= age_boost * max(now - self.arrival - age_delay, 0.0)
        return r


@dataclass
class Decision:
    """One ``select_batch`` outcome: who runs, who yields, who starts."""

    scheduled: list[int] = field(default_factory=list)   # rids to run
    preempted: list[int] = field(default_factory=list)   # rids kicked out
    admitted: list[int] = field(default_factory=list)    # rids newly started


def select_batch(entries: dict[int, SchedEntry], *, policy: str,
                 max_batch: int, mem_budget: int, bytes_fn,
                 lookahead: int = 1, now: float = 0.0,
                 age_boost: float = 0.0, age_delay: float = 0.0,
                 deadline_slack: float = 0.0) -> Decision:
    """Pick the next iteration's batch.

    ``lookahead`` is the number of decode tokens every scheduled row will
    generate before the scheduler is consulted again (1 for the per-token
    loop; k for the engine's k-token decode megasteps). The caller's
    ``bytes_fn`` should account for that growth (context + lookahead), and
    with lookahead > 1 the prediction-based policies additionally pin any
    RUNNING job whose predicted remaining length fits inside the upcoming
    megastep: preempting a job that would have finished within k tokens
    discards nearly-complete work for at most k tokens of relief. With the
    default lookahead=1 the decision is exactly the per-token one.

    ``now`` / ``age_boost`` / ``age_delay`` / ``deadline_slack`` are the
    tail-aware knobs forwarded into `SchedEntry.rank` (see the module
    docstring); at their zero defaults every decision is byte-identical
    to the pre-aging scheduler.

    Invariants (tested by hypothesis):
      * non-preemptable RUNNING jobs are always scheduled (policy != fcfs/sjf
        handles this via rank -inf; fcfs/sjf never preempt at all);
      * |scheduled| <= max_batch and sum(bytes) <= mem_budget (pinned jobs
        may alone exceed the budget only if they were admitted when it fit);
      * no WAITING job is scheduled while a strictly lower-rank candidate
        with room is left out (greedy by rank, FCFS tiebreak);
      * with ``age_boost`` > 0 an unpinned WAITING entry past the grace
        window that has waited ``(max_base - min_base) / age_boost``
        longer than every competitor outranks them all — waiting time is
        bounded (no starvation);
      * with ``deadline_slack`` > 0 a RUNNING entry inside its slack
        window is never preempted.
    """
    live = [e for e in entries.values()
            if e.state in (ReqState.WAITING, ReqState.RUNNING,
                           ReqState.PREEMPTED)]
    if policy in ("fcfs", "sjf"):
        # running jobs are immovable; waiting sorted by policy rank
        running = sorted((e for e in live if e.state is ReqState.RUNNING),
                         key=lambda e: e.arrival)
        waiting = sorted((e for e in live if e.state is not ReqState.RUNNING),
                         key=lambda e: (e.rank(policy), e.arrival))
        ordered = running + waiting
        must_keep = set(e.rid for e in running)
    else:
        ranks = {e.rid: e.rank(policy, now=now, age_boost=age_boost,
                               age_delay=age_delay,
                               deadline_slack=deadline_slack)
                 for e in live}
        ordered = sorted(live, key=lambda e: (ranks[e.rid], e.arrival))
        # pinned = every RUNNING entry whose rank collapsed to -inf:
        # past its C-limit preemption budget (trail/trail-bert) or inside
        # its deadline-slack window (any preemptive policy). For
        # srpt/mlfq/rank with the slack knob off this set is empty —
        # unlimited preemption, exactly the legacy behavior.
        must_keep = set(e.rid for e in live
                        if e.state is ReqState.RUNNING
                        and ranks[e.rid] == NEG_INF)
        if lookahead > 1 and policy not in ORDINAL_POLICIES:
            # mlfq has no predictions; rank scores are not token counts
            # megastep lookahead: about-to-finish jobs ride out the megastep
            must_keep |= set(
                e.rid for e in live
                if e.state is ReqState.RUNNING
                and e.pred_remaining <= lookahead)
            # lookahead-pinned jobs keep their normal (finite) rank, so
            # unlike -inf-ranked non-preemptables they would not sort
            # first: move every pinned entry to the front (stable) so
            # pinned slots/bytes are claimed before any admission — else
            # a better-ranked WAITING job could take the last slot and
            # the forced pin would oversubscribe max_batch / the pool
            ordered = ([e for e in ordered if e.rid in must_keep]
                       + [e for e in ordered if e.rid not in must_keep])

    decision = Decision()
    used_mem = 0
    used_slots = 0
    for e in ordered:
        cost = bytes_fn(e)
        pinned = e.rid in must_keep
        if not pinned and (used_slots + 1 > max_batch
                           or used_mem + cost > mem_budget):
            continue
        decision.scheduled.append(e.rid)
        used_slots += 1
        used_mem += cost
    sched = set(decision.scheduled)
    for e in live:
        if e.state is ReqState.RUNNING and e.rid not in sched:
            decision.preempted.append(e.rid)
        if e.state is not ReqState.RUNNING and e.rid in sched:
            decision.admitted.append(e.rid)
    return decision
