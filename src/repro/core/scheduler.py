"""SPRPT with limited preemption (paper Section 3.3) + baseline policies.

Rank function (Appendix C):

    rank(x, r, a) = r - a   if a < a0 = floor(C * r0)
                    -inf    otherwise  (non-preemptable: pinned to the batch)

where r0 is the *initial* prediction (prompt-phase probe) that fixes the
preemption budget, and the live rank uses the refined per-iteration
prediction when available (TRAIL) or r0 - a (TRAIL-BERT).

The scheduler is iteration-level: it is consulted after every decode
iteration and returns the set of requests to run next, subject to a batch
slot limit and a KV-memory budget.  Memory accounting is delegated to a
``bytes_fn(entry) -> int`` callback so the engine can supply the arch-aware
cost (dense KV grows with age; SSM state is O(1); sliding-window caches
clamp at the window — see DESIGN.md section 4).

Policies:
  fcfs        — arrival order, never preempt (vanilla vLLM)
  sjf         — shortest *initial* prediction first among waiting;
                running jobs are never preempted (vLLM-SJF_BERT)
  srpt        — SPRPT, unlimited preemption (TRAIL with C=1)
  trail       — SPRPT-LP with refined predictions (the paper's system)
  trail-bert  — SPRPT-LP with static prompt-only predictions
  rank        — learning-to-rank (Fu et al., arXiv:2408.15792): order
                the queue by an ordinal score with NO magnitude
                semantics (a rank-only predictor's output). Unlimited
                preemption like srpt, but — unlike every
                prediction-based policy above — the score is never used
                arithmetically: no preemption budget a0 (that needs
                floor(C*r0) in tokens) and no megastep lookahead
                pinning (that compares pred_remaining to a token
                count). With scores that are any strictly monotone
                transform of true remaining length, the selected batch
                is identical to srpt's.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from enum import Enum

NEG_INF = float("-inf")

POLICIES = ("fcfs", "sjf", "srpt", "trail", "trail-bert", "mlfq", "rank")

#: Policies whose ranks are ordinal only — select_batch never interprets
#: rank values as token counts for these (no lookahead pinning).
ORDINAL_POLICIES = ("mlfq", "rank")

# FastServe-style MLFQ (Wu et al. 2023, the paper's related-work baseline):
# priority queues by quantum thresholds on served tokens; a request demotes
# one level each time it exhausts its quantum. Prediction-free.
MLFQ_QUANTA = (16, 64, 256, 1024)


def mlfq_level(age: int) -> int:
    """MLFQ priority level for a job that has been served ``age`` tokens."""
    served = 0
    for lvl, q in enumerate(MLFQ_QUANTA):
        served += q
        if age < served:
            return lvl
    return len(MLFQ_QUANTA)


class ReqState(Enum):
    """Request lifecycle states the scheduler distinguishes."""

    WAITING = "waiting"      # never started (no cache footprint)
    RUNNING = "running"      # in the current batch
    PREEMPTED = "preempted"  # started, kicked out, cache discarded
    FINISHED = "finished"
    CANCELLED = "cancelled"  # terminated early (user cancel / deadline /
                             # load shed); cache fully released. Terminal
                             # like FINISHED: ``select_batch`` lists the
                             # live states explicitly, so cancelled
                             # entries can never be scheduled again.


@dataclass
class SchedEntry:
    """Host-side scheduling metadata for one request."""
    rid: int
    arrival: float
    prompt_len: int
    r0: float = 0.0               # initial predicted output length
    pred_remaining: float = 0.0   # refined predicted remaining length
    age: int = 0                  # output tokens generated so far
    c_limit: float = 0.8          # the paper's C
    state: ReqState = ReqState.WAITING
    prefill_done: int = 0         # chunked-prefill progress (tokens)
    prefill_left: float = 0.0     # remaining prefill work (tokens) counted
                                  # into prediction-based ranks; the engine
                                  # populates it only when cross-request
                                  # prefix caching is on (a cached prefix
                                  # shrinks remaining work, so SRPT-style
                                  # ranks must see prefill too). Default 0
                                  # keeps ranks byte-identical.
    finish_len: int = 0           # ground-truth output length (oracle/sim)
    preemptions: int = 0
    first_token_time: float = -1.0
    finish_time: float = -1.0

    @property
    def a0(self) -> int:
        """The preemption budget floor(C * r0) (paper Section 3.3)."""
        return math.floor(self.c_limit * max(self.r0, 0.0))

    @property
    def preemptable(self) -> bool:
        """True while the request is within its preemption budget."""
        return self.age < self.a0

    def rank(self, policy: str) -> float:
        """Policy rank (lower runs first; -inf = pinned to the batch)."""
        if policy == "fcfs":
            return self.arrival
        if policy == "sjf":
            return self.r0
        if policy == "mlfq":
            return float(mlfq_level(self.age))     # FCFS tiebreak inside level
        if policy == "rank":
            # ordinal score straight from a rank-only predictor: compared,
            # never added/subtracted — prefill_left (a token count) cannot
            # fold into a scale-free score
            return self.pred_remaining
        # prediction-based remaining-time ranks; prefill_left folds the
        # (cache-aware) remaining prefill work into "remaining time" so a
        # request whose prompt prefix is already resident ranks ahead of
        # an equal-output request that still owes its whole prefill
        if policy == "trail-bert":
            r = self.r0 - self.age + self.prefill_left
        elif policy in ("trail", "srpt"):
            r = self.pred_remaining + self.prefill_left
        else:
            raise ValueError(f"unknown policy {policy!r}")
        if policy != "srpt" and self.state is ReqState.RUNNING and not self.preemptable:
            return NEG_INF           # pinned: past the preemption budget
        return r


@dataclass
class Decision:
    """One ``select_batch`` outcome: who runs, who yields, who starts."""

    scheduled: list[int] = field(default_factory=list)   # rids to run
    preempted: list[int] = field(default_factory=list)   # rids kicked out
    admitted: list[int] = field(default_factory=list)    # rids newly started


def select_batch(entries: dict[int, SchedEntry], *, policy: str,
                 max_batch: int, mem_budget: int, bytes_fn,
                 lookahead: int = 1) -> Decision:
    """Pick the next iteration's batch.

    ``lookahead`` is the number of decode tokens every scheduled row will
    generate before the scheduler is consulted again (1 for the per-token
    loop; k for the engine's k-token decode megasteps). The caller's
    ``bytes_fn`` should account for that growth (context + lookahead), and
    with lookahead > 1 the prediction-based policies additionally pin any
    RUNNING job whose predicted remaining length fits inside the upcoming
    megastep: preempting a job that would have finished within k tokens
    discards nearly-complete work for at most k tokens of relief. With the
    default lookahead=1 the decision is exactly the per-token one.

    Invariants (tested by hypothesis):
      * non-preemptable RUNNING jobs are always scheduled (policy != fcfs/sjf
        handles this via rank -inf; fcfs/sjf never preempt at all);
      * |scheduled| <= max_batch and sum(bytes) <= mem_budget (pinned jobs
        may alone exceed the budget only if they were admitted when it fit);
      * no WAITING job is scheduled while a strictly lower-rank candidate
        with room is left out (greedy by rank, FCFS tiebreak).
    """
    live = [e for e in entries.values()
            if e.state in (ReqState.WAITING, ReqState.RUNNING,
                           ReqState.PREEMPTED)]
    if policy in ("fcfs", "sjf"):
        # running jobs are immovable; waiting sorted by policy rank
        running = sorted((e for e in live if e.state is ReqState.RUNNING),
                         key=lambda e: e.arrival)
        waiting = sorted((e for e in live if e.state is not ReqState.RUNNING),
                         key=lambda e: (e.rank(policy), e.arrival))
        ordered = running + waiting
        must_keep = set(e.rid for e in running)
    else:
        ordered = sorted(live, key=lambda e: (e.rank(policy), e.arrival))
        # srpt/mlfq/rank = unlimited preemption: nothing is pinned
        must_keep = set() if policy in ("srpt", "mlfq", "rank") else set(
            e.rid for e in live
            if e.state is ReqState.RUNNING and not e.preemptable)
        if lookahead > 1 and policy not in ORDINAL_POLICIES:
            # mlfq has no predictions; rank scores are not token counts
            # megastep lookahead: about-to-finish jobs ride out the megastep
            must_keep |= set(
                e.rid for e in live
                if e.state is ReqState.RUNNING
                and e.pred_remaining <= lookahead)
            # lookahead-pinned jobs keep their normal (finite) rank, so
            # unlike -inf-ranked non-preemptables they would not sort
            # first: move every pinned entry to the front (stable) so
            # pinned slots/bytes are claimed before any admission — else
            # a better-ranked WAITING job could take the last slot and
            # the forced pin would oversubscribe max_batch / the pool
            ordered = ([e for e in ordered if e.rid in must_keep]
                       + [e for e in ordered if e.rid not in must_keep])

    decision = Decision()
    used_mem = 0
    used_slots = 0
    for e in ordered:
        cost = bytes_fn(e)
        pinned = e.rid in must_keep
        if not pinned and (used_slots + 1 > max_batch
                           or used_mem + cost > mem_budget):
            continue
        decision.scheduled.append(e.rid)
        used_slots += 1
        used_mem += cost
    sched = set(decision.scheduled)
    for e in live:
        if e.state is ReqState.RUNNING and e.rid not in sched:
            decision.preempted.append(e.rid)
        if e.state is not ReqState.RUNNING and e.rid in sched:
            decision.admitted.append(e.rid)
    return decision
