"""Bayesian per-iteration refinement of the probe's bin distribution.

Paper Section 3.1 + Appendix A. The remaining length shrinks by one each
iteration, so probability mass drifts from bin B_{i+1} into B_i at rate
1/bin_size (uniform-within-bin assumption). The filter is:

  q_prior(t) = T @ q(t-1)
  q(t)(i)    = q_prior(t)(i) * p(t)(i) / sum_j q_prior(t)(j) * p(t)(j)

with the bidiagonal transition matrix
  T[i, i]   = 1 - 1/bin_size
  T[i, i+1] = 1/bin_size          (drift from B_{i+1} to B_i)

All functions are batched: q, p are (..., k).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ProbeConfig
from repro.core.bins import bin_means


def transition_matrix(pc: ProbeConfig) -> np.ndarray:
    """Appendix A matrix; computed once from bin sizes."""
    k = pc.num_bins
    r = 1.0 / pc.bin_width
    T = np.eye(k) * (1.0 - r)
    for i in range(k - 1):
        T[i, i + 1] = r
    T[0, 0] = 1.0    # bin 0 absorbs (request finishes from B_0)
    return T


def bayes_update(q_prev, p_t, T) -> jax.Array:
    """One filter step. q_prev, p_t: (..., k); T: (k, k). Returns q_t."""
    prior = q_prev @ jnp.asarray(T, q_prev.dtype).T
    post = prior * p_t
    z = jnp.sum(post, axis=-1, keepdims=True)
    return jnp.where(z > 0, post / jnp.maximum(z, 1e-30), prior)


def expected_length(q, pc: ProbeConfig) -> jax.Array:
    """L_t = sum_i q(i) * m_i  (paper Section 3.1)."""
    m = jnp.asarray(bin_means(pc), q.dtype)
    return q @ m


def refine_sequence(p_seq, pc: ProbeConfig) -> jax.Array:
    """Filter a whole prediction sequence (offline eval): p_seq (T,k) -> q (T,k)."""
    T = jnp.asarray(transition_matrix(pc))

    def step(q, p):
        """One Bayes filter update over the scan carry."""
        qn = bayes_update(q, p, T)
        return qn, qn

    q0 = p_seq[0]
    _, qs = jax.lax.scan(step, q0, p_seq[1:])
    return jnp.concatenate([q0[None], qs], axis=0)
