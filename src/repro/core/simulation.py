"""Discrete-event M/G/1 simulator for SPRPT with limited preemption.

Paper Appendix D, with age-proportional memory tracking.

Single server, Poisson(lam) arrivals, Exp(1) service times, predictions
either perfect or exponential around the true size. Policies:

  fcfs / sjf (non-preemptive) / spjf (same as sjf) / srpt (C=inf ~ C=1 in
  paper notation: always preemptable) / sprpt-lp (preemption only while
  age < C * r).

Rank dynamics make event-driven simulation exact: between events the served
job's rank (r - a) only decreases, so preemption can only happen at arrival
or completion instants.

Memory model (Appendix D): a started-but-unfinished job holds memory equal
to its age (service received so far); we track the time series of total
memory and report peak and mean, plus mean/median response times.
"""

from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass, field


@dataclass
class SimJob:
    """One job in the M/G/1 token-level simulation."""

    jid: int
    arrival: float
    size: float
    pred: float
    served: float = 0.0
    done_at: float = -1.0

    def remaining(self) -> float:
        """True remaining service (size minus served)."""
        return self.size - self.served

    def pred_remaining(self) -> float:
        """Predicted remaining service (rank signal)."""
        # NOTE: unclamped, matching the analyzed rank r - a (an overrun job's
        # rank keeps falling, so it keeps its priority rather than ties at 0).
        return self.pred - self.served


@dataclass
class SimResult:
    """Aggregates of one simulate() run (response times, memory)."""

    mean_response: float
    median_response: float
    peak_memory: float
    mean_memory: float
    n_jobs: int
    preemptions: int
    responses: list[float] = field(default_factory=list)


def _rank(job: SimJob, policy: str, C: float) -> float:
    """Policy rank (lower = served first); Appendix C rank functions."""
    if policy == "fcfs":
        return job.arrival
    if policy in ("sjf", "spjf"):
        return job.pred
    if policy == "srpt":
        return job.pred_remaining()
    if policy == "sprpt-lp":
        if job.served >= C * job.pred and job.served > 0:
            return float("-inf")            # non-preemptable once past a0
        return job.pred_remaining()
    raise ValueError(policy)


def simulate(policy: str, lam: float, *, C: float = 0.8, n_jobs: int = 20000,
             prediction: str = "exponential", seed: int = 0,
             warmup_frac: float = 0.1) -> SimResult:
    """Event-driven M/G/1 simulation of one scheduling policy."""
    rng = random.Random(seed)
    # pre-generate arrivals
    jobs: list[SimJob] = []
    t = 0.0
    for j in range(n_jobs):
        t += rng.expovariate(lam)
        size = rng.expovariate(1.0)
        if prediction == "perfect":
            pred = size
        elif prediction == "exponential":
            pred = rng.expovariate(1.0 / size) if size > 0 else 0.0
        else:
            raise ValueError(prediction)
        jobs.append(SimJob(j, t, size, pred))

    # event loop
    now = 0.0
    idx = 0                      # next arrival index
    system: list[SimJob] = []    # jobs in system (waiting or served)
    current: SimJob | None = None
    responses = []
    preemptions = 0
    mem_area = 0.0               # integral of memory over time
    peak_mem = 0.0
    last_t = 0.0
    non_preempt = policy in ("fcfs", "sjf", "spjf")

    def memory() -> float:
        """Held state: served work across jobs in system (Appendix D)."""
        return sum(j.served for j in system)

    def pick() -> SimJob | None:
        """Next job to serve under the policy rank (FCFS tiebreak)."""
        if not system:
            return None
        if non_preempt and current in system:
            return current
        return min(system, key=lambda j: (_rank(j, policy, C), j.arrival))

    while idx < n_jobs or system:
        next_arrival = jobs[idx].arrival if idx < n_jobs else math.inf
        if current is not None:
            completion = now + current.remaining()
        else:
            completion = math.inf
        t_next = min(next_arrival, completion)

        # integrate memory over [now, t_next]; served job's age grows linearly
        dt = t_next - now
        m0 = memory()
        m1 = m0 + (dt if current is not None else 0.0)
        mem_area += (m0 + m1) / 2.0 * dt
        peak_mem = max(peak_mem, m1)
        if current is not None:
            current.served += dt
        now = t_next

        if completion <= next_arrival and current is not None:
            current.served = current.size
            current.done_at = now
            system.remove(current)
            responses.append(now - current.arrival)
            current = None
        else:
            system.append(jobs[idx])
            idx += 1
        prev = current
        current = pick()
        if prev is not None and current is not prev and prev in system:
            preemptions += 1
        last_t = now

    # drop warmup
    k = int(len(responses) * warmup_frac)
    rs = sorted(responses[k:])
    mean_r = sum(rs) / max(len(rs), 1)
    med_r = rs[len(rs) // 2] if rs else 0.0
    return SimResult(mean_response=mean_r, median_response=med_r,
                     peak_memory=peak_mem,
                     mean_memory=mem_area / max(last_t, 1e-9),
                     n_jobs=len(rs), preemptions=preemptions,
                     responses=rs)


def sweep(policy: str, lams, *, C: float = 0.8, n_jobs: int = 20000,
          prediction: str = "exponential", seed: int = 0):
    """simulate() across arrival rates; returns {lam: SimResult}."""
    return {lam: simulate(policy, lam, C=C, n_jobs=n_jobs,
                          prediction=prediction, seed=seed) for lam in lams}
