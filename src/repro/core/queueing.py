"""Lemma 1: closed-form M/G/1 mean response time for SPRPT-LP.

Evaluated numerically via the SOAP decomposition (Appendix C of the
paper; Scully & Harchol-Balter 2018):

    E[T(x,r)] = lambda * (I1(r) + I2(r, a0)) / (2 (1 - rho'_r)^2)
              + int_0^{min(x, a0)} da / (1 - rho'_{(r-a)+})
              + max(x - a0, 0)

    rho'_r    = lambda * int_{y<=r} int_x x   g(x,y) dx dy
    I1(r)     =          int_{y<=r} int_x x^2 g(x,y) dx dy
    I2(r,a0)  = int_{t>=r+a0} int_{x>=t-r} g(x,t) (x-(t-r))^2 dx dt

The paper writes the residence term as int_0^{a0} + (x - a0); for x < a0 the
job finishes while still preemptable, so we evaluate the natural
generalization with min(x, a0) and (x-a0)^+ (identical when x >= a0, the
regime the paper considers). a0 = C * r.

Prediction models (Appendix D):
  * exponential: g(x, y) = f(x) * exp(-y/x) / x  (prediction ~ Exp(mean x))
  * perfect:     g(x, y) = f(x) * delta(y - x)

All inner integrals are precomputed once on a grid (cumulative trapezoid)
and interpolated, so a full E[T] evaluation is vectorized numpy. The test
suite cross-validates against the discrete-event simulator.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class MG1Config:
    """Parameters of the Appendix-D M/G/1 SPRPT-LP closed form."""

    lam: float = 0.5            # Poisson arrival rate (rho = lam * E[X] < 1)
    C: float = 0.8              # preemption budget multiplier
    prediction: str = "exponential"   # "exponential" | "perfect"
    x_max: float = 16.0         # integration cutoff (Exp(1) tail ~ e^-16)
    n_grid: int = 400


def service_density(x):
    """f(x) = e^{-x} (exponential, mean 1, as in Appendix D)."""
    return np.exp(-x)


class Lemma1:
    """Precomputed SOAP terms for one (lam, C, prediction model)."""

    def __init__(self, cfg: MG1Config):
        self.cfg = cfg
        n = cfg.n_grid
        self.xs = np.linspace(1e-4, cfg.x_max, n)
        xs = self.xs
        if cfg.prediction == "perfect":
            # g(x,y) = f(x) delta(y-x): moments below r collapse to x <= r
            fx = service_density(xs)
            self._m1 = _cumtrapz(xs * fx, xs)            # int_{x<=r} x f
            self._m2 = _cumtrapz(xs ** 2 * fx, xs)
        else:
            # m_k(y) = int_x x^k g(x,y) dx  on a y grid, then cumint over y
            ys = xs
            X, Y = np.meshgrid(xs, ys, indexing="ij")
            G = service_density(X) * np.exp(-Y / X) / X
            m1y = np.trapezoid(X * G, xs, axis=0)        # (n_y,)
            m2y = np.trapezoid(X ** 2 * G, xs, axis=0)
            self._m1 = _cumtrapz(m1y, ys)
            self._m2 = _cumtrapz(m2y, ys)

    # -- interpolated terms -------------------------------------------------
    def rho_prime(self, r):
        """Truncated load rho'(r) = lam * E[min(X, r)-ish mass below r]."""
        return self.cfg.lam * np.interp(r, self.xs, self._m1)

    def i1(self, r):
        """Second moment of service mass below rank r (interpolated)."""
        return np.interp(r, self.xs, self._m2)

    def i2(self, r):
        """Recycled second moment; depends on r via a0 = C r."""
        cfg = self.cfg
        a0 = cfg.C * r
        if cfg.prediction == "perfect":
            # t = x: recycled iff x - r >= a0; served r each -> r^2 * P(x >= r+a0)
            return r * r * np.exp(-(r + a0))
        ts = np.linspace(r + a0 + 1e-5, cfg.x_max + r + a0, 300)
        xs = self.xs
        X = xs[None, :]
        Tm = ts[:, None]
        G = service_density(X) * np.exp(-Tm / X) / X
        w = np.where(X >= (Tm - r), (X - (Tm - r)) ** 2, 0.0)
        inner = np.trapezoid(G * w, xs, axis=1)
        return float(np.trapezoid(inner, ts))

    def response_xr(self, x, r):
        """E[T(x, r)] per Lemma 1."""
        cfg = self.cfg
        a0 = cfg.C * r
        rp = self.rho_prime(r)
        wait = cfg.lam * (self.i1(r) + self.i2(r)) / (2.0 * (1.0 - rp) ** 2)
        a_hi = min(x, a0)
        a_grid = np.linspace(0.0, max(a_hi, 1e-9), 160)
        denom = 1.0 - self.rho_prime(np.maximum(r - a_grid, 0.0))
        residence = np.trapezoid(1.0 / denom, a_grid) + max(x - a0, 0.0)
        return float(wait + residence)

    def mean_response(self, n_xr: int = 32):
        """E[T] = E_{(x,r)~g}[T(x,r)].

        For the exponential model the prediction scales with x, so we
        integrate with the substitution r = x*u, u ~ Exp(1): a linear grid
        in r cannot resolve the conditional density for small x.
        """
        cfg = self.cfg
        xs = np.linspace(0.02, cfg.x_max * 0.7, n_xr)
        wx = service_density(xs)
        if cfg.prediction == "perfect":
            vals = np.array([self.response_xr(x, x) for x in xs])
            return float(np.trapezoid(vals * wx, xs) / np.trapezoid(wx, xs))
        us = np.linspace(1e-3, 8.0, 48)
        wu = np.exp(-us)
        vals = np.array([
            np.trapezoid(np.array([self.response_xr(x, x * u) for u in us]) * wu, us)
            / np.trapezoid(wu, us)
            for x in xs])
        return float(np.trapezoid(vals * wx, xs) / np.trapezoid(wx, xs))


def _cumtrapz(y, x):
    """Cumulative trapezoidal integral of y over grid x."""
    out = np.zeros_like(y)
    out[1:] = np.cumsum((y[1:] + y[:-1]) / 2.0 * np.diff(x))
    return out


def mean_response(cfg: MG1Config, n_xr: int = 32) -> float:
    """Mean response time of the Lemma-1 closed form under ``cfg``."""
    return Lemma1(cfg).mean_response(n_xr)


def sweep_C(lam: float, cs, prediction: str = "exponential"):
    """Theory curve for the Appendix-D comparison."""
    return {c: mean_response(MG1Config(lam=lam, C=c, prediction=prediction))
            for c in cs}
