"""TRAIL core: the paper's contribution.

  predictor   — probe MLP on recycled layer embeddings (Section 3.1)
  smoothing   — Bayesian per-iteration refinement (Section 3.1, Appendix A)
  bins        — length-bin geometry shared by predictor/smoothing
  scheduler   — SPRPT with limited preemption (Section 3.3)
  queueing    — Lemma 1 closed form via SOAP terms (Appendix C)
  simulation  — M/G/1 discrete-event simulator w/ memory tracking (Appendix D)
"""
