"""The probe: remaining-output-length classifier on recycled embeddings.

Paper Section 3.1: a 2-layer MLP (d_model -> 512 -> k bins, ReLU) applied to
the tap layer's hidden state of the *serving model itself*:
  * prompt phase: input = mean of all prompt-token embeddings at the tap layer
  * decode phase: input = the embedding of the token just generated

This module also implements the prompt-only baseline predictor ("BERT" in the
paper: a one-shot classifier that sees only the prompt). Offline we cannot
ship a pretrained DistilBERT, so the baseline is the same probe architecture
reading the *first* (embedding-layer) representation of the prompt — the same
information regime as S^3's BERT: prompt only, no recycling, no refinement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ProbeConfig


def init_probe(key, d_model: int, pc: ProbeConfig) -> dict:
    """Initialize the 2-layer MLP probe head (paper Section 3.1)."""
    k1, k2 = jax.random.split(key)
    s1, s2 = d_model ** -0.5, pc.hidden ** -0.5
    return {
        "w1": jax.random.normal(k1, (d_model, pc.hidden), jnp.float32) * s1,
        "b1": jnp.zeros((pc.hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (pc.hidden, pc.num_bins), jnp.float32) * s2,
        "b2": jnp.zeros((pc.num_bins,), jnp.float32),
    }


def apply_probe(p, x) -> jax.Array:
    """x: (..., d_model) -> logits (..., num_bins). float32 throughout."""
    h = jax.nn.relu(x.astype(jnp.float32) @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def probe_probs(p, x) -> jax.Array:
    """Softmax bin posterior of the probe at embeddings ``x``."""
    return jax.nn.softmax(apply_probe(p, x), axis=-1)


def probe_loss(p, x, bin_labels) -> jax.Array:
    """Cross-entropy over bins. x: (N,d); bin_labels: (N,) int32."""
    logits = apply_probe(p, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, bin_labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def probe_accuracy(p, x, bin_labels) -> jax.Array:
    """Top-1 bin accuracy of the probe against gold labels."""
    return jnp.mean(jnp.argmax(apply_probe(p, x), -1) == bin_labels)
