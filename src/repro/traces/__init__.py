"""Trace ingestion, synthesis, and open-loop replay.

The evaluation path the paper's distributional claims need: load (or
synthesize) an Azure-LLM-inference-style request trace, rescale it with
time-warp/rate-scale knobs, and replay it open-loop into an `Engine` or
cluster `Router` while the metrics layer (`repro.metrics`) captures
per-request events. See ``docs/ARCHITECTURE.md`` § Trace-driven
evaluation.
"""

from repro.traces.loaders import (load_csv, load_jsonl, load_trace,
                                  sample_trace_path, save_jsonl)
from repro.traces.replay import ReplayConfig, replay, requests_from_trace
from repro.traces.schema import Trace, TraceRecord, normalize
from repro.traces.synthesis import (SAMPLE_CONFIG, SynthesisConfig,
                                    TenantTraceSpec, sample_trace,
                                    synthesize)

__all__ = [
    "Trace",
    "TraceRecord",
    "normalize",
    "load_csv",
    "load_jsonl",
    "load_trace",
    "sample_trace_path",
    "save_jsonl",
    "ReplayConfig",
    "replay",
    "requests_from_trace",
    "SynthesisConfig",
    "TenantTraceSpec",
    "SAMPLE_CONFIG",
    "sample_trace",
    "synthesize",
]
