"""Open-loop trace replay: turn a `Trace` into engine/router arrivals.

Two pieces:

* `requests_from_trace` materializes `Request` objects — deterministic
  prompt token content from the replay seed, arrival times rescaled by
  the `ReplayConfig` time-warp / rate-scale knobs. Same trace + same
  config → byte-identical requests, which is what makes replayed
  metrics reproducible bit-for-bit.
* `replay` drives a single `Engine` (or a cluster `Router`) through the
  arrival stream **open-loop**: arrivals are submitted in virtual time
  regardless of completions — a saturated engine falls behind rather
  than back-pressuring the trace, exactly how production load arrives.
"""

from __future__ import annotations

import random
from dataclasses import dataclass

from repro.serving.request import Request
from repro.traces.schema import Trace


@dataclass(frozen=True)
class ReplayConfig:
    """Knobs shaping one replay of a trace.

    Attributes:
        rate_scale: arrival-rate multiplier — inter-arrival gaps divide
            by it, preserving the trace's burst structure while sweeping
            load (the benchmark's x-axis).
        time_warp: uniform playback-speed multiplier applied to the
            whole time axis. Mathematically it composes with
            ``rate_scale`` (both divide timestamps); keep it at 1.0 for
            load sweeps and use it for coarse fast-forward of very long
            traces.
        limit: replay only the first N records (None = all).
        max_prompt: prompt-length clip in tokens (trace outliers would
            otherwise dwarf every cache budget).
        max_output: output-length clip in tokens.
        seed: drives prompt token content (not lengths or arrivals —
            those come from the trace).
        vocab: vocabulary for the synthesized prompt token ids.
    """

    rate_scale: float = 1.0
    time_warp: float = 1.0
    limit: int | None = None
    max_prompt: int = 2048
    max_output: int = 512
    seed: int = 0
    vocab: int = 32000


def requests_from_trace(trace: Trace,
                        rcfg: ReplayConfig = ReplayConfig()) -> list[Request]:
    """Materialize a trace into arrival-sorted `Request` objects.

    Token content is synthesized from one dedicated RNG stream keyed on
    ``rcfg.seed`` (the trace only records lengths), so identical
    (trace, config) pairs produce identical requests — including across
    processes (string seeding hashes via sha512, not PYTHONHASHSEED).
    """
    if rcfg.rate_scale <= 0 or rcfg.time_warp <= 0:
        raise ValueError("rate_scale and time_warp must be positive")
    tok_rng = random.Random(f"{rcfg.seed}:trace-content")
    scale = rcfg.rate_scale * rcfg.time_warp
    records = trace.records[:rcfg.limit]
    reqs = []
    for rid, rec in enumerate(records):
        plen = max(1, min(rec.prompt_tokens, rcfg.max_prompt))
        olen = max(1, min(rec.output_tokens, rcfg.max_output))
        prompt = [tok_rng.randrange(1, rcfg.vocab) for _ in range(plen)]
        reqs.append(Request(rid=rid, arrival=rec.arrival / scale,
                            prompt=prompt, true_out_len=olen,
                            max_new_tokens=rcfg.max_output,
                            tenant=rec.tenant))
    return reqs


def replay(target, requests: list[Request]):
    """Feed an arrival stream open-loop and drive the target to drain.

    Both targets implement open-loop virtual-time feeding already — the
    `Router` dispatches each arrival once the replica clocks reach it,
    and the `Engine` gates admission of submitted arrivals on its own
    clock — so this driver delegates to their canonical ``run()`` loops
    rather than re-implementing (and risking drift from) them. Arrivals
    land at their trace timestamps regardless of completions; a
    saturated target falls behind instead of back-pressuring the trace.

    Args:
        target: an `Engine` (incremental ``submit()``/``step()`` API) or
            a cluster `Router` over N replica engines.
        requests: arrival-sorted `Request` objects (from
            `requests_from_trace` or any workload generator).

    Returns:
        The target's stats object — `EngineStats` for an engine,
        `ClusterStats` for a router.
    """
    return target.run(requests)
