"""Trace schema: the normalized form every trace source reduces to.

A trace is a sequence of `TraceRecord`s — (arrival, prompt_tokens,
output_tokens, tenant) — matching the public Azure LLM inference trace
shape (TIMESTAMP / ContextTokens / GeneratedTokens). Loaders normalize
arbitrary column namings and time bases into this one schema so the
replay driver, synthesis, and benchmarks never see source quirks.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class TraceRecord:
    """One request observation from a serving trace.

    Attributes:
        arrival: arrival time in seconds from the trace start (the
            loaders rebase so the first arrival is 0.0).
        prompt_tokens: prompt / context length in tokens.
        output_tokens: generated / output length in tokens.
        tenant: optional workload tag (empty for single-stream traces).
    """

    arrival: float
    prompt_tokens: int
    output_tokens: int
    tenant: str = ""

    def as_dict(self) -> dict:
        """JSONL-record form (the bundled fixture's on-disk schema)."""
        d = {"ts": self.arrival, "context_tokens": self.prompt_tokens,
             "generated_tokens": self.output_tokens}
        if self.tenant:
            d["tenant"] = self.tenant
        return d


@dataclass
class Trace:
    """A normalized request trace plus its provenance metadata.

    Attributes:
        records: arrival-sorted `TraceRecord`s, rebased to start at 0.
        name: source identifier (file stem or synthesis tag).
        meta: free-form provenance (source path, synthesis config, ...).
    """

    records: list[TraceRecord]
    name: str = "trace"
    meta: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.records)

    @property
    def duration(self) -> float:
        """Span from first to last arrival, in seconds."""
        if not self.records:
            return 0.0
        return self.records[-1].arrival - self.records[0].arrival

    @property
    def mean_rate(self) -> float:
        """Long-run mean arrival rate (req/s) over the trace span."""
        if len(self.records) < 2 or self.duration <= 0:
            return 0.0
        return (len(self.records) - 1) / self.duration

    def stats(self) -> dict:
        """Shape summary: counts, rate, and length means (for artifacts)."""
        n = len(self.records)
        if not n:
            return {"n": 0}
        return {
            "n": n,
            "duration_s": self.duration,
            "mean_rate": self.mean_rate,
            "mean_prompt_tokens":
                sum(r.prompt_tokens for r in self.records) / n,
            "mean_output_tokens":
                sum(r.output_tokens for r in self.records) / n,
            "tenants": sorted({r.tenant for r in self.records if r.tenant}),
        }


def normalize(records: list[TraceRecord], name: str = "trace",
              meta: dict | None = None) -> Trace:
    """Sort by arrival, rebase to t=0, and wrap into a `Trace`.

    Records with non-positive lengths are clamped to 1 token — zero
    -length rows occur in real exports (failed requests) and would
    otherwise wedge the engine's finish condition.
    """
    recs = sorted(records, key=lambda r: r.arrival)
    t0 = recs[0].arrival if recs else 0.0
    recs = [TraceRecord(arrival=r.arrival - t0,
                        prompt_tokens=max(int(r.prompt_tokens), 1),
                        output_tokens=max(int(r.output_tokens), 1),
                        tenant=r.tenant)
            for r in recs]
    return Trace(records=recs, name=name, meta=dict(meta or {}))
