"""Synthetic trace synthesis with per-tenant correlated length marginals.

The synthetic scenario library (`serving/workload.py`) draws prompt and
output lengths *independently*, but real traces correlate them — long
contexts beget long answers in chat, and RAG tenants pair huge prompts
with terse outputs (negative correlation). Prediction-based schedulers
are sensitive to exactly this structure (Mitzenmacher & Shahout 2025),
so the trace subsystem can synthesize it directly:

* **Gaussian copula** (default): per tenant, draw correlated standard
  normals ``(z_p, z_o)`` with correlation ρ and push them through the
  lognormal marginals ``exp(μ + σ z)`` — with lognormal marginals the
  copula is exact and Pearson-in-log = ρ.
* **rank shuffle**: draw both marginals independently, then reorder the
  output column so its ranks follow a ρ-correlated latent — keeps the
  marginals *exactly* as drawn (any distribution), at the cost of only
  rank-level (Spearman) correlation control.

Arrivals are homogeneous Poisson at the configured mean rate with
tenant choice by weight. Everything derives from one seed — the bundled
``data/azure_llm_sample.jsonl`` fixture is `sample_trace()` written to
disk, and `tests/test_traces.py` re-generates it to prove the checked-in
bytes match the code.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.traces.schema import Trace, TraceRecord, normalize


@dataclass(frozen=True)
class TenantTraceSpec:
    """Length-distribution spec for one tenant in a synthesized trace.

    Attributes:
        name: tenant tag stamped onto the records.
        weight: sampling weight (normalized over the mix).
        prompt_median: lognormal median prompt length (tokens).
        prompt_sigma: lognormal sigma of prompt lengths.
        out_median: lognormal median output length (tokens).
        out_sigma: lognormal sigma of output lengths.
        rho: prompt/output correlation in copula space (-1..1).
    """

    name: str
    weight: float = 1.0
    prompt_median: float = 44.0
    prompt_sigma: float = 0.6
    out_median: float = 48.0
    out_sigma: float = 1.0
    rho: float = 0.0


@dataclass(frozen=True)
class SynthesisConfig:
    """Parameters for one synthesized trace.

    Attributes:
        n_requests: number of records.
        mean_rate: Poisson arrival rate (req/s).
        tenants: the tenant mix (at least one spec).
        method: ``copula`` | ``rank-shuffle`` (see module docstring).
        max_prompt: prompt-length clip (tokens).
        max_output: output-length clip (tokens).
        seed: master seed; every draw derives from it.
    """

    n_requests: int = 300
    mean_rate: float = 0.5
    tenants: tuple = (TenantTraceSpec("default"),)
    method: str = "copula"
    max_prompt: int = 2048
    max_output: int = 512
    seed: int = 0


def _correlated_normals(rng: np.random.Generator, n: int,
                        rho: float) -> tuple[np.ndarray, np.ndarray]:
    """n draws of (z1, z2) standard normals with correlation rho."""
    z1 = rng.standard_normal(n)
    z2 = rho * z1 + np.sqrt(max(1.0 - rho * rho, 0.0)) \
        * rng.standard_normal(n)
    return z1, z2


def _lengths_copula(rng, spec: TenantTraceSpec, n: int):
    z_p, z_o = _correlated_normals(rng, n, spec.rho)
    prompts = np.exp(np.log(spec.prompt_median) + spec.prompt_sigma * z_p)
    outs = np.exp(np.log(spec.out_median) + spec.out_sigma * z_o)
    return prompts, outs


def _lengths_rank_shuffle(rng, spec: TenantTraceSpec, n: int):
    prompts = rng.lognormal(np.log(spec.prompt_median), spec.prompt_sigma, n)
    outs = rng.lognormal(np.log(spec.out_median), spec.out_sigma, n)
    # reorder the independently-drawn outputs so their ranks follow a
    # rho-correlated latent: marginals stay exactly as drawn
    z_p, z_latent = _correlated_normals(rng, n, spec.rho)
    order_p = np.argsort(np.argsort(prompts))       # rank of each prompt
    # give row i the output whose rank matches the latent's rank at the
    # same prompt-rank position
    latent_by_prompt_rank = z_latent[np.argsort(z_p)]
    out_rank_for_prompt_rank = np.argsort(np.argsort(latent_by_prompt_rank))
    outs_sorted = np.sort(outs)
    return prompts, outs_sorted[out_rank_for_prompt_rank[order_p]]


def synthesize(sc: SynthesisConfig) -> Trace:
    """Generate one trace from a `SynthesisConfig` (deterministic in seed)."""
    if not sc.tenants:
        raise ValueError("at least one TenantTraceSpec is required")
    if sc.method not in ("copula", "rank-shuffle"):
        raise ValueError(f"unknown synthesis method {sc.method!r}")
    arr_rng = np.random.default_rng([sc.seed, 1])
    ten_rng = np.random.default_rng([sc.seed, 2])

    arrivals = np.cumsum(arr_rng.exponential(1.0 / sc.mean_rate,
                                             sc.n_requests))
    weights = np.asarray([t.weight for t in sc.tenants], np.float64)
    tenant_idx = ten_rng.choice(len(sc.tenants), size=sc.n_requests,
                                p=weights / weights.sum())

    # per-tenant length streams, drawn in one vectorized block each so
    # a tenant's joint distribution is independent of the others' counts
    records: list[TraceRecord] = []
    lengths_fn = (_lengths_copula if sc.method == "copula"
                  else _lengths_rank_shuffle)
    for ti, spec in enumerate(sc.tenants):
        rows = np.flatnonzero(tenant_idx == ti)
        if not len(rows):
            continue
        len_rng = np.random.default_rng([sc.seed, 3, ti])
        prompts, outs = lengths_fn(len_rng, spec, len(rows))
        prompts = np.clip(prompts.astype(np.int64), 1, sc.max_prompt)
        outs = np.clip(outs.astype(np.int64), 1, sc.max_output)
        for j, row in enumerate(rows):
            records.append(TraceRecord(
                arrival=round(float(arrivals[row]), 6),
                prompt_tokens=int(prompts[j]),
                output_tokens=int(outs[j]),
                tenant=spec.name))
    return normalize(
        records, name=f"synth-{sc.method}-{sc.seed}",
        meta={"synthesis": {"method": sc.method, "seed": sc.seed,
                            "mean_rate": sc.mean_rate,
                            "n_requests": sc.n_requests}})


#: The bundled fixture's mix: chat (long-begets-long, ρ=0.6), code
#: (moderate coupling), and a RAG-like tenant whose huge prompts pair
#: with short outputs (ρ=-0.5) — the correlation pattern that flips
#: policy rankings between mean and tail.
SAMPLE_CONFIG = SynthesisConfig(
    n_requests=300,
    mean_rate=0.5,
    tenants=(
        TenantTraceSpec("chat", 0.55, prompt_median=44.0, prompt_sigma=0.6,
                        out_median=48.0, out_sigma=0.9, rho=0.6),
        TenantTraceSpec("code", 0.3, prompt_median=120.0, prompt_sigma=0.5,
                        out_median=96.0, out_sigma=0.8, rho=0.4),
        TenantTraceSpec("rag", 0.15, prompt_median=380.0, prompt_sigma=0.4,
                        out_median=28.0, out_sigma=0.6, rho=-0.5),
    ),
    method="copula",
    seed=2026,
)

def sample_trace() -> Trace:
    """Regenerate the bundled sample trace from `SAMPLE_CONFIG`.

    `tests/test_traces.py` asserts this matches the checked-in JSONL
    byte-for-byte, so the fixture can always be audited/regenerated.
    """
    return synthesize(SAMPLE_CONFIG)
