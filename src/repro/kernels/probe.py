"""Fused TRAIL probe: MLP classifier + softmax + Bayesian filter (Pallas TPU).

The paper's per-iteration add-on (Sections 3.1-3.2): after each decode step,
the tap embedding feeds a 2-layer MLP whose softmax is fused with the
Bayesian transition update. On GPU the paper offloads this to the CPU to
overlap with layers 12-32; on TPU the whole thing is one VMEM-resident fused
kernel (~2 matmul tiles), so it rides the decode step at ~0.03% overhead
with no host round-trip.

The bin dimension k (10) is far below the 128-lane tile, so ops.py pads the
classifier head and the transition matrix to k_pad=128; padded logits get a
-1e9 bias so they vanish in the softmax, and the padded transition rows/cols
are zero so they contribute nothing to the prior.

Grid: (nb,) over batch tiles; weights are replicated into VMEM per tile
(w1 is d x hidden = 768x512 bf16 = 768 KiB for the paper's probe — fits).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(tap_ref, w1_ref, b1_ref, w2_ref, b2_ref, qprev_ref, t_ref,
                  q_ref, p_ref):
    tap = tap_ref[...].astype(jnp.float32)                 # (bb, d)
    h = jax.lax.dot_general(tap, w1_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b1_ref[...].astype(jnp.float32), 0.0)
    logits = jax.lax.dot_general(h, w2_ref[...].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits = logits + b2_ref[...].astype(jnp.float32)      # (bb, k_pad)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    # Bayesian filter: prior = q_prev @ T^T ; posterior ∝ prior * p
    prior = jax.lax.dot_general(qprev_ref[...].astype(jnp.float32),
                                t_ref[...].astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    post = prior * p
    z = jnp.sum(post, axis=-1, keepdims=True)
    q = jnp.where(z > 0, post / jnp.maximum(z, 1e-30), prior)
    q_ref[...] = q
    p_ref[...] = p


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def probe_update(tap, w1, b1, w2, b2, q_prev, T, *, block_b: int = 128,
                 interpret: bool = False):
    """tap: (B,d); w1: (d,hid); w2: (hid,k); q_prev: (B,k); T: (k,k).

    Returns (q_new (B,k) f32, p (B,k) f32) — the refined posterior and the
    raw probe distribution. Pads k->128 and B->block_b internally.
    """
    B, d = tap.shape
    k = w2.shape[1]
    k_pad = max(128, k)
    pad_k = k_pad - k
    if pad_k:
        w2 = jnp.pad(w2, ((0, 0), (0, pad_k)))
        b2 = jnp.pad(b2, (0, pad_k), constant_values=-1e9)
        q_prev = jnp.pad(q_prev, ((0, 0), (0, pad_k)))
        T = jnp.pad(T, ((0, pad_k), (0, pad_k)))
    block_b = min(block_b, max(B, 1))
    pad_b = (-B) % block_b
    if pad_b:
        tap = jnp.pad(tap, ((0, pad_b), (0, 0)))
        q_prev = jnp.pad(q_prev, ((0, pad_b), (0, 0)))
    Bp = B + pad_b
    nb = Bp // block_b

    q, p = pl.pallas_call(
        _probe_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, w1.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((w1.shape[1],), lambda i: (0,)),
            pl.BlockSpec((w1.shape[1], k_pad), lambda i: (0, 0)),
            pl.BlockSpec((k_pad,), lambda i: (0,)),
            pl.BlockSpec((block_b, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((k_pad, k_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k_pad), jnp.float32),
        ],
        interpret=interpret,
    )(tap, w1, b1, w2, b2, q_prev, T)
    return q[:B, :k], p[:B, :k]
