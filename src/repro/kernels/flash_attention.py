"""Blocked causal flash attention (Pallas TPU).

Grid (B, H, nq, nk); the innermost (fastest) grid axis streams KV blocks while
f32 running-max / running-sum / accumulator scratch persists in VMEM — the
classic online-softmax schedule. GQA is expressed in the K/V BlockSpec index
map (query head h reads KV head h // G), so no KV replication ever
materializes. Sliding windows and gemma-style logit softcaps are fused.

Block sizes default to 128x128: MXU-aligned, and the per-step VMEM working
set (q, k, v blocks + acc) is ~4 * 128 * hd * 4B ≈ 256 KiB for hd=128, far
under the ~16 MiB VMEM budget, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               window: int, softcap: float, nk: int):
    i_q = pl.program_id(2)
    i_k = pl.program_id(3)

    @pl.when(i_k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i_q * block_q
    k_start = i_k * block_k
    # Block-level pruning: skip fully-masked KV blocks.
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window:
        needed = jnp.logical_and(needed,
                                 k_start + block_k - 1 > q_start - window)

    @pl.when(needed)
    def _update():
        q = q_ref[0, :, 0, :].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        valid = jnp.ones((block_q, block_k), bool)
        if causal:
            valid &= ki <= qi
        if window:
            valid &= ki > qi - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(i_k == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B,S,H,hd); k/v: (B,S,KH,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq, Sk = S + pad_q, S + pad_k
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _fa_kernel, scale=hd ** -0.5, block_q=block_q, block_k=block_k,
        causal=causal, window=window, softcap=softcap, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
