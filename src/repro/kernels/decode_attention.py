"""Flash-decode over a slot KV cache (Pallas TPU).

The decode hot loop: one query token per sequence against a (possibly
ring-buffered) KV cache with explicit per-slot positions ``kpos``
(-1 = empty). Grid (B, KH, nk): for each (sequence, KV head) the innermost
axis streams KV blocks HBM->VMEM with online-softmax scratch — decode is
memory-bandwidth-bound, so the kernel's job is simply to touch each cache
byte exactly once; all G grouped query heads ride along in registers/VMEM
((G, hd) tile) amortizing the stream.

Masking is position-based (kpos <= q_pos, window, kpos >= 0), identical to
the jnp reference path in ``repro.models.attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _dec_kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                m_scr, l_scr, acc_scr, *, scale: float, block_k: int,
                window: int, softcap: float, nk: int):
    i_k = pl.program_id(2)

    @pl.when(i_k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                      # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    kpos = kpos_ref[0]                                       # (bk,)
    q_pos = qpos_ref[0]                                      # scalar

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = (kpos >= 0) & (kpos <= q_pos)
    if window:
        valid &= kpos > q_pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)                # (G, bk)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(i_k == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "block_k", "interpret"))
def decode_attention(q, k, v, kpos, q_pos, *, window: int = 0,
                     softcap: float = 0.0, block_k: int = 256,
                     interpret: bool = False):
    """q: (B,H,hd); k/v: (B,M,KH,hd); kpos: (B,M); q_pos: (B,) -> (B,H,hd)."""
    B, H, hd = q.shape
    M, KH = k.shape[1], k.shape[2]
    G = H // KH
    block_k = min(block_k, M)
    pad = (-M) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
    Mk = M + pad
    nk = Mk // block_k
    qg = q.reshape(B, KH, G, hd)

    kernel = functools.partial(
        _dec_kernel, scale=hd ** -0.5, block_k=block_k, window=window,
        softcap=softcap, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, KH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),                 # q_pos
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, ik: (b, ik)),      # kpos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, qg, k, v, kpos)
    return out.reshape(B, H, hd)
