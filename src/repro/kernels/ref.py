"""Pure-jnp oracles for every kernel (shapes/dtypes as the kernels).

These are the semantics contract: tests sweep shapes and dtypes asserting
allclose(kernel(interpret=True), ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q: (B,S,H,hd); k/v: (B,S,KH,hd). Dense-position attention."""
    B, S, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,bmkh->bkgsm", qg, k.astype(jnp.float32))
    scores = scores * (hd ** -0.5)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    valid = jnp.ones((S, S), bool)
    if causal:
        valid &= ki <= qi
    if window:
        valid &= ki > qi - window
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsm,bmkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, kpos, q_pos, *, window: int = 0,
                         softcap: float = 0.0):
    """q: (B,H,hd) one query/row; k/v: (B,M,KH,hd); kpos: (B,M); q_pos: (B,)."""
    B, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bmkh->bkgm", qg, k.astype(jnp.float32))
    scores = scores * (hd ** -0.5)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (kpos >= 0) & (kpos <= q_pos[:, None])
    if window:
        valid &= kpos > (q_pos[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgm,bmkh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, kpos_pages, block_table,
                               q_pos, *, window: int = 0,
                               softcap: float = 0.0):
    """q: (B,H,hd); k/v_pages: (P,ps,KH,hd) shared page pool; kpos_pages:
    (P,ps); block_table: (B,pmax) int32 (0 = null page, kpos -1); q_pos: (B,).

    Semantics: gather each sequence's pages in logical order and run the
    contiguous decode reference over the flattened view.
    """
    P, ps, KH, hd = k_pages.shape
    k = k_pages[block_table].reshape(q.shape[0], -1, KH, hd)
    v = v_pages[block_table].reshape(q.shape[0], -1, KH, hd)
    kpos = kpos_pages[block_table].reshape(q.shape[0], -1)
    return decode_attention_ref(q, k, v, kpos, q_pos, window=window,
                                softcap=softcap)


def paged_decode_attention_multi_ref(q, k_pages, v_pages, kpos_pages,
                                     block_table, q_pos, *, window: int = 0,
                                     softcap: float = 0.0):
    """q: (B,T,H,hd); q_pos: (B,T) (-1 = inactive query); pool args as in
    ``paged_decode_attention_ref``. Gather the pages in logical order and
    attend all T queries over the flattened view (position-mask causality).
    """
    B, T, H, hd = q.shape
    KH = k_pages.shape[2]
    G = H // KH
    k = k_pages[block_table].reshape(B, -1, KH, hd)
    v = v_pages[block_table].reshape(B, -1, KH, hd)
    kpos = kpos_pages[block_table].reshape(B, -1)
    qg = q.reshape(B, T, KH, G, hd).astype(jnp.float32)
    scores = jnp.einsum("btkgh,bmkh->bkgtm", qg, k.astype(jnp.float32))
    scores = scores * (hd ** -0.5)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= q_pos[:, :, None])
    if window:
        valid &= kpos[:, None, :] > (q_pos[:, :, None] - window)
    vmask = valid[:, None, None]
    scores = jnp.where(vmask, scores, NEG_INF)
    # masked softmax: inactive queries (q_pos=-1, nothing valid) -> zeros,
    # matching the kernels' l=max(sum p, eps) guard
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(vmask, jnp.exp(scores - m), 0.0)
    w = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgtm,bmkh->btkgh", w, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, init_state=None):
    """Sequential SSD recurrence (the ground truth the chunked forms must match).

    x: (B,L,nh,hp); dt: (B,L,nh); A: (nh,); Bm/Cm: (B,L,N).
    Returns (y (B,L,nh,hp) f32, final_state (B,nh,hp,N) f32).
    """
    Bsz, L, nh, hp = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    if init_state is None:
        init_state = jnp.zeros((Bsz, nh, hp, N), f32)

    def step(S, inp):
        """One recurrence step: decay the state, inject x, read out y."""
        xt, dtt, Bt, Ct = inp                       # (B,nh,hp),(B,nh),(B,N),(B,N)
        a = jnp.exp(dtt * A)                        # (B,nh)
        S = a[:, :, None, None] * S + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhpn,bn->bhp", S, Ct)
        return S, y

    xs = (jnp.moveaxis(x.astype(f32), 1, 0), jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(Bm.astype(f32), 1, 0), jnp.moveaxis(Cm.astype(f32), 1, 0))
    S_fin, ys = jax.lax.scan(step, init_state.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1), S_fin


def probe_update_ref(tap, w1, b1, w2, b2, q_prev, T):
    """Fused probe + Bayesian filter oracle.

    tap: (B,d); q_prev: (B,k); T: (k,k).
    Returns (q_new (B,k), p (B,k) raw probe probs).
    """
    h = jax.nn.relu(tap.astype(jnp.float32) @ w1 + b1)
    logits = h @ w2 + b2
    p = jax.nn.softmax(logits, axis=-1)
    prior = q_prev.astype(jnp.float32) @ T.T
    post = prior * p
    z = jnp.sum(post, axis=-1, keepdims=True)
    q_new = jnp.where(z > 0, post / jnp.maximum(z, 1e-30), prior)
    return q_new, p
