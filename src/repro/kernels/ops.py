"""Public jit'd wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; everywhere else (this
container is CPU-only) they run in interpret mode, which executes the kernel
body in Python with identical semantics — that is how the test suite
validates them against the ref.py oracles.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_decode_attention as _paged
from repro.kernels import probe as _probe
from repro.kernels import ssd_scan as _ssd


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=None):
    """Tiled causal/windowed flash attention over full sequences."""
    if interpret is None:
        interpret = _interpret_default()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def decode_attention(q, k, v, kpos, q_pos, *, window=0, softcap=0.0,
                     block_k=256, interpret=None):
    """Single-step decode attention against a contiguous KV cache."""
    if interpret is None:
        interpret = _interpret_default()
    return _dec.decode_attention(q, k, v, kpos, q_pos, window=window,
                                 softcap=softcap, block_k=block_k,
                                 interpret=interpret)


def paged_decode_attention(q, k_pages, v_pages, kpos_pages, block_table,
                           q_pos, *, window=0, softcap=0.0, interpret=None):
    """Single-step decode attention against a paged KV cache."""
    if interpret is None:
        interpret = _interpret_default()
    return _paged.paged_decode_attention(
        q, k_pages, v_pages, kpos_pages, block_table, q_pos, window=window,
        softcap=softcap, interpret=interpret)


def paged_decode_attention_multi(q, k_pages, v_pages, kpos_pages,
                                 block_table, q_pos, *, window=0,
                                 softcap=0.0, interpret=None):
    """Multi-query decode attention against a paged KV cache."""
    if interpret is None:
        interpret = _interpret_default()
    return _paged.paged_decode_attention_multi(
        q, k_pages, v_pages, kpos_pages, block_table, q_pos, window=window,
        softcap=softcap, interpret=interpret)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    """Chunked state-space (SSD/Mamba-2) selective scan."""
    if interpret is None:
        interpret = _interpret_default()
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


def probe_update(tap, w1, b1, w2, b2, q_prev, T, *, block_b=128,
                 interpret=None):
    """Fused TRAIL probe step: EMA-smooth the tap and score the MLP."""
    if interpret is None:
        interpret = _interpret_default()
    return _probe.probe_update(tap, w1, b1, w2, b2, q_prev, T,
                               block_b=block_b, interpret=interpret)
