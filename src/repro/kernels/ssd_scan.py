"""Mamba2 chunked SSD scan (Pallas TPU).

TPU adaptation of the SSD algorithm [arXiv:2405.21060]: the GPU version is a
warp-specialized scan; on TPU we restructure it so nearly all FLOPs are
MXU matmuls over (Q, Q) and (Q, N)/(hp, N) tiles:

  grid (B, nh, nc) — the innermost axis walks chunks sequentially while the
  (hp, N) f32 running state persists in VMEM scratch (the same
  scratch-carry trick the flash kernels use for online softmax). Per chunk:

    intra:   y  = tril((C Bᵀ) ⊙ exp(Δcum)) ⊙ dt  @  x        (Q,Q)@(Q,hp)
    inter:   y += exp(cum) ⊙ (C @ stateᵀ)                    (Q,N)@(N,hp)
    state:   S  = exp(cum_Q) S + xᵀ @ (B ⊙ (dt exp(cum_Q-cum)))  (hp,Q)@(Q,N)

B/C are head-shared (groups=1), so their blocks are indexed by (b, c) only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, s_scr,
                *, chunk: int, nc: int):
    i_c = pl.program_id(2)

    @pl.when(i_c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)            # (Q, hp)
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # (Q,)
    A = a_ref[0]                                         # scalar (negative)
    Bm = b_ref[0].astype(jnp.float32)                    # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)                    # (Q, N)

    cum = jnp.cumsum(dt * A)                             # (Q,)
    # intra-chunk
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    dec = jnp.exp(cum[:, None] - cum[None, :])
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(si <= ti, CB * dec * dt[None, :], 0.0)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q,hp)
    # inter-chunk from carried state
    S = s_scr[...]                                        # (hp, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, S, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # state update
    w = dt * jnp.exp(cum[-1] - cum)                       # (Q,)
    S_new = (jnp.exp(cum[-1]) * S
             + jax.lax.dot_general(x, Bm * w[:, None], (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_scr[...] = S_new

    @pl.when(i_c == nc - 1)
    def _finish():
        sfin_ref[0, 0] = S_new.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """x: (B,L,nh,hp); dt: (B,L,nh) post-softplus; A: (nh,) negative;
    Bm/Cm: (B,L,N). Returns (y (B,L,nh,hp) f32, final_state (B,nh,hp,N) f32).
    L must be divisible by chunk."""
    B, L, nh, hp = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hp), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hp, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hp, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hp, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, s_fin
