"""Flash-decode over a paged KV cache (Pallas TPU).

The paged sibling of ``decode_attention``: K/V live in a pool of
fixed-size pages shared by every sequence, and each (sequence, KV head)
streams its pages HBM->VMEM through a *block-table* indirection instead of
a contiguous slot stripe. The block table and query positions ride in as
scalar-prefetch operands (``PrefetchScalarGridSpec``), so the page index
of the next DMA is known before the kernel body runs — the gather costs
nothing beyond the streaming the contiguous kernel already does.

Grid (B, KH, pmax): the innermost axis walks the sequence's logical pages;
``index_map`` resolves logical page p of sequence b to physical page
``block_table[b, p]``. Unallocated table entries point at physical page 0,
the null page, whose per-token positions ``pkpos`` are pinned to -1 — the
standard position mask (kpos >= 0, kpos <= q_pos) then drops them, and
stale data from a page's previous owner is likewise invisible because page
resets set pkpos=-1. All G grouped query heads ride along in VMEM as in
the contiguous kernel.

``paged_decode_attention_multi`` is the multi-query variant for decode
megasteps / chunked prefill over the same pool: T query tokens per
sequence ride in VMEM together and every page is streamed HBM->VMEM
*once* for all T of them, so the block-table scalar prefetch and the page
DMA traffic are amortized T-fold versus T single-query calls. Causality
within the chunk comes from the same position mask (the in-flight tokens'
K/V must already be written to their pages — pages are request-exclusive,
so write-first is safe).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _paged_kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, window: int,
                  softcap: float, npages: int):
    i_p = pl.program_id(2)

    @pl.when(i_p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    b = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32)                      # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (ps, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    kpos = kpos_ref[0]                                       # (ps,)
    q_pos = qpos_ref[b]                                      # scalar

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = (kpos >= 0) & (kpos <= q_pos)
    if window:
        valid &= kpos > q_pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)                # (G, ps)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(i_p == npages - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, kpos_pages, block_table,
                           q_pos, *, window: int = 0, softcap: float = 0.0,
                           interpret: bool = False):
    """q: (B,H,hd); k/v_pages: (P,ps,KH,hd); kpos_pages: (P,ps);
    block_table: (B,pmax) int32 (0 = null page); q_pos: (B,) -> (B,H,hd)."""
    B, H, hd = q.shape
    P, ps, KH = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    pmax = block_table.shape[1]
    G = H // KH
    qg = q.reshape(B, KH, G, hd)

    kernel = functools.partial(
        _paged_kernel, scale=hd ** -0.5, window=window, softcap=softcap,
        npages=pmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block_table, q_pos
        grid=(B, KH, pmax),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, p, bt, qp: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, p, bt, qp: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, p, bt, qp: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, ps), lambda b, h, p, bt, qp: (bt[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, p, bt, qp: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, q_pos, qg, k_pages, v_pages, kpos_pages)
    return out.reshape(B, H, hd)


def _paged_multi_kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref, kpos_ref,
                        o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                        window: int, softcap: float, npages: int):
    i_p = pl.program_id(2)

    @pl.when(i_p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    b = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32)                      # (T, G, hd)
    T, G, hd = q.shape
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (ps, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    kpos = kpos_ref[0]                                       # (ps,)
    q_pos = qpos_ref[b]                                      # (T,)

    s = jax.lax.dot_general(q.reshape(T * G, hd), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = (kpos[None, :] >= 0) & (kpos[None, :] <= q_pos[:, None])
    if window:
        valid &= kpos[None, :] > q_pos[:, None] - window
    validg = jnp.broadcast_to(valid[:, None, :],
                              (T, G, kpos.shape[0])).reshape(T * G, -1)
    s = jnp.where(validg, s, NEG_INF)                        # (T*G, ps)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(validg, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(i_p == npages - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).reshape(
            T, G, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "interpret"))
def paged_decode_attention_multi(q, k_pages, v_pages, kpos_pages,
                                 block_table, q_pos, *, window: int = 0,
                                 softcap: float = 0.0,
                                 interpret: bool = False):
    """q: (B,T,H,hd); k/v_pages: (P,ps,KH,hd); kpos_pages: (P,ps);
    block_table: (B,pmax) int32 (0 = null page); q_pos: (B,T) int32
    (-1 = inactive query) -> (B,T,H,hd).

    The T queries of each sequence attend over the pool together: one
    block-table prefetch and one page stream per (sequence, kv-head) per
    megastep, not per token. K/V of the T in-flight tokens must already be
    written through the block table (write-first; causality is enforced by
    the position mask alone)."""
    B, T, H, hd = q.shape
    ps, KH = k_pages.shape[1], k_pages.shape[2]
    pmax = block_table.shape[1]
    G = H // KH
    qg = jnp.moveaxis(q.reshape(B, T, KH, G, hd), 1, 2)      # (B,KH,T,G,hd)

    kernel = functools.partial(
        _paged_multi_kernel, scale=hd ** -0.5, window=window,
        softcap=softcap, npages=pmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block_table, q_pos
        grid=(B, KH, pmax),
        in_specs=[
            pl.BlockSpec((1, 1, T, G, hd),
                         lambda b, h, p, bt, qp: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, p, bt, qp: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, p, bt, qp: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, ps), lambda b, h, p, bt, qp: (bt[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, G, hd),
                               lambda b, h, p, bt, qp: (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G,), jnp.float32),
            pltpu.VMEM((T * G,), jnp.float32),
            pltpu.VMEM((T * G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, T, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, q_pos, qg, k_pages, v_pages, kpos_pages)
    return jnp.moveaxis(out, 2, 1).reshape(B, T, H, hd)
