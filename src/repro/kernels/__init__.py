"""Pallas TPU kernels for the serving hot spots.

Each kernel ships three pieces:
  <name>.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrappers (interpret=True off-TPU)
  ref.py    — pure-jnp oracles used by tests and the dry-run path

Kernels:
  flash_attention — blocked causal FA (GQA, sliding window, logit softcap)
  decode_attention — flash-decode over a slot KV cache (the decode_32k /
                     long_500k hot loop)
  paged_decode_attention — flash-decode over a paged KV cache: block-table
                     gather across non-contiguous pages via scalar prefetch
                     (plus a multi-query variant that amortizes the prefetch
                     and page streaming over a decode megastep / prefill
                     chunk's T query tokens)
  ssd_scan        — Mamba2 chunked state-space-dual scan
  probe           — the paper's fused probe MLP + softmax + Bayesian update
"""
