"""Configuration system for the TRAIL reproduction framework.

A single :class:`ModelConfig` dataclass describes every architecture in the
assigned pool (dense GQA, MoE, SSM, hybrid, encoder-decoder, VLM).  The model
factory (``repro.models.model``) consumes only this dataclass, so adding an
architecture means adding one file under ``repro/configs``.

Layer heterogeneity (gemma-style local:global alternation, hybrid stacks) is
expressed with ``layer_kinds`` — a tuple of per-layer kind strings.  The model
builder compresses this into maximal runs of identical kind and ``lax.scan``s
each run, which keeps HLO size sane for 64-layer configs.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any

# Layer kinds understood by the model builder.
KIND_ATTN = "attn"        # full (global) causal self-attention + MLP
KIND_LOCAL = "local"      # sliding-window causal self-attention + MLP
KIND_SSM = "ssm"          # Mamba2 SSD block (no MLP; block includes gating)
KIND_MOE = "moe"          # attention + mixture-of-experts MLP
KIND_HYBRID = "hybrid"    # Hymba-style parallel attention + SSM heads + MLP

VALID_KINDS = (KIND_ATTN, KIND_LOCAL, KIND_SSM, KIND_MOE, KIND_HYBRID)

# Architecture families (metadata; drives input stubs and shape skips).
FAMILY_DENSE = "dense"
FAMILY_MOE = "moe"
FAMILY_SSM = "ssm"
FAMILY_HYBRID = "hybrid"
FAMILY_AUDIO = "audio"    # enc-dec with stub audio frontend
FAMILY_VLM = "vlm"        # decoder with stub vision-prefix frontend


@dataclass(frozen=True)
class ProbeConfig:
    """The paper's length-prediction probe (Section 3.1).

    A two-layer MLP (d_model -> hidden -> num_bins) applied to the hidden
    state after ``tap_layer``; during prefill the input is the mean of all
    prompt-token embeddings at that layer.
    """

    tap_layer: int = 11           # paper: layer 11 of 32 (Llama3-8B)
    hidden: int = 512             # paper: 512-d hidden, ReLU
    num_bins: int = 10            # paper: k = 10 equal-width bins
    max_len: int = 512            # paper: lengths in [0, 512]

    @property
    def bin_width(self) -> float:
        """Width of one equal-width length bin."""
        return self.max_len / self.num_bins

    def bin_mean(self, i: int) -> float:
        """Midpoint of bin ``i``: m_i = (b_i + b_{i+1}) / 2 (paper S3.1)."""
        return self.bin_width * (i + 0.5)


@dataclass(frozen=True)
class ModelConfig:
    """One architecture in the assigned pool, fully described.

    The model factory (``repro.models.model``) consumes only this
    dataclass; defaults describe a small dense GQA decoder and each
    family overrides the sections it needs (MoE, SSM, encoder-decoder,
    VLM prefix). Frozen so configs can key caches and travel through
    jit closures safely.
    """

    # -- identity ----------------------------------------------------------
    name: str = "model"
    family: str = FAMILY_DENSE
    source: str = ""              # citation ([arXiv:...] / [hf:...])

    # -- trunk dimensions --------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0             # 0 -> d_model // num_heads
    d_ff: int = 1024              # dense MLP hidden (per-expert hidden for MoE)
    vocab_size: int = 32000

    # -- attention flavour -------------------------------------------------
    layer_kinds: tuple[str, ...] = ()   # empty -> homogeneous from family
    sliding_window: int = 0             # window for KIND_LOCAL layers
    qkv_bias: bool = False              # qwen1.5
    attn_logit_softcap: float = 0.0     # gemma2: 50.0
    final_logit_softcap: float = 0.0    # gemma2: 30.0
    rope_theta: float = 10000.0
    use_rope: bool = True

    # -- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False    # arctic: dense MLP in parallel w/ MoE
    router_aux_weight: float = 0.01     # load-balance loss weight
    capacity_factor: float = 1.25       # static-shape expert capacity

    # -- SSM (Mamba2 SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128                # SSD chunk length
    ssm_conv: int = 4                   # depthwise conv width
    ssm_groups: int = 1                 # B/C groups (mamba2 default: shared)

    # -- encoder-decoder (whisper) -------------------------------------------
    num_encoder_layers: int = 0
    encoder_seq: int = 0                # stub frontend: #frames/patches
    cross_attention: bool = False

    # -- VLM (paligemma) ------------------------------------------------------
    num_prefix_tokens: int = 0          # stub vision prefix length

    # -- KV cache ---------------------------------------------------------
    kv_quant: bool = False              # int8 KV with per-(token,head) scales

    # -- norms / embeddings ----------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False           # gemma-style sqrt(d_model) scaling

    # -- training -----------------------------------------------------------
    remat: bool = True
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # -- the paper's probe -----------------------------------------------------
    probe: ProbeConfig = field(default_factory=ProbeConfig)

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if not self.layer_kinds:
            if self.family == FAMILY_SSM:
                kinds = (KIND_SSM,) * self.num_layers
            elif self.family == FAMILY_MOE:
                kinds = (KIND_MOE,) * self.num_layers
            elif self.family == FAMILY_HYBRID:
                kinds = (KIND_HYBRID,) * self.num_layers
            else:
                kinds = (KIND_ATTN,) * self.num_layers
            object.__setattr__(self, "layer_kinds", kinds)
        if len(self.layer_kinds) != self.num_layers:
            raise ValueError(
                f"{self.name}: layer_kinds has {len(self.layer_kinds)} entries "
                f"for num_layers={self.num_layers}")
        for k in self.layer_kinds:
            if k not in VALID_KINDS:
                raise ValueError(f"{self.name}: unknown layer kind {k!r}")
        if self.num_heads and self.num_kv_heads and self.num_heads % self.num_kv_heads:
            raise ValueError(f"{self.name}: num_heads must divide by num_kv_heads")
        # Clamp the probe tap into range (paper uses mid-stack).
        tap = min(self.probe.tap_layer, self.num_layers - 1)
        if tap != self.probe.tap_layer:
            object.__setattr__(self, "probe", dataclasses.replace(self.probe, tap_layer=tap))

    # -- derived -------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        """Total query projection width (num_heads * head_dim)."""
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        """Total key/value projection width (num_kv_heads * head_dim)."""
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        """True when every layer is an SSM block (no KV cache at all)."""
        return all(k == KIND_SSM for k in self.layer_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer needs an unbounded full-attention KV cache."""
        return all(k in (KIND_SSM, KIND_LOCAL) for k in self.layer_kinds)

    @property
    def has_global_attention(self) -> bool:
        """True when any layer carries an unbounded full-attention KV cache."""
        return any(k in (KIND_ATTN, KIND_MOE, KIND_HYBRID) for k in self.layer_kinds)

    @property
    def supports_long_decode(self) -> bool:
        """long_500k eligibility: SSM/hybrid/sliding-window archs.

        Hybrid (hymba) attention heads use a sliding window in our config;
        gemma2/3 globals are a bounded fraction of layers and their per-step
        decode is linear — we follow DESIGN.md section 5.
        """
        n_global = sum(k in (KIND_ATTN, KIND_MOE) for k in self.layer_kinds)
        return (self.family in (FAMILY_SSM, FAMILY_HYBRID)
                or (self.sliding_window > 0
                    and n_global <= self.num_layers // 2))

    @property
    def has_decoder(self) -> bool:
        """Every assigned arch has a decode path (whisper: its decoder)."""
        return True

    def layer_runs(self) -> tuple[tuple[str, int], ...]:
        """Compress layer_kinds into maximal (kind, run_length) runs."""
        runs: list[tuple[str, int]] = []
        for k in self.layer_kinds:
            if runs and runs[-1][0] == k:
                runs[-1] = (k, runs[-1][1] + 1)
            else:
                runs.append((k, 1))
        return tuple(runs)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + trunk)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for kind in self.layer_kinds:
            n += self._layer_params(kind)
        if self.num_encoder_layers:
            enc = self.num_encoder_layers * self._layer_params(KIND_ATTN)
            n += enc
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        n = self.vocab_size * self.d_model
        for kind in self.layer_kinds:
            n += self._layer_params(kind, active=True)
        if self.num_encoder_layers:
            n += self.num_encoder_layers * self._layer_params(KIND_ATTN)
        return n

    def _layer_params(self, kind: str, active: bool = False) -> int:
        """Parameter count of one layer of ``kind`` (active: routed only)."""
        d, ff = self.d_model, self.d_ff
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp = 3 * d * ff  # gated (gate/up/down)
        if kind == KIND_SSM:
            return self._ssm_params()
        if kind == KIND_MOE:
            ne = self.experts_per_token if active else self.num_experts
            moe = ne * 3 * d * ff + d * self.num_experts
            if self.moe_dense_residual:
                moe += 3 * d * ff
            return attn + moe
        if kind == KIND_HYBRID:
            return attn + self._ssm_params() + mlp
        return attn + mlp

    def _ssm_params(self) -> int:
        """Parameter count of one Mamba2 SSD block."""
        d = self.d_model
        d_in = self.ssm_expand * d
        nh = max(d_in // self.ssm_head_dim, 1)
        # in_proj produces [z, x, B, C, dt]; B/C shared across heads (groups).
        bc = 2 * self.ssm_groups * self.ssm_state
        zxbcdt = 2 * d_in + bc + nh
        return d * zxbcdt + d_in * d + self.ssm_conv * (d_in + bc)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "mamba2-370m", "whisper-tiny", "paligemma-3b", "granite-3-8b",
    "arctic-480b", "qwen1.5-32b", "gemma3-1b", "hymba-1.5b",
    "gemma2-9b", "olmoe-1b-7b",
)

_EXTRA_IDS = ("trail-llama",)   # the paper's own serving model (reduced scale)


def _module_name(arch: str) -> str:
    """Map an arch id to its ``repro.configs`` module name."""
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    """Load the full-size config for an architecture id."""
    if arch not in ARCH_IDS + _EXTRA_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS + _EXTRA_IDS}")
    mod = importlib.import_module(_module_name(arch))
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Load the reduced smoke-test variant (<=2 layers, d_model<=512, <=4 experts)."""
    mod = importlib.import_module(_module_name(arch))
    return mod.SMOKE


def all_configs() -> dict[str, ModelConfig]:
    """Load every assigned full-size config, keyed by arch id."""
    return {a: get_config(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    """One assigned benchmark input shape (sequence x batch x mode)."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                     # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applies(cfg: ModelConfig, shape: InputShape) -> bool:
    """DESIGN.md section 5 skip rules."""
    if shape.name == "long_500k":
        return cfg.supports_long_decode
    return True


def pattern_local_global(num_layers: int, local: int, glob: int = 1,
                         window_kind: str = KIND_LOCAL) -> tuple[str, ...]:
    """Build an (L..LG)* repeating pattern truncated to num_layers."""
    block = (window_kind,) * local + (KIND_ATTN,) * glob
    kinds: list[str] = []
    while len(kinds) < num_layers:
        kinds.extend(block)
    return tuple(kinds[:num_layers])
