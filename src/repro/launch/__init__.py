"""Launchers: production mesh, shardings, multi-pod dry-run, train/serve."""
