"""Serving launcher: run the TRAIL engine (or an N-replica cluster) over a
workload.

    # paper-scale policy comparison under the roofline cost model
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --policy trail --rate 14 --n 300

    # named workload scenario (see serving/workload.py SCENARIOS)
    PYTHONPATH=src python -m repro.launch.serve --scenario bursty --rate 14

    # 2-replica cluster with predicted-work routing
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 --router jspw \
        --scenario bursty --rate 2.0 --compute-bound

    # real end-to-end on a CPU-sized model (trains briefly first)
    PYTHONPATH=src python -m repro.launch.serve --arch trail-llama \
        --smoke --real --policy trail --n 16
"""

from __future__ import annotations

import argparse
import json

from repro.cluster import ROUTER_POLICIES, run_cluster
from repro.config import ARCH_IDS, get_config, get_smoke_config
from repro.core.scheduler import POLICIES
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import run_policy
from repro.serving.workload import (SCENARIOS, WorkloadConfig, generate,
                                    scenario_config)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b",
                    choices=ARCH_IDS + ("trail-llama",))
    ap.add_argument("--policy", default="trail", choices=POLICIES)
    ap.add_argument("--c", type=float, default=0.8)
    ap.add_argument("--rate", type=float, default=14.0,
                    help="aggregate request rate (req/s)")
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--burst", action="store_true")
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="named workload scenario preset")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--mem-gb", type=float, default=0.0,
                    help="KV memory budget (0 = unlimited)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="cluster mode: number of replica engines (sim)")
    ap.add_argument("--router", default="jspw", choices=ROUTER_POLICIES,
                    help="cluster dispatch policy")
    ap.add_argument("--compute-bound", action="store_true",
                    help="compute-bound hardware point (2 TFLOP/s) where "
                         "routing quality is visible; default is tpu-v5e")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share identical KV prefixes across requests "
                         "(implies --kv-layout paged; pairs with the "
                         "shared-prefix scenario and the prefix-affinity "
                         "router)")
    ap.add_argument("--kv-layout", default=None,
                    choices=("contig", "paged"),
                    help="KV cache layout (default contig; --prefix-cache "
                         "forces paged)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--real", action="store_true",
                    help="actually run the model (CPU-sized configs)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    # real mode shrinks lengths to CPU scale; with a --scenario preset the
    # arrival process is kept and only the length mix is downsized. The
    # tenant mix is dropped at this scale, so --prefix-cache keeps a small
    # single-tenant system prompt instead (one KV page at page_size=16) —
    # otherwise the downsizing would silently remove every shared prefix.
    real_sizes = dict(prompt_mean=10.0, out_median=8.0, max_out=32,
                      tenants=())
    if args.prefix_cache:
        real_sizes.update(prefix_len=16, split_streams=True)
    if args.scenario:
        wc = scenario_config(args.scenario, n_requests=args.n,
                             request_rate=args.rate, seed=args.seed,
                             vocab=cfg.vocab_size,
                             **(real_sizes if args.real else {}))
    else:
        wc = WorkloadConfig(n_requests=args.n, request_rate=args.rate,
                            burst=args.burst, vocab=cfg.vocab_size,
                            seed=args.seed,
                            **(real_sizes if args.real else {}))
    reqs = generate(wc)
    hardware = (HardwareSpec(name="compute-bound-2tf", peak_flops=2e12,
                             hbm_bw=819e9, overhead_s=2e-4)
                if args.compute_bound else HardwareSpec())
    mem_budget = int(args.mem_gb * 1e9) if args.mem_gb else 1 << 62
    kv_layout = args.kv_layout or ("paged" if args.prefix_cache else "contig")

    if args.replicas > 1:
        if args.real:
            raise SystemExit("cluster mode is sim-only (one device pool)")
        stats = run_cluster(
            cfg, reqs, router_policy=args.router,
            n_replicas=args.replicas, policy=args.policy,
            c_limit=args.c, max_batch=args.max_batch,
            mem_budget=mem_budget, hardware=hardware, seed=args.seed,
            kv_layout=kv_layout, prefix_cache=args.prefix_cache)
        print(json.dumps({"arch": cfg.name, "policy": args.policy,
                          "router": args.router, "replicas": args.replicas,
                          "scenario": args.scenario or "poisson",
                          "rate": args.rate, **stats.summary()}, indent=1))
        return

    model = params = None
    mode = "sim"
    predictor = None
    if args.real:
        import jax
        from repro.models.model import build_model
        from repro.serving.predictors import ProbePredictor
        model = build_model(cfg)
        params = model.init(jax.random.key(args.seed))
        predictor = ProbePredictor(cfg.probe, probe_params=params["probe"],
                                   embed_table=params["embed"])
        mode = "real"

    stats = run_policy(
        cfg, args.policy, reqs, c_limit=args.c, max_batch=args.max_batch,
        mem_budget=mem_budget, mode=mode, predictor=predictor, model=model,
        params=params, hardware=hardware, seed=args.seed,
        kv_layout=kv_layout, prefix_cache=args.prefix_cache)
    print(json.dumps({"arch": cfg.name, "policy": args.policy,
                      "c": args.c, "rate": args.rate,
                      "scenario": args.scenario or
                      ("burst" if args.burst else "poisson"),
                      **stats.summary()}, indent=1))


if __name__ == "__main__":
    main()
