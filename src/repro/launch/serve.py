"""Serving launcher: run the TRAIL engine (or an N-replica cluster) over a
workload.

    # paper-scale policy comparison under the roofline cost model
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --policy trail --rate 14 --n 300

    # named workload scenario (see serving/workload.py SCENARIOS)
    PYTHONPATH=src python -m repro.launch.serve --scenario bursty --rate 14

    # 2-replica cluster with predicted-work routing
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 --router jspw \
        --scenario bursty --rate 2.0 --compute-bound

    # real end-to-end on a CPU-sized model (trains briefly first)
    PYTHONPATH=src python -m repro.launch.serve --arch trail-llama \
        --smoke --real --policy trail --n 16

    # replay the bundled Azure-style trace at 2x its native rate and
    # write the full percentile/SLO metrics report
    PYTHONPATH=src python -m repro.launch.serve --trace sample \
        --rate-scale 2.0 --compute-bound --metrics-out metrics.json

    # swap the length-prediction strategy (the predictor bake-off dial)
    PYTHONPATH=src python -m repro.launch.serve --trace sample \
        --predictor noisy-oracle:sigma=0.5

    # overload + failure resilience: deadlines, predicted-work load
    # shedding, and deterministic chaos with router failover
    PYTHONPATH=src python -m repro.launch.serve --scenario bursty \
        --rate 40 --deadline 120 --shed-watermark 20000
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 --router jspw \
        --scenario bursty --chaos crash:1@30-90 --compute-bound

    # prefill/decode disaggregation: 1 prefill + 3 decode replicas with
    # paged KV-page shipping over a 25 GB/s interconnect
    PYTHONPATH=src python -m repro.launch.serve --disagg 1:3 \
        --scenario bursty --rate 8 --compute-bound --link-gbps 25

    # tail-aware scheduling: the BENCH_tail recipe (rank aging + early
    # C-limit pin + paged KV) that un-inverts completion-p99 vs FCFS
    PYTHONPATH=src python -m repro.launch.serve --trace sample \
        --rate-scale 24 --tail --metrics-out metrics.json
    PYTHONPATH=src python -m repro.launch.serve --scenario bursty \
        --rate 40 --age-boost 256 --age-delay 5 --deadline 120 \
        --deadline-slack 20

    # online front door: HTTP/SSE server with continuous admission
    # (curl -N ... POST /v1/generate streams token events back)
    PYTHONPATH=src python -m repro.launch.serve --serve --port 8100 \
        --shed-watermark 3000 --admission-control

    # live closed loop: 8 socket clients against the in-process server,
    # 20x time warp
    PYTHONPATH=src python -m repro.launch.serve --serve --clients 8 \
        --think-time 1.0 --time-scale 20

    # deterministic in-process closed loop (virtual clock, no sockets)
    PYTHONPATH=src python -m repro.launch.serve --clients 64 \
        --policy trail --think-time 2.0
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import replace as dc_replace

from repro.cluster import ROUTER_POLICIES, run_cluster
from repro.cluster.faults import parse_chaos
from repro.config import ARCH_IDS, get_config, get_smoke_config
from repro.core.scheduler import POLICIES
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import run_policy
from repro.serving.predictors import STRATEGIES, parse_spec
from repro.serving.workload import (SCENARIOS, WorkloadConfig, generate,
                                    scenario_config)


def main():
    """Parse CLI flags, build the workload, and run the engine/cluster."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b",
                    choices=ARCH_IDS + ("trail-llama",))
    ap.add_argument("--policy", default="trail", choices=POLICIES)
    ap.add_argument("--predictor", default=None, metavar="SPEC",
                    help="length-prediction strategy spec "
                         "'name[:key=value,...]' (names: "
                         f"{', '.join(STRATEGIES)}); sim mode only. "
                         "Default: the scenario's recommendation, else "
                         "the legacy trail probe. 'rank-only' pairs "
                         "with --policy rank (auto-selected when the "
                         "policy is left at its default)")
    ap.add_argument("--c", type=float, default=None,
                    help="preemption budget multiplier C (default 0.8; "
                         "--tail lowers it to 0.2)")
    ap.add_argument("--rate", type=float, default=None,
                    help="aggregate request rate (req/s; default 14, or "
                         "the trace's native rate with --trace)")
    ap.add_argument("--n", type=int, default=None,
                    help="request count (synthetic default: 300); with "
                         "--trace: cap on replayed records (default: the "
                         "whole trace)")
    ap.add_argument("--burst", action="store_true")
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="named workload scenario preset")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay a recorded trace (.jsonl/.csv, or "
                         "'sample' for the bundled Azure-style fixture) "
                         "instead of a synthetic scenario; --rate sets "
                         "the target mean arrival rate (0 = native)")
    ap.add_argument("--rate-scale", type=float, default=None,
                    help="trace replay: multiply the native arrival rate "
                         "(overrides the --rate-derived scale)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="capture the per-request event stream and write "
                         "the rollup (TTFT/TBT/completion percentiles + "
                         "SLO attainment) as JSON; also prints the "
                         "markdown table")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--mem-gb", type=float, default=0.0,
                    help="KV memory budget (0 = unlimited)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="cluster mode: number of replica engines (sim)")
    ap.add_argument("--router", default="jspw", choices=ROUTER_POLICIES,
                    help="cluster dispatch policy")
    ap.add_argument("--disagg", default=None, metavar="P:D",
                    help="prefill/decode disaggregation: P dedicated "
                         "prefill replicas + D decode replicas (implies "
                         "cluster mode with P+D replicas and --kv-layout "
                         "paged; finished prefills ship their KV pages "
                         "to a decode replica over the interconnect)")
    ap.add_argument("--link-gbps", type=float, default=None, metavar="GBPS",
                    help="replica<->replica interconnect bandwidth in "
                         "gigabytes/s for the KV handoff hop (default 25, "
                         "~200 Gb/s Ethernet); requires --disagg")
    ap.add_argument("--compute-bound", action="store_true",
                    help="compute-bound hardware point (2 TFLOP/s) where "
                         "routing quality is visible; default is tpu-v5e")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share identical KV prefixes across requests "
                         "(implies --kv-layout paged; pairs with the "
                         "shared-prefix scenario and the prefix-affinity "
                         "router)")
    ap.add_argument("--kv-layout", default=None,
                    choices=("contig", "paged"),
                    help="KV cache layout (default contig; --prefix-cache "
                         "forces paged)")
    ap.add_argument("--deadline", type=float, default=0.0, metavar="S",
                    help="per-request completion deadline (seconds after "
                         "arrival, engine clock); expired requests are "
                         "cancelled and count against goodput (0 = none)")
    ap.add_argument("--ttft-deadline", type=float, default=0.0, metavar="S",
                    help="first-token deadline (seconds after arrival); "
                         "requests still waiting past it are cancelled "
                         "(0 = none)")
    ap.add_argument("--shed-watermark", type=float, default=0.0,
                    metavar="TOKENS",
                    help="predicted-backlog watermark (tokens) above which "
                         "the engine sheds its worst-ranked waiting "
                         "requests (0 = shedding off)")
    ap.add_argument("--admission-control", action="store_true",
                    help="with --shed-watermark: refuse new arrivals at "
                         "admission while the predicted backlog is above "
                         "the watermark, instead of shedding queued work")
    ap.add_argument("--age-boost", type=float, default=None, metavar="R",
                    help="rank-aging boost: rank units (predicted tokens) "
                         "subtracted per second a request waits beyond "
                         "the --age-delay grace window; starvation-free "
                         "for any value > 0 (default 0 = off)")
    ap.add_argument("--age-delay", type=float, default=None, metavar="S",
                    help="rank-aging grace window (seconds): ordering "
                         "stays pure SRPT inside it (default 0)")
    ap.add_argument("--deadline-slack", type=float, default=None,
                    metavar="S",
                    help="deadline-aware limited preemption: a running "
                         "request within this many seconds of its "
                         "--deadline is never preempted (0 = off)")
    ap.add_argument("--tail", action="store_true",
                    help="apply the BENCH_tail recipe (age-boost 3072, "
                         "age-delay 20.5, c 0.2, paged KV): un-inverts "
                         "completion-p99 vs fcfs at overload while "
                         "keeping the >=1.5x mean win; explicit "
                         "--age-boost/--age-delay/--c/--kv-layout "
                         "flags override individual knobs")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault injection for cluster mode: "
                         "comma-separated crash:R@T[-U] | slow:R@T-U*F | "
                         "flaky:R@T-U%%P (e.g. 'crash:1@30,slow:0@10-20*4')")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="cluster failover: per-request retry budget "
                         "before a request is declared lost")
    ap.add_argument("--serve", action="store_true",
                    help="run the online front door: an asyncio HTTP/SSE "
                         "server that admits requests continuously into "
                         "the engine and streams tokens back (sim mode, "
                         "single engine; POST /v1/generate, GET /healthz, "
                         "GET /metrics)")
    ap.add_argument("--port", type=int, default=None,
                    help="front-door TCP port (default 8100; 0 = "
                         "OS-assigned; requires --serve)")
    ap.add_argument("--time-scale", type=float, default=None, metavar="X",
                    help="virtual seconds the engine clock advances per "
                         "wall second behind the front door (default 1.0 "
                         "= real time; requires --serve)")
    ap.add_argument("--clients", type=int, default=None, metavar="N",
                    help="closed-loop pool of N think-time users; with "
                         "--serve they drive the live server over "
                         "sockets, alone they drive the engine in-process "
                         "on its virtual clock (deterministic)")
    ap.add_argument("--think-time", type=float, default=None, metavar="S",
                    help="mean exponential think time between a user's "
                         "requests (default 2.0; requires --clients)")
    ap.add_argument("--requests-per-client", type=int, default=None,
                    metavar="K",
                    help="logical requests each user issues (default 4; "
                         "requires --clients)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--real", action="store_true",
                    help="actually run the model (CPU-sized configs)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rate = 14.0 if args.rate is None else args.rate
    # real mode shrinks lengths to CPU scale; with a --scenario preset the
    # arrival process is kept and only the length mix is downsized. The
    # tenant mix is dropped at this scale, so --prefix-cache keeps a small
    # single-tenant system prompt instead (one KV page at page_size=16) —
    # otherwise the downsizing would silently remove every shared prefix.
    real_sizes = dict(prompt_mean=10.0, out_median=8.0, max_out=32,
                      tenants=())
    if args.prefix_cache:
        real_sizes.update(prefix_len=16, split_streams=True)
    # CLI contract: every invalid flag combination or unusable input
    # exits 2 with a one-line error (argparse's own convention), never a
    # traceback or a status-1 SystemExit
    if args.rate_scale is not None:
        if not args.trace:
            ap.error("--rate-scale only applies to --trace replay "
                     "(use --rate for synthetic scenarios)")
        if args.rate_scale <= 0:
            ap.error("--rate-scale must be positive")
    if args.deadline < 0 or args.ttft_deadline < 0:
        ap.error("--deadline/--ttft-deadline must be >= 0")
    if args.shed_watermark < 0:
        ap.error("--shed-watermark must be >= 0")
    if args.admission_control and args.shed_watermark <= 0:
        ap.error("--admission-control requires --shed-watermark > 0 "
                 "(the watermark is the admission threshold)")
    for flag, val in (("--age-boost", args.age_boost),
                      ("--age-delay", args.age_delay),
                      ("--deadline-slack", args.deadline_slack)):
        if val is not None and val < 0:
            ap.error(f"{flag} must be >= 0")
    if args.deadline_slack and not args.deadline:
        ap.error("--deadline-slack requires --deadline > 0 (the slack "
                 "window is measured against the completion deadline)")
    # --tail supplies the BENCH_tail recipe as *defaults*; any knob the
    # user set explicitly wins over the recipe value
    age_boost = args.age_boost if args.age_boost is not None \
        else (3072.0 if args.tail else 0.0)
    age_delay = args.age_delay if args.age_delay is not None \
        else (20.5 if args.tail else 0.0)
    deadline_slack = args.deadline_slack or 0.0
    c_limit = args.c if args.c is not None else (0.2 if args.tail else 0.8)
    prefill_replicas = 0
    if args.disagg:
        try:
            p_str, d_str = args.disagg.split(":")
            p, d = int(p_str), int(d_str)
        except ValueError:
            ap.error("--disagg must be P:D with integer replica counts "
                     "(e.g. --disagg 1:3)")
        if p < 1 or d < 1:
            ap.error("--disagg needs at least one prefill and one decode "
                     "replica (P >= 1 and D >= 1)")
        if args.replicas > 1 and args.replicas != p + d:
            ap.error(f"--replicas {args.replicas} conflicts with "
                     f"--disagg {args.disagg} (= {p + d} replicas); "
                     "drop --replicas — --disagg sets the fleet size")
        if args.kv_layout == "contig":
            ap.error("--disagg requires a paged KV layout (pages are the "
                     "unit of handoff); drop --kv-layout contig")
        prefill_replicas = p
        args.replicas = p + d
    if args.link_gbps is not None:
        if not args.disagg:
            ap.error("--link-gbps only applies to --disagg (it sets the "
                     "KV handoff interconnect bandwidth)")
        if args.link_gbps <= 0:
            ap.error("--link-gbps must be positive")
    faults = None
    if args.chaos:
        if args.replicas <= 1:
            ap.error("--chaos requires cluster mode (--replicas >= 2): "
                     "fault injection and failover live in the router")
        try:
            faults = parse_chaos(args.chaos, seed=args.seed)
        except ValueError as e:
            ap.error(str(e))
    serve_mode = args.serve or args.clients is not None
    if args.port is not None:
        if not args.serve:
            ap.error("--port requires --serve (it binds the front-door "
                     "listener)")
        if not 0 <= args.port <= 65535:
            ap.error("--port must be in [0, 65535] (0 = OS-assigned)")
    if args.time_scale is not None:
        if not args.serve:
            ap.error("--time-scale requires --serve (the in-process "
                     "closed loop already runs on the virtual clock)")
        if args.time_scale <= 0:
            ap.error("--time-scale must be positive")
    if args.clients is not None and args.clients <= 0:
        ap.error("--clients must be a positive user count")
    if args.think_time is not None:
        if args.clients is None:
            ap.error("--think-time requires --clients (it is the pool's "
                     "mean think time)")
        if args.think_time < 0:
            ap.error("--think-time must be >= 0")
    if args.requests_per_client is not None:
        if args.clients is None:
            ap.error("--requests-per-client requires --clients")
        if args.requests_per_client <= 0:
            ap.error("--requests-per-client must be positive")
    if serve_mode:
        mode_flags = "--serve/--clients"
        for flag, bad in (("--trace", args.trace),
                          ("--scenario", args.scenario),
                          ("--burst", args.burst),
                          ("--disagg", args.disagg),
                          ("--chaos", args.chaos),
                          ("--real", args.real),
                          ("--metrics-out", args.metrics_out)):
            if bad:
                ap.error(f"{mode_flags} run a live closed loop over one "
                         f"sim engine and conflict with {flag} (the "
                         "clients are the workload; GET /metrics serves "
                         "the live rollup)")
        if args.replicas > 1:
            ap.error(f"{mode_flags} drive a single engine; the cluster "
                     "router is not behind the front door yet (drop "
                     "--replicas)")
        policy = args.policy
        pred_spec = args.predictor or ""
        if pred_spec:
            name = parse_spec(pred_spec)[0]
            if name not in STRATEGIES:
                ap.error(f"unknown predictor strategy {name!r}; "
                         f"choose from {STRATEGIES}")
            if name == "rank-only" and policy == "trail":
                policy = "rank"
        _run_front_door(args, cfg, policy=policy, pred_spec=pred_spec,
                        c_limit=c_limit, age_boost=age_boost,
                        age_delay_s=age_delay,
                        deadline_slack_s=deadline_slack)
        return
    if args.trace:
        if args.real:
            ap.error("--trace replay is sim-only (trace lengths "
                     "exceed CPU-sized device pools)")
        if args.scenario or args.burst:
            ap.error("--trace conflicts with --scenario/--burst: "
                     "a trace supplies its own arrivals and lengths")
        if args.trace != "sample" and not os.path.isfile(args.trace):
            ap.error(f"--trace path {args.trace!r} does not exist or is "
                     "not a file (pass 'sample' for the bundled fixture)")
        overrides = ({"trace_rate_scale": args.rate_scale}
                     if args.rate_scale is not None else {})
        # --n caps the replay; None/0 = the whole trace, never a silent
        # truncation to the synthetic default
        wc = scenario_config(f"trace:{args.trace}",
                             n_requests=args.n or 0,
                             request_rate=args.rate or 0.0, seed=args.seed,
                             vocab=cfg.vocab_size, **overrides)
    elif args.scenario:
        wc = scenario_config(args.scenario, n_requests=args.n or 300,
                             request_rate=rate, seed=args.seed,
                             vocab=cfg.vocab_size,
                             **(real_sizes if args.real else {}))
    else:
        wc = WorkloadConfig(n_requests=args.n or 300, request_rate=rate,
                            burst=args.burst, vocab=cfg.vocab_size,
                            seed=args.seed,
                            **(real_sizes if args.real else {}))
    reqs = generate(wc)
    if args.trace:
        # report the replayed stream's actual mean rate, not the
        # synthetic default (native trace rate x whatever scaling
        # applied); 0.0 = undefined (single request / zero span)
        span = (reqs[-1].arrival - reqs[0].arrival) if len(reqs) > 1 else 0.0
        rate = (len(reqs) - 1) / span if span > 0 else 0.0
    hardware = (HardwareSpec(name="compute-bound-2tf", peak_flops=2e12,
                             hbm_bw=819e9, overhead_s=2e-4)
                if args.compute_bound else HardwareSpec())
    if args.link_gbps is not None:
        hardware = dc_replace(hardware, link_bw=args.link_gbps * 1e9)
    mem_budget = int(args.mem_gb * 1e9) if args.mem_gb else 1 << 62
    kv_layout = args.kv_layout or ("paged" if args.prefix_cache or args.tail
                                   or args.disagg else "contig")

    # strategy resolution: explicit flag > scenario recommendation >
    # legacy default ("" = the engine's built-in trail probe)
    pred_spec = args.predictor if args.predictor is not None else wc.predictor
    policy = args.policy
    if pred_spec:
        if args.real:
            ap.error("--predictor strategies are sim-only; the "
                     "real engine uses the live ProbePredictor")
        name = parse_spec(pred_spec)[0]
        if name not in STRATEGIES:
            ap.error(f"unknown predictor strategy {name!r}; "
                     f"choose from {STRATEGIES}")
        if name == "rank-only" and policy == "trail":
            # the ordinal strategy needs the rank-aware scheduler path;
            # only the default policy is overridden — an explicit
            # incompatible choice still errors in the engine
            policy = "rank"

    if args.replicas > 1:
        if args.real:
            ap.error("cluster mode is sim-only (one device pool)")
        stats = run_cluster(
            cfg, reqs, router_policy=args.router,
            n_replicas=args.replicas, policy=policy,
            c_limit=c_limit, max_batch=args.max_batch,
            mem_budget=mem_budget, hardware=hardware, seed=args.seed,
            kv_layout=kv_layout, prefix_cache=args.prefix_cache,
            predictor=pred_spec,
            prefill_replicas=prefill_replicas,
            faults=faults, max_retries=args.max_retries,
            deadline_s=args.deadline, ttft_deadline_s=args.ttft_deadline,
            shed_watermark=args.shed_watermark,
            admission_control=args.admission_control,
            age_boost=age_boost, age_delay_s=age_delay,
            deadline_slack_s=deadline_slack,
            record_events=bool(args.metrics_out))
        print(json.dumps({"arch": cfg.name, "policy": policy,
                          "predictor": pred_spec or "trail-probe",
                          "router": args.router, "replicas": args.replicas,
                          **({"disagg": args.disagg} if args.disagg else {}),
                          "scenario": (f"trace:{args.trace}" if args.trace
                                       else args.scenario or "poisson"),
                          "rate": rate, **stats.summary()}, indent=1))
        if args.metrics_out:
            _write_metrics(args.metrics_out, stats.event_log, cfg,
                           hardware, reqs, kv_layout=kv_layout)
        return

    model = params = None
    mode = "sim"
    predictor = None
    if args.real:
        import jax
        from repro.models.model import build_model
        from repro.serving.predictors import ProbePredictor
        model = build_model(cfg)
        params = model.init(jax.random.key(args.seed))
        predictor = ProbePredictor(cfg.probe, probe_params=params["probe"],
                                   embed_table=params["embed"])
        mode = "real"

    event_log = None
    if args.metrics_out:
        from repro.metrics import EventLog
        event_log = EventLog()
    stats = run_policy(
        cfg, policy, reqs, c_limit=c_limit, max_batch=args.max_batch,
        mem_budget=mem_budget, mode=mode,
        predictor=predictor if predictor is not None else (pred_spec or None),
        model=model,
        params=params, hardware=hardware, seed=args.seed,
        kv_layout=kv_layout, prefix_cache=args.prefix_cache,
        deadline_s=args.deadline, ttft_deadline_s=args.ttft_deadline,
        shed_watermark=args.shed_watermark,
        admission_control=args.admission_control,
        age_boost=age_boost, age_delay_s=age_delay,
        deadline_slack_s=deadline_slack,
        event_log=event_log)
    print(json.dumps({"arch": cfg.name, "policy": policy,
                      "predictor": ("probe" if args.real
                                    else pred_spec or "trail-probe"),
                      "c": c_limit, "rate": rate,
                      "scenario": (f"trace:{args.trace}" if args.trace
                                   else args.scenario or
                                   ("burst" if args.burst else "poisson")),
                      **stats.summary()}, indent=1))
    if args.metrics_out:
        _write_metrics(args.metrics_out, event_log, cfg, hardware, reqs,
                       kv_layout=kv_layout)


def _run_front_door(args, cfg, *, policy, pred_spec, c_limit, age_boost,
                    age_delay_s, deadline_slack_s):
    """Run the online front door / closed-loop client modes.

    Three shapes, all over one sim engine built from the shared CLI
    knobs: ``--serve`` alone binds the HTTP/SSE server and serves until
    interrupted; ``--serve --clients N`` additionally drives it with a
    live socket pool and prints the closed-loop summary; ``--clients N``
    alone runs the deterministic in-process closed loop on the engine's
    virtual clock.
    """
    from repro.clients import (ClientPoolConfig, run_closed_loop,
                               run_live_pool)
    from repro.metrics import EventLog
    from repro.serving.engine import Engine, EngineConfig
    hardware = (HardwareSpec(name="compute-bound-2tf", peak_flops=2e12,
                             hbm_bw=819e9, overhead_s=2e-4)
                if args.compute_bound else HardwareSpec())
    mem_budget = int(args.mem_gb * 1e9) if args.mem_gb else 1 << 62
    kv_layout = args.kv_layout or ("paged" if args.prefix_cache or args.tail
                                   else "contig")
    engine = Engine(cfg, EngineConfig(
        policy=policy, c_limit=c_limit, max_batch=args.max_batch,
        mem_budget=mem_budget, kv_layout=kv_layout,
        prefix_cache=args.prefix_cache, predictor=pred_spec,
        hardware=hardware, seed=args.seed, deadline_s=args.deadline,
        ttft_deadline_s=args.ttft_deadline,
        shed_watermark=args.shed_watermark,
        admission_control=args.admission_control, age_boost=age_boost,
        age_delay_s=age_delay_s, deadline_slack_s=deadline_slack_s),
        event_log=EventLog())
    pool = ClientPoolConfig(
        n_clients=args.clients or 0,
        requests_per_client=args.requests_per_client or 4,
        think_time_s=(2.0 if args.think_time is None else args.think_time),
        timeout_s=args.deadline, max_retries=args.max_retries,
        seed=args.seed)
    meta = {"arch": cfg.name, "policy": policy,
            "predictor": pred_spec or "trail-probe"}
    if not args.serve:
        stats = run_closed_loop(engine, pool)
        print(json.dumps({**meta, "mode": "closed-loop",
                          "clients": pool.n_clients,
                          **stats.summary()}, indent=1))
        return

    import asyncio

    from repro.server import EngineServer, ServerConfig
    scfg = ServerConfig(port=8100 if args.port is None else args.port,
                        time_scale=args.time_scale or 1.0,
                        vocab=cfg.vocab_size, seed=args.seed)

    async def _amain():
        server = EngineServer(engine, scfg)
        await server.start()
        if args.clients:
            try:
                return await run_live_pool(scfg.host, server.port, pool,
                                           time_scale=scfg.time_scale)
            finally:
                await server.close()
        print(json.dumps({**meta, "mode": "serve",
                          "url": f"http://{scfg.host}:{server.port}",
                          "time_scale": scfg.time_scale}), flush=True)
        await server.serve_forever()

    try:
        stats = asyncio.run(_amain())
    except KeyboardInterrupt:
        return
    if stats is not None:
        print(json.dumps({**meta, "mode": "live",
                          "clients": pool.n_clients,
                          "time_scale": scfg.time_scale,
                          **stats.summary()}, indent=1))


def _write_metrics(path: str, event_log, cfg, hardware, reqs,
                   kv_layout: str = "contig"):
    """Roll the captured event stream up and write/print the report.

    The slowdown denominator must come from the same cost regime that
    drove the engine's clock, so a paged engine gets a paged CostModel
    (page-granular cache streaming) — otherwise slowdowns would divide
    paged-clock completions by contiguous-clock ideals.
    """
    from repro.metrics import (ideal_service_times, report_json,
                               report_markdown, rollup)
    from repro.serving.costmodel import CostModel
    from repro.serving.engine import EngineConfig
    page = EngineConfig().page_size if kv_layout == "paged" else 0
    service = ideal_service_times(CostModel(cfg, hardware, page_size=page),
                                  reqs)
    # per-tenant TTFT/completion splits whenever the workload is tagged
    # (multi-tenant scenarios, tenant-annotated traces)
    tenants = {r.rid: r.tenant for r in reqs if r.tenant}
    report = rollup(event_log, service_times=service,
                    tenants=tenants or None)
    with open(path, "w") as f:
        f.write(report_json(report))
    print(report_markdown(report, title=f"metrics -> {path}"))


if __name__ == "__main__":
    main()
