"""Serving launcher: run the TRAIL engine over a workload.

    # paper-scale policy comparison under the roofline cost model
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --policy trail --rate 14 --n 300

    # real end-to-end on a CPU-sized model (trains briefly first)
    PYTHONPATH=src python -m repro.launch.serve --arch trail-llama \
        --smoke --real --policy trail --n 16
"""

from __future__ import annotations

import argparse
import json

from repro.config import ARCH_IDS, get_config, get_smoke_config
from repro.core.scheduler import POLICIES
from repro.serving.engine import run_policy
from repro.serving.workload import WorkloadConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b",
                    choices=ARCH_IDS + ("trail-llama",))
    ap.add_argument("--policy", default="trail", choices=POLICIES)
    ap.add_argument("--c", type=float, default=0.8)
    ap.add_argument("--rate", type=float, default=14.0)
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--burst", action="store_true")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--mem-gb", type=float, default=0.0,
                    help="KV memory budget (0 = unlimited)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--real", action="store_true",
                    help="actually run the model (CPU-sized configs)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    wc = WorkloadConfig(n_requests=args.n, request_rate=args.rate,
                        burst=args.burst, vocab=cfg.vocab_size,
                        seed=args.seed)
    if args.real:
        wc = WorkloadConfig(n_requests=args.n, request_rate=args.rate,
                            burst=args.burst, vocab=cfg.vocab_size,
                            prompt_mean=10.0, out_median=8.0, max_out=32,
                            seed=args.seed)
    reqs = generate(wc)

    model = params = None
    mode = "sim"
    predictor = None
    if args.real:
        import jax
        from repro.models.model import build_model
        from repro.serving.predictors import ProbePredictor
        model = build_model(cfg)
        params = model.init(jax.random.key(args.seed))
        predictor = ProbePredictor(cfg.probe, probe_params=params["probe"],
                                   embed_table=params["embed"])
        mode = "real"

    stats = run_policy(
        cfg, args.policy, reqs, c_limit=args.c, max_batch=args.max_batch,
        mem_budget=int(args.mem_gb * 1e9) if args.mem_gb else 1 << 62,
        mode=mode, predictor=predictor, model=model, params=params,
        seed=args.seed)
    print(json.dumps({"arch": cfg.name, "policy": args.policy,
                      "c": args.c, "rate": args.rate,
                      **stats.summary()}, indent=1))


if __name__ == "__main__":
    main()
