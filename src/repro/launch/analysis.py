"""Roofline-term extraction from compiled dry-run artifacts.

Caveat discovered during calibration (see EXPERIMENTS.md §Roofline): XLA's
``cost_analysis()`` counts a ``while`` body ONCE, not per trip — a
scan-over-layers model under-reports flops/bytes by ~the layer count.
Therefore:

  * collective term — parsed from the partitioned HLO text per
    *computation*, then scaled by each while's ``known_trip_count``
    (recursively, so KV-block scans nested inside layer scans are handled);
  * compute term   — analytic MODEL_FLOPS (6·N_active·D train, 2·N·D
    inference, + attention window terms), the exact lower bound on MXU work;
  * memory term    — analytic traffic model (weight shards + optimizer
    state + activations + KV-cache streaming per device);
  * raw HLO flops/bytes are retained in the report for transparency.

Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.config import KIND_LOCAL, KIND_SSM, InputShape, ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)"
    r"\[([\d,]*)\]")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")  # nested parens ok
_WHILE_BODY = re.compile(r"body=%?([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")


def shape_bytes(dtype: str, dims: str) -> int:
    """Byte size of one HLO shape literal (dtype + comma-joined dims)."""
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_result_bytes(rhs: str) -> int:
    head = rhs.split("(", 1)[0] if not rhs.startswith("(") else \
        rhs[:rhs.index(")") + 1]
    return sum(shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(head))


def parse_collectives(hlo_text: str) -> dict[str, float]:
    """Trip-count-aware collective bytes per op kind (per-device program)."""
    # 1. split into computations
    comps: dict[str, dict] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and "->" in line and line.rstrip().endswith("{"):
            m = _COMP_HEAD.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = {"coll": {}, "subs": []}
                if m.group(1):
                    entry = cur
                continue
        if cur is None or " = " not in line:
            continue
        _, rhs = line.split(" = ", 1)
        op_hit = None
        for op in _COLL_OPS:
            if re.search(rf"\b{op}(-start)?\(", rhs):
                op_hit = op
                break
        if op_hit:
            b = _line_result_bytes(rhs)
            comps[cur]["coll"][op_hit] = comps[cur]["coll"].get(op_hit, 0) + b
        if " while(" in rhs:
            mb = _WHILE_BODY.search(rhs)
            mt = _TRIP.search(rhs)
            if mb:
                comps[cur]["subs"].append(
                    (mb.group(1), int(mt.group(1)) if mt else 1))
        else:
            for name in _CALLS.findall(rhs):
                comps[cur]["subs"].append((name, 1))

    # 2. DFS from entry, scaling by trip counts (memoized on comp name)
    memo: dict[str, dict[str, float]] = {}

    def total(name: str, depth=0) -> dict[str, float]:
        """Trip-count-scaled collective bytes of one computation subtree."""
        if name in memo or depth > 32 or name not in comps:
            return memo.get(name, {})
        out = dict(comps[name]["coll"])
        for sub, trips in comps[name]["subs"]:
            for op, b in total(sub, depth + 1).items():
                out[op] = out.get(op, 0.0) + trips * b
        memo[name] = out
        return out

    res = {op: 0.0 for op in _COLL_OPS}
    if entry:
        res.update({op: float(b) for op, b in total(entry).items()})
    return res


# ---------------------------------------------------------------------------
# Analytic compute / memory models (per device)
# ---------------------------------------------------------------------------

def model_flops_estimate(cfg: ModelConfig, shape: InputShape) -> float:
    """Global step FLOPs: 6·N·D train / 2·N·D inference + attention terms."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n * tokens
        attn = 3.0 * _attn_flops_prefill(cfg, shape.seq_len) * shape.global_batch
        return base + attn
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens + _attn_flops_prefill(
            cfg, shape.seq_len) * shape.global_batch
    return (2.0 * n + _attn_flops_decode(cfg, shape.seq_len)) * shape.global_batch


def _attn_flops_prefill(cfg: ModelConfig, S: int) -> float:
    f = 0.0
    for kind in cfg.layer_kinds:
        if kind == KIND_SSM:
            f += 6.0 * cfg.ssm_expand * cfg.d_model * cfg.ssm_state * S
            continue
        eff = min(S, cfg.sliding_window) if kind == KIND_LOCAL else S
        f += 4.0 * cfg.q_dim * eff * S / (1 if kind == KIND_LOCAL else 2)
    return f


def _attn_flops_decode(cfg: ModelConfig, ctx: int) -> float:
    f = 0.0
    for kind in cfg.layer_kinds:
        if kind == KIND_SSM:
            f += 4.0 * cfg.ssm_expand * cfg.d_model * cfg.ssm_state
            continue
        eff = min(ctx, cfg.sliding_window) if kind == KIND_LOCAL else ctx
        f += 4.0 * cfg.q_dim * eff
    return f


def model_bytes_estimate(cfg: ModelConfig, shape: InputShape,
                         n_chips: int) -> float:
    """Per-device HBM traffic per step (weights + state + activations)."""
    from repro.serving.kv_cache import bytes_for_context
    wbytes = cfg.param_count() * 2.0            # bf16 weights, read once
    per_dev = wbytes / n_chips
    if shape.mode == "train":
        # fwd+bwd weight reads, f32 grads r/w, AdamW moments r/w, master r/w
        per_dev += (cfg.param_count() * (2.0 + 4.0 * 2 + 4.0 * 4)) / n_chips
        tokens = shape.global_batch * shape.seq_len
        per_dev += tokens * cfg.d_model * 2.0 * cfg.num_layers * 8 / n_chips
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        per_dev += tokens * cfg.d_model * 2.0 * cfg.num_layers * 4 / n_chips
        per_dev += shape.global_batch * bytes_for_context(
            cfg, shape.seq_len) / n_chips
    else:
        per_dev += shape.global_batch * bytes_for_context(
            cfg, shape.seq_len) / n_chips
    return per_dev


@dataclass
class Roofline:
    """Per-device roofline terms extracted from one compiled case."""

    flops_per_device: float           # raw HLO (while bodies counted once)
    bytes_per_device: float           # raw HLO
    collective_bytes: float           # trip-count-scaled, per device
    n_chips: int
    model_flops: float = 0.0          # analytic, global
    model_bytes_per_device: float = 0.0
    collectives: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        """Analytic MXU seconds/step/device (exact lower bound)."""
        return self.model_flops / self.n_chips / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        """Analytic HBM seconds/step/device."""
        return self.model_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        """ICI seconds/step/device (trip-count-scaled collective bytes)."""
        return self.collective_bytes / ICI_BW

    @property
    def hlo_compute_s(self) -> float:
        """Raw-HLO compute seconds (while bodies counted once)."""
        return self.flops_per_device / PEAK_FLOPS

    @property
    def hlo_memory_s(self) -> float:
        """Raw-HLO memory seconds (while bodies counted once)."""
        return self.bytes_per_device / HBM_BW

    @property
    def bottleneck(self) -> str:
        """The dominating roofline term: compute | memory | collective."""
        terms = {"compute": self.compute_s,
                 "memory": max(self.memory_s, self.hlo_memory_s),
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs. NOTE: >1 just means the HLO count
        hides while-loop trips; <1 flags remat/dispatch-redundancy waste."""
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> dict:
        """JSON-friendly report form (dry-run artifact `roofline` key)."""
        return {
            "flops_per_device_hlo_raw": self.flops_per_device,
            "bytes_per_device_hlo_raw": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
            "collectives_by_op": self.collectives,
            "compute_s": self.compute_s,
            "memory_s": max(self.memory_s, self.hlo_memory_s),
            "memory_s_analytic": self.memory_s,
            "memory_s_hlo_raw": self.hlo_memory_s,
            "compute_s_hlo_raw": self.hlo_compute_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "n_chips": self.n_chips,
        }
