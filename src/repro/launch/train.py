"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch trail-llama \
        --steps 200 --batch 8 --seq 256 [--smoke] [--ckpt out.npz]

On this CPU container it trains the reduced/smoke variants for real; on a
TPU slice the same entry point shards the identical train_step over the
production mesh (--mesh prod).
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.config import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import build_model
from repro.training import optimizer as opt_mod
from repro.training.checkpoint import save
from repro.training.data import DataConfig, batches
from repro.training.train import train_lm


def main():
    """CLI entry: train the chosen arch and optionally save a checkpoint."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="trail-llama",
                    choices=ARCH_IDS + ("trail-llama",))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    dc = DataConfig(vocab=cfg.vocab_size, seq_len=args.seq, batch=args.batch,
                    max_out=min(448, args.seq - 64), seed=args.seed)
    ocfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10,
                               total_steps=args.steps)
    params, _, hist = train_lm(
        model, params, batches(dc, args.steps), ocfg, args.steps,
        callback=lambda r: print(json.dumps(r)))
    if args.ckpt:
        save(args.ckpt, {"params": params, "config": {}})
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
