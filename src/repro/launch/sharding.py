"""Shape-aware sharding rules for parameters, optimizer state, batches and
caches.

Strategy (DESIGN.md section 6):
  * weights: tensor-parallel over "model" (heads / d_ff / experts / vocab),
    FSDP over "data" on the other big axis; replicated over "pod"
    (pods are pure DP — gradient all-reduce crosses pods once per step);
  * batch/activations: batch dim over ("pod","data");
  * KV caches: batch over data axes, kv-heads over "model" when they are
    wide enough; SSM state heads over "model".

Every rule is validated against the actual leaf shape: an axis is used only
if dim_size >= axis_size (degenerate padding refused); uneven-but-wide dims
are allowed (GSPMD pads).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


def _fits(dim: int, mesh, axis) -> bool:
    """jit in_shardings demand exact divisibility (no GSPMD padding)."""
    if axis is None:
        return True
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim >= size and dim % size == 0


def _spec_for(shape, mesh, want):
    """Clamp a desired spec to the shape (drop axes that don't fit)."""
    want = tuple(want) + (None,) * (len(shape) - len(want))
    out = []
    for dim, ax in zip(shape, want):
        out.append(ax if _fits(dim, mesh, ax) else None)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

_EXPERT_FSDP_BYTES = 1e7   # FSDP expert weights over "data" only above this


def _param_rule(path: tuple[str, ...], ndim: int, dp, shape=(),
                mesh=None):
    """Desired spec for the *trailing* dims (leading run-stack dim -> None)."""
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    if name in ("embed", "unembed", "pos_embed", "pos"):
        return ("model", "data")
    if parent == "moe" and name in ("w_gate", "w_up", "w_down"):
        # (E, d, ff) / (E, ff, d): experts over "model"; FSDP the matrix
        # dims over "data" only when a model-shard is big (arctic 58 GB/dev
        # without it) — small-expert archs (olmoe) keep weights whole so the
        # expert einsum needs no per-layer weight collectives (§Perf).
        n = 1
        for s in shape:
            n *= s
        model_ways = mesh.shape.get("model", 1) if mesh is not None else 1
        if n * 4 / model_ways > _EXPERT_FSDP_BYTES:
            return ("model", "data", None)
        return ("model",)
    if name == "router":
        return ("data", "model")
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj"):
        return ("data", "model")
    if name in ("wo", "w_down", "out_proj"):
        return ("model", "data")
    if name in ("bq", "bk", "bv", "b_up"):
        return ("model",)
    return (None,)                             # norms, biases, scalars, probe


def param_specs(params_sds, mesh):
    """PartitionSpec tree for a parameter pytree (TP + FSDP rules)."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        """Clamped spec for one parameter leaf."""
        names = tuple(_key_name(k) for k in path)
        shape = leaf.shape
        want = _param_rule(names, leaf.ndim, dp, shape=shape, mesh=mesh)
        # stacked run params have a leading layer dim: shift rules right
        pad = leaf.ndim - len(want)
        if pad > 0:
            want = (None,) * pad + want
        return _spec_for(shape, mesh, want)

    return jax.tree_util.tree_map_with_path(spec, params_sds)


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ---------------------------------------------------------------------------
# Batches / caches / optimizer state
# ---------------------------------------------------------------------------

def batch_specs(batch_sds, mesh):
    """PartitionSpec tree for batch inputs (batch dim over data axes)."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        """Batch-dim-over-data spec for one input leaf."""
        return _spec_for(leaf.shape, mesh, (dp,))
    return jax.tree_util.tree_map_with_path(spec, batch_sds)


# How to shard KV caches whose head count is too narrow for the "model"
# axis (GQA/MQA): "seq" shards the cache sequence dim — softmax reductions
# over the sharded dim become tiny per-row all-reduces (flash-decode
# pattern); "hd" shards head_dim — the QK contraction all-reduces full score
# tensors per layer. "seq" won the §Perf hillclimb on granite decode_32k.
KV_SHARD = "seq"


def cache_specs(cache_sds, mesh, kv_shard: str | None = None):
    """PartitionSpec tree for KV/SSM caches (see `KV_SHARD` narrow-KH modes)."""
    dp = data_axes(mesh)
    kv_shard = kv_shard or KV_SHARD
    seq_mode = kv_shard == "seq"

    def spec(path, leaf):
        """Per-cache-leaf spec (kv-heads / seq / head_dim over model)."""
        names = tuple(_key_name(k) for k in path)
        name = names[-1]
        if name == "lengths":
            return _spec_for(leaf.shape, mesh, (dp,))
        if name in ("k", "v", "ck", "cv"):   # (n, B, M|T, KH, hd)
            # Prefer sharding KV heads over "model"; narrow-KH (GQA/MQA)
            # archs shard the sequence dim ("seq") or head_dim ("hd") —
            # see KV_SHARD above and EXPERIMENTS.md §Perf.
            if _fits(leaf.shape[3], mesh, "model"):
                return _spec_for(leaf.shape, mesh, (None, dp, None, "model"))
            if seq_mode:
                return _spec_for(leaf.shape, mesh, (None, dp, "model"))
            return _spec_for(leaf.shape, mesh, (None, dp, None, None, "model"))
        if name in ("kpos", "k_scale", "v_scale"):   # (n, B, M[, KH])
            if seq_mode:
                return _spec_for(leaf.shape, mesh, (None, dp, "model"))
            return _spec_for(leaf.shape, mesh, (None, dp))
        if name == "ssm_state":      # (n, B, nh, hp, N)
            return _spec_for(leaf.shape, mesh, (None, dp, "model"))
        if name == "conv_buf":       # (n, B, W-1, ch)
            return _spec_for(leaf.shape, mesh, (None, dp, None, "model"))
        return _spec_for(leaf.shape, mesh, (None,))
    return jax.tree_util.tree_map_with_path(spec, cache_sds)


def opt_specs(opt_sds, pspecs):
    """Optimizer moments shard exactly like their parameters."""
    return {
        "step": P(),
        "mu": pspecs,
        "nu": pspecs,
    }


def to_shardings(spec_tree, mesh):
    """Bind a PartitionSpec tree to a mesh as NamedShardings."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P))
