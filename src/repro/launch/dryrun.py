"""Multi-pod dry run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape decode_32k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results are appended as JSON files under experiments/dryrun/ and summarized
in EXPERIMENTS.md section Dry-run / section Roofline.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede the jax import: jax locks the device count on first
#   init, and the production meshes below need 512 placeholder host devices.

import argparse
import json
import time
import traceback

import jax

from repro.config import (ARCH_IDS, INPUT_SHAPES, get_config, shape_applies)
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh, n_chips
from repro.launch.sharding import (batch_specs, cache_specs, opt_specs,
                                   param_specs, to_shardings)
from repro.launch.steps import (input_specs, make_prefill_step,
                                make_serve_step, make_train_step_for)
from repro.models.model import build_model


def lower_case(arch: str, shape_name: str, multi_pod: bool,
               donate: bool = True, kv_shard: str | None = None,
               kv_quant: bool = False) -> dict:
    """Lower + compile one (arch, input-shape) case; return its report dict
    (memory analysis, collective bytes, roofline terms, timings)."""
    cfg = get_config(arch)
    if kv_quant:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_quant=True)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "mode": shape.mode}
    if not shape_applies(cfg, shape):
        return {**base, "skipped": "long_500k needs sub-quadratic attention "
                                   "(DESIGN.md section 5)"}

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = input_specs(cfg, shape_name, model)
    pspecs = param_specs(spec["params"], mesh)
    pshard = to_shardings(pspecs, mesh)
    t0 = time.time()
    # ambient mesh: activates models/hints.py. jax.set_mesh landed in
    # jax 0.4.38; on older jax the Mesh object itself is the context
    # manager (hints degrade to no-ops there, lowering still succeeds).
    ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    ctx.__enter__()

    if spec["mode"] == "train":
        step = make_train_step_for(model)
        oshard = to_shardings(
            jax.tree.map(lambda s: s, opt_specs(spec["opt"], pspecs),
                         is_leaf=lambda x: isinstance(
                             x, jax.sharding.PartitionSpec)), mesh)
        bshard = to_shardings(batch_specs(spec["batch"], mesh), mesh)
        jf = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     donate_argnums=(0, 1) if donate else ())
        lowered = jf.lower(spec["params"], spec["opt"], spec["batch"])
    elif spec["mode"] == "prefill":
        cshard = to_shardings(cache_specs(spec["cache"], mesh,
                                          kv_shard=kv_shard), mesh)
        bshard = to_shardings(batch_specs(spec["batch"], mesh), mesh)
        base_step = make_prefill_step(model)
        keys = sorted(spec["batch"].keys())          # tokens [+ frontend]
        fr_keys = [k for k in keys if k != "tokens"]

        def step(params, cache, tokens, *fr):
            """Positional-frontend adapter for jit in_shardings."""
            kw = dict(zip(fr_keys, fr))
            return base_step(params, cache, tokens, **kw)
        jf = jax.jit(step, in_shardings=(
            pshard, cshard, bshard["tokens"],
            *[bshard[k] for k in fr_keys]),
            donate_argnums=(1,) if donate else ())
        lowered = jf.lower(spec["params"], spec["cache"],
                           spec["batch"]["tokens"],
                           *[spec["batch"][k] for k in fr_keys])
    else:
        cshard = to_shardings(cache_specs(spec["cache"], mesh,
                                          kv_shard=kv_shard), mesh)
        bshard = to_shardings(batch_specs(spec["batch"], mesh), mesh)
        step = make_serve_step(model)
        jf = jax.jit(step, in_shardings=(
            pshard, cshard, bshard["tokens"], bshard["q_prev"]),
            donate_argnums=(1,) if donate else ())
        lowered = jf.lower(spec["params"], spec["cache"],
                           spec["batch"]["tokens"], spec["batch"]["q_prev"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ctx.__exit__(None, None, None)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax < 0.4.38: one dict per program
        cost = cost[0] if cost else {}
    coll = analysis.parse_collectives(compiled.as_text())
    rl = analysis.Roofline(
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=float(sum(coll.values())),
        n_chips=n_chips(mesh),
        model_flops=analysis.model_flops_estimate(cfg, shape),
        model_bytes_per_device=analysis.model_bytes_estimate(
            cfg, shape, n_chips(mesh)),
        collectives=coll)
    report = {
        **base,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "peak_per_device_gb": (mem.argument_size_in_bytes
                                   + mem.output_size_in_bytes
                                   + mem.temp_size_in_bytes
                                   - mem.alias_size_in_bytes) / 1e9,
        },
        "collectives": coll,
        "roofline": rl.as_dict(),
    }
    return report


def main():
    """CLI entry: run the selected (or all) dry-run cases and save JSON."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv-shard", choices=("seq", "hd"), default=None,
                    help="narrow-KH cache sharding mode (default: "
                         "sharding.KV_SHARD)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache with per-(token,head) scales")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cases = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                cases.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cases.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cases:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        tag = f"{arch}_{shape}_{mesh_name}"
        try:
            rep = lower_case(arch, shape, args.multi_pod,
                             kv_shard=args.kv_shard, kv_quant=args.kv_quant)
        except Exception as e:  # noqa: BLE001 — report and continue
            rep = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            failures += 1
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rep, f, indent=1)
        if "skipped" in rep:
            print(f"[skip] {tag}: {rep['skipped']}")
        elif "error" in rep:
            print(f"[FAIL] {tag}: {rep['error']}")
        else:
            r = rep["roofline"]
            print(f"[ok]  {tag}: mem {rep['memory']['peak_per_device_gb']:.2f}GB/dev "
                  f"compute {r['compute_s']:.2e}s memory {r['memory_s']:.2e}s "
                  f"coll {r['collective_s']:.2e}s -> {r['bottleneck']} "
                  f"(lower {rep['lower_s']}s compile {rep['compile_s']}s)")
    if failures:
        raise SystemExit(f"{failures} case(s) failed")


if __name__ == "__main__":
    main()
