"""Step functions the launchers shard and the dry-run lowers.

``make_serve_step`` is the paper's fused per-iteration hot path: decode one
token for every sequence AND refine the length posterior (probe MLP +
Bayesian filter) inside the same jitted program — the TPU-native form of
TRAIL's Section 3.2 overlap trick.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import INPUT_SHAPES, ModelConfig
from repro.core.smoothing import bayes_update, expected_length, transition_matrix
from repro.models.model import Model
from repro.training import optimizer as opt_mod
from repro.training.train import make_train_step


def make_serve_step(model: Model):
    """The fused decode+probe serving step (Section 3.2 overlap trick)."""
    cfg = model.cfg
    T = jnp.asarray(transition_matrix(cfg.probe), jnp.float32)

    def serve_step(params, cache, tokens, q_prev):
        """tokens: (B,1); q_prev: (B,k) posterior from the last iteration.

        Returns (next_token (B,), cache, q_new (B,k), pred_remaining (B,)).
        """
        logits, cache, _tap, probe_logits = model.decode_step(
            params, cache, tokens)
        p = jax.nn.softmax(probe_logits, axis=-1)
        q_new = bayes_update(q_prev, p, T)
        pred_remaining = expected_length(q_new, cfg.probe)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache, q_new, pred_remaining

    return serve_step


def make_prefill_step(model: Model):
    """A chunked-prefill step bound to one model."""
    def prefill_step(params, cache, tokens, **frontend):
        """Prefill one token chunk into the cache."""
        return model.prefill_chunk(params, cache, tokens, **frontend)
    return prefill_step


def default_opt_config(cfg: ModelConfig) -> opt_mod.AdamWConfig:
    """Production AdamW defaults, sized to the arch's parameter count."""
    # bf16 moments on the giant MoE keep master+moments inside v5e HBM
    moment_dtype = "bfloat16" if cfg.param_count() > 1e11 else "float32"
    return opt_mod.AdamWConfig(lr=3e-4, warmup_steps=200, total_steps=20000,
                               moment_dtype=moment_dtype)


def make_train_step_for(model: Model):
    """A train step bound to the model with its default optimizer config."""
    return make_train_step(model, default_opt_config(model.cfg))


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for every model input (no device allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str, model: Model) -> dict:
    """Returns {"args": tuple_of_sds, "mode": str} for the given input shape."""
    sds = jax.ShapeDtypeStruct
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    frontend = {}
    if cfg.family == "audio":
        frontend["enc_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                     jnp.float32)
    if cfg.family == "vlm":
        frontend["prefix_embeds"] = sds((B, cfg.num_prefix_tokens,
                                         cfg.d_model), jnp.float32)

    params_sds = jax.eval_shape(model.init, jax.random.key(0))

    if shape.mode == "train":
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32),
                 **frontend}
        ocfg = default_opt_config(cfg)
        opt_sds = jax.eval_shape(lambda p: opt_mod.init(ocfg, p), params_sds)
        return {"mode": "train", "params": params_sds, "opt": opt_sds,
                "batch": batch}

    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, S))
    if shape.mode == "prefill":
        batch = {"tokens": sds((B, S), i32), **frontend}
        return {"mode": "prefill", "params": params_sds, "cache": cache_sds,
                "batch": batch}

    # decode: one token against a seq_len cache, probe posterior carried
    batch = {"tokens": sds((B, 1), i32),
             "q_prev": sds((B, cfg.probe.num_bins), jnp.float32)}
    return {"mode": "decode", "params": params_sds, "cache": cache_sds,
            "batch": batch}
