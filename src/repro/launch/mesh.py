"""Production mesh construction (TPU v5e pods).

Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2, data=16,
model=16) = 512 chips — the pod axis is pure data parallelism (gradient
all-reduce crosses the inter-pod DCN/ICI boundary; everything else stays
within a pod).

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax.sharding.AxisType landed in jax 0.4.38; older jax's make_mesh
    has no axis_types parameter (all axes are Auto there anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """The (data, model) single-pod or (pod, data, model) multi-pod mesh."""
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(
        shape, axes, devices=devices[:n], **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """A trivial 1x1 mesh for single-device smoke runs."""
    return jax.make_mesh(
        (1, 1), ("data", "model"), devices=jax.devices()[:1],
        **_axis_type_kwargs(2))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch-sharding axes for this mesh ((pod,data) or (data,))."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_chips(mesh) -> int:
    """Total device count of a mesh."""
    return mesh.devices.size
