"""Roll a per-request event log up into distribution-level metrics.

`rollup()` consumes an `EventLog` (one engine's, or a cluster merge) and
produces the benchmark-facing report: per-metric mean + exact
p50/p90/p99 for TTFT, TBT (inter-token latency), completion time,
slowdown, and per-token normalized latency; SLO-attainment curves over
fixed threshold grids; and preemption / swap / prefix-cache counters.

Metric definitions (all in engine-clock seconds):

* **TTFT**  — first token time minus arrival.
* **TBT**   — gap between consecutive output tokens of one request,
  *excluding* the TTFT gap. A decode megastep materializes k tokens at
  one timestamp; their shared inter-step gap is split evenly across the
  k tokens (and extra tokens inside the *first* token event count a
  0-gap — they reached the stream in the same flush).
* **completion** — finish minus arrival.
* **slowdown** — completion divided by the request's ideal isolated
  service time (supplied via ``service_times``, e.g. from
  `CostModel.ideal_service_time`); omitted when no estimate is given.
* **latency_per_token** — completion divided by output length (the
  learning-to-rank literature's normalized latency).
"""

from __future__ import annotations

from repro.metrics.events import EventLog
from repro.metrics.streaming import DEFAULT_PERCENTILES, StreamingQuantiles

#: Default SLO threshold grids (seconds). Fixed — not data-derived — so
#: attainment curves are comparable across policies, seeds, and runs.
DEFAULT_SLOS: dict[str, tuple] = {
    "ttft": (0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0),
    "tbt": (0.05, 0.1, 0.2, 0.5, 1.0, 2.0),
    "completion": (5.0, 15.0, 30.0, 60.0, 120.0, 300.0),
}


def _attainment_curve(acc: StreamingQuantiles, slos) -> list[dict]:
    return [{"slo_s": float(s), "attainment": acc.attainment(s)}
            for s in slos]


def ideal_service_times(cost_model, requests) -> dict[int, float]:
    """rid → isolated completion time, the slowdown denominator.

    The single definition shared by the serve CLI and the benchmarks —
    evaluated through `CostModel.ideal_service_time` so the slowdown
    metric can never drift between emitters.
    """
    return {r.rid: cost_model.ideal_service_time(len(r.prompt),
                                                 r.true_out_len)
            for r in requests}


def rollup(log: EventLog, *, service_times: dict[int, float] | None = None,
           slos: dict[str, tuple] | None = None,
           percentiles=DEFAULT_PERCENTILES,
           tenants: dict[int, str] | None = None) -> dict:
    """Aggregate an event log into the benchmark-facing metrics report.

    Args:
        log: the captured event stream (`Engine(event_log=...)`).
        service_times: optional rid → ideal isolated service time
            (seconds); enables the ``slowdown`` distribution.
        slos: per-metric SLO threshold grids; defaults to `DEFAULT_SLOS`.
            Keys: ``ttft`` | ``tbt`` | ``completion``.
        percentiles: which percentiles each summary carries.
        tenants: optional rid → tenant label; when given, the report
            gains a ``per_tenant`` section with per-tenant TTFT and
            completion summaries (the per-tenant p99 split that makes
            cross-tenant starvation visible). Absent by default so
            existing reports keep their exact structure.

    Returns:
        A JSON-ready dict: ``requests`` (arrived/finished counts),
        per-metric summaries, ``slo_attainment`` curves, and counters
        (including the tail-facing ``max_wait_s`` — the worst
        first-token wait observed, charging still-waiting requests up
        to the log's last event — and ``preemptions_per_request``).
        Deterministic: identical logs yield byte-identical
        ``json.dumps(..., sort_keys=True)`` output.
    """
    slos = {**DEFAULT_SLOS, **(slos or {})}
    ttft = StreamingQuantiles()
    tbt = StreamingQuantiles()
    completion = StreamingQuantiles()
    slowdown = StreamingQuantiles()
    per_token = StreamingQuantiles()
    n_arrived = n_finished = 0
    n_cancelled = n_timeouts = n_shed = n_retries = 0
    replica_downs = 0
    preemptions = 0
    handoffs = 0
    handoff_pages = 0.0
    swap_bytes = 0.0
    prefix_hit_tokens = 0.0
    total_tokens = 0.0
    max_wait = 0.0
    t_end = 0.0
    unstarted_arrivals: list[float] = []
    by_tenant: dict[str, dict[str, StreamingQuantiles]] = {}

    for rid, evs in sorted(log.per_request().items()):
        arrival = first_tok = finish = None
        cancelled = False
        tok_events: list[tuple[float, int]] = []
        for e in evs:
            if e.kind == "arrival" and arrival is None:
                arrival = e.t
            elif e.kind == "first_token" and first_tok is None:
                first_tok = e.t
            elif e.kind == "tokens":
                tok_events.append((e.t, int(e.value)))
                total_tokens += e.value
            elif e.kind == "finish" and finish is None:
                finish = e.t
            elif e.kind == "preempt":
                preemptions += 1
            elif e.kind == "swap":
                swap_bytes += e.value
            elif e.kind == "prefix_hit":
                prefix_hit_tokens += e.value
            elif e.kind in ("cancel", "timeout", "shed"):
                if not cancelled:           # one terminal cancel per rid
                    cancelled = True
                    n_cancelled += 1
                    if e.kind == "timeout":
                        n_timeouts += 1
                    elif e.kind == "shed":
                        n_shed += 1
            elif e.kind == "retry":
                n_retries += 1
            elif e.kind == "replica_down":
                replica_downs += 1
            elif e.kind == "handoff":
                handoffs += 1
                handoff_pages += e.value
        if evs:
            t_end = max(t_end, max(e.t for e in evs))
        tenant = tenants.get(rid) if tenants else None
        if arrival is not None:
            n_arrived += 1
            if first_tok is not None:
                # TTFT is determined at the first token — record it even
                # for in-flight requests, or a mid-run rollup would drop
                # exactly the long-stuck started-but-unfinished tail and
                # flatter the TTFT distribution
                ttft.add(first_tok - arrival)
                max_wait = max(max_wait, first_tok - arrival)
                if tenant is not None:
                    by_tenant.setdefault(tenant, {
                        "ttft": StreamingQuantiles(),
                        "completion": StreamingQuantiles(),
                    })["ttft"].add(first_tok - arrival)
            else:
                # never started: charge its wait up to the log's last
                # event (resolved once t_end is final, after the loop)
                unstarted_arrivals.append(arrival)
        if finish is None or arrival is None:
            continue                    # unfinished: TTFT + counters only
        n_finished += 1
        lat = finish - arrival
        completion.add(lat)
        if tenant is not None:
            by_tenant.setdefault(tenant, {
                "ttft": StreamingQuantiles(),
                "completion": StreamingQuantiles(),
            })["completion"].add(lat)
        out_len = sum(n for _, n in tok_events)
        if out_len > 0:
            per_token.add(lat / out_len)
        if service_times and rid in service_times and service_times[rid] > 0:
            slowdown.add(lat / service_times[rid])
        # inter-token gaps: megastep events spread their gap over their
        # k tokens; the first event's extra tokens landed in one flush
        prev_t = None
        for t, n in tok_events:
            if n <= 0:
                continue
            if prev_t is None:
                if n > 1:
                    tbt.extend([0.0] * (n - 1))
            else:
                tbt.extend([(t - prev_t) / n] * n)
            prev_t = t

    report = {
        "requests": {"arrived": n_arrived, "finished": n_finished,
                     "cancelled": n_cancelled,
                     # goodput: fraction of arrived requests actually
                     # served to completion — cancelled/timed-out/shed/
                     # lost requests all count against it
                     "goodput": (n_finished / n_arrived
                                 if n_arrived else 0.0),
                     "output_tokens": total_tokens},
        "ttft": ttft.summary(percentiles),
        "tbt": tbt.summary(percentiles),
        "completion": completion.summary(percentiles),
        "latency_per_token": per_token.summary(percentiles),
        "slo_attainment": {
            "ttft": _attainment_curve(ttft, slos["ttft"]),
            "tbt": _attainment_curve(tbt, slos["tbt"]),
            "completion": _attainment_curve(completion, slos["completion"]),
        },
        "counters": {"preemptions": preemptions,
                     "preemptions_per_request": (preemptions / n_arrived
                                                 if n_arrived else 0.0),
                     "max_wait_s": max(
                         [max_wait] + [t_end - a
                                       for a in unstarted_arrivals]),
                     "swap_bytes": swap_bytes,
                     "prefix_hit_tokens": prefix_hit_tokens,
                     "cancelled": n_cancelled,
                     "timeouts": n_timeouts,
                     "shed": n_shed,
                     "retries": n_retries,
                     "replica_downs": replica_downs,
                     "handoffs": handoffs,
                     "handoff_pages": handoff_pages},
    }
    if len(slowdown):
        report["slowdown"] = slowdown.summary(percentiles)
    if tenants is not None:
        report["per_tenant"] = {
            tenant: {"ttft": accs["ttft"].summary(percentiles),
                     "completion": accs["completion"].summary(percentiles)}
            for tenant, accs in sorted(by_tenant.items())
        }
    return report
