"""Exact, mergeable percentile accumulators for the metrics rollup.

Benchmark-scale request counts (10^2..10^6) fit comfortably in memory,
so approximation sketches (t-digest, P²) would trade accuracy for
nothing here: `StreamingQuantiles` keeps every sample in an amortized
-growth flat buffer and answers percentile queries *exactly*, matching
``numpy.percentile(..., method="linear")`` bit-for-bit
(``tests/test_metrics.py`` pins this against random samples). The
streaming part is the API: O(1) amortized `add()`, mergeable across
cluster replicas, and deterministic summaries independent of insertion
order.
"""

from __future__ import annotations

import numpy as np

#: The tail percentiles every benchmark reports.
DEFAULT_PERCENTILES = (50.0, 90.0, 99.0)


class StreamingQuantiles:
    """Exact percentile accumulator over a growing sample stream.

    Samples append into a pre-sized numpy buffer (doubling growth);
    queries sort a copy on demand and cache the sorted view until the
    next mutation. Summaries are a function of the sample *multiset*
    only — insertion and merge order never change a digit, which the
    replay-determinism guarantee relies on.
    """

    __slots__ = ("_buf", "_n", "_sorted")

    def __init__(self, values=None):
        self._buf = np.empty(64, np.float64)
        self._n = 0
        self._sorted = None
        if values is not None:
            self.extend(values)

    def __len__(self) -> int:
        return self._n

    def _grow(self, need: int):
        cap = len(self._buf)
        if self._n + need <= cap:
            return
        while cap < self._n + need:
            cap *= 2
        buf = np.empty(cap, np.float64)
        buf[:self._n] = self._buf[:self._n]
        self._buf = buf

    def add(self, x: float):
        """Append one sample (O(1) amortized)."""
        self._grow(1)
        self._buf[self._n] = x
        self._n += 1
        self._sorted = None

    def extend(self, xs):
        """Append a batch of samples."""
        xs = np.asarray(list(xs) if not isinstance(xs, np.ndarray) else xs,
                        np.float64)
        self._grow(len(xs))
        self._buf[self._n:self._n + len(xs)] = xs
        self._n += len(xs)
        self._sorted = None

    def merge(self, other: "StreamingQuantiles") -> "StreamingQuantiles":
        """Fold another accumulator's samples into this one."""
        self.extend(other.values())
        return self

    def values(self) -> np.ndarray:
        """The raw samples seen so far (insertion order)."""
        return self._buf[:self._n].copy()

    def _view(self) -> np.ndarray:
        if self._sorted is None:
            self._sorted = np.sort(self._buf[:self._n])
        return self._sorted

    def percentile(self, q: float) -> float:
        """Exact q-th percentile (numpy ``method="linear"``); 0 if empty."""
        if self._n == 0:
            return 0.0
        return float(np.percentile(self._view(), q))

    def mean(self) -> float:
        """Sample mean (0 if empty). Computed over the *sorted* view so
        the result is insertion/merge-order invariant bit-for-bit (numpy
        pairwise summation is order-sensitive in the last ulp)."""
        return float(np.mean(self._view())) if self._n else 0.0

    def attainment(self, threshold: float) -> float:
        """Fraction of samples <= threshold (SLO attainment); 0 if empty."""
        if self._n == 0:
            return 0.0
        return float(np.searchsorted(self._view(), threshold, side="right")
                     / self._n)

    def summary(self, percentiles=DEFAULT_PERCENTILES) -> dict:
        """Mean / min / max plus the requested percentiles as one dict."""
        if self._n == 0:
            out = {"n": 0, "mean": 0.0, "min": 0.0, "max": 0.0}
            out.update({f"p{_plabel(q)}": 0.0 for q in percentiles})
            return out
        v = self._view()
        out = {"n": self._n, "mean": self.mean(),
               "min": float(v[0]), "max": float(v[-1])}
        for q in percentiles:
            out[f"p{_plabel(q)}"] = float(np.percentile(v, q))
        return out


def _plabel(q: float) -> str:
    """Percentile label: 50.0 -> "50", 99.9 -> "99.9"."""
    return f"{q:g}"
