"""Shared JSON / markdown emitters for metrics reports.

Every benchmark writes its distribution metrics through these two
functions so artifact formatting cannot drift between benchmarks, and
so the replay-determinism guarantee ("same trace + seed → byte-identical
metrics JSON") has a single canonical byte representation to pin.
"""

from __future__ import annotations

import json

#: Metric sections a rollup report may carry, in canonical row order —
#: the single source of truth shared with benchmarks/make_tables.py.
METRIC_ROWS = ("ttft", "tbt", "completion", "slowdown",
               "latency_per_token")

#: Summary columns every metric section carries (mean + the
#: streaming layer's DEFAULT_PERCENTILES), in canonical column order.
SUMMARY_COLS = ("mean", "p50", "p90", "p99")


def report_json(report: dict) -> str:
    """Canonical JSON bytes for a rollup report (sorted keys, 1-indent).

    This is the representation the determinism tests compare — always
    serialize reports through here, never ad-hoc ``json.dumps`` calls.
    """
    return json.dumps(report, indent=1, sort_keys=True)


def _fmt(v: float) -> str:
    return f"{v:.4g}"


def report_markdown(report: dict, title: str = "") -> str:
    """Render a rollup report as a GitHub-markdown table.

    One row per metric (TTFT, TBT, completion, slowdown when present,
    per-token latency) with mean / p50 / p90 / p99 columns, followed by
    a compact SLO-attainment line per metric and the counters.
    """
    lines = []
    if title:
        lines.append(f"### {title}")
        lines.append("")
    cols = SUMMARY_COLS
    lines.append("| metric (s) | " + " | ".join(cols) + " | n |")
    lines.append("|---|" + "---|" * (len(cols) + 1))
    for key in METRIC_ROWS:
        s = report.get(key)
        if not s or not s.get("n"):
            continue
        row = " | ".join(_fmt(s.get(c, 0.0)) for c in cols)
        lines.append(f"| {key} | {row} | {s['n']} |")
    slo = report.get("slo_attainment", {})
    for key, curve in slo.items():
        if not curve:
            continue
        pts = ", ".join(f"{c['attainment']:.0%}@{c['slo_s']:g}s"
                        for c in curve)
        lines.append("")
        lines.append(f"SLO attainment ({key}): {pts}")
    counters = report.get("counters")
    if counters:
        lines.append("")
        lines.append("Counters: " + ", ".join(
            f"{k}={_fmt(v)}" for k, v in sorted(counters.items())))
    req = report.get("requests")
    if req:
        lines.append("")
        lines.append(f"Requests: {req['finished']}/{req['arrived']} "
                     f"finished, {req['output_tokens']:g} output tokens.")
    return "\n".join(lines)
