"""First-class metrics layer: per-request event logs and rollups.

Submodules:

* ``events``    — `EventLog`: the per-request event stream (arrival /
  admit / first-token / tokens / finish / preempt / swap timestamps)
  captured by `Engine.step()` and merged across cluster replicas.
* ``streaming`` — `StreamingQuantiles`: an exact, mergeable percentile
  accumulator (validated against ``numpy.percentile``).
* ``rollup``    — `rollup()`: turn an event log into TTFT / TBT /
  completion-time / slowdown distributions (mean + p50/p90/p99),
  SLO-attainment curves, and preemption/swap counters.
* ``emitters``  — shared JSON and markdown-table emitters used by every
  benchmark artifact.
"""

from repro.metrics.emitters import report_json, report_markdown
from repro.metrics.events import Event, EventLog, check_invariants
from repro.metrics.rollup import (DEFAULT_SLOS, ideal_service_times,
                                  rollup)
from repro.metrics.streaming import StreamingQuantiles

__all__ = [
    "Event",
    "EventLog",
    "StreamingQuantiles",
    "check_invariants",
    "ideal_service_times",
    "rollup",
    "report_json",
    "report_markdown",
    "DEFAULT_SLOS",
]
