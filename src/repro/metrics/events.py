"""Per-request event log captured from the engine's step stream.

One `Event` is a timestamped lifecycle transition of one request; an
`EventLog` is the append-only stream one engine (or one merged cluster)
produced. The engine emits events inside ``Engine.step()`` — observation
only, never control flow — so enabling the log cannot change scheduling
results (``tests/test_metrics.py`` pins this byte-for-byte).

Event kinds (``Event.kind``):

* ``arrival``     — the request entered the engine's pool (t = its
  arrival timestamp, which may precede the emitting step's clock).
* ``admit``       — the scheduler moved it WAITING/PREEMPTED → RUNNING.
* ``first_token`` — the first output token materialized.
* ``tokens``      — ``value`` output tokens materialized at time t (one
  event per decode megastep; sim mode emits value=1 per step).
* ``finish``      — the request completed.
* ``preempt``     — the scheduler preempted it (``value`` = preemption
  count so far).
* ``swap``        — KV bytes crossed the device↔host DMA link
  (``value`` = bytes; covers swap-out and swap-in).
* ``prefix_hit``  — prompt tokens served from the KV prefix cache at
  admission (``value`` = tokens).

Resilience kinds (PR 7 — the failure/overload layer):

* ``cancel``       — the request was cancelled explicitly
  (`Engine.cancel`); its KV footprint is fully released.
* ``timeout``      — cancelled because its completion or TTFT deadline
  expired (checked at megastep boundaries on the engine clock).
* ``shed``         — cancelled by predicted-work load shedding: the
  engine's predicted backlog exceeded the shed watermark and this was
  among the worst-ranked waiting requests (or it was refused at
  admission under admission control).
* ``retry``        — the router re-dispatched the request to a surviving
  replica after a fault (``value`` = retry count so far); a fresh
  ``arrival`` event follows on the new replica.
* ``replica_down`` / ``replica_up`` — a replica crashed / recovered
  (``rid`` = -1, ``value`` = replica index; emitted by the router).

Disaggregation kinds (PR 9 — prefill/decode split):

* ``handoff``     — the request's paged KV was exported from this
  replica for migration to another (``value`` = pages shipped; 0 means
  the destination re-prefills). Emitted on the source; the request's
  later events continue on the destination replica — the merged-log
  per-request ordering still holds because import time is never earlier
  than export time.
"""

from __future__ import annotations

from dataclasses import dataclass

#: Every kind an `Event` may carry, in lifecycle order.
EVENT_KINDS = ("arrival", "admit", "first_token", "tokens", "finish",
               "preempt", "swap", "prefix_hit",
               "cancel", "timeout", "shed", "retry",
               "replica_down", "replica_up", "handoff")

#: The cancellation-reason kinds a terminal cancel event may carry.
CANCEL_KINDS = ("cancel", "timeout", "shed")

#: Kinds that occur at most once per request, in their required order.
_ORDERED_ONCE = ("arrival", "first_token", "finish")


@dataclass(frozen=True)
class Event:
    """One timestamped request-lifecycle transition.

    Attributes:
        t: engine-clock timestamp in seconds (sim clock in sim mode).
        rid: the request id.
        kind: one of `EVENT_KINDS`.
        value: kind-specific payload (tokens emitted, bytes swapped,
            preemption count); 0.0 where meaningless.
    """

    t: float
    rid: int
    kind: str
    value: float = 0.0

    def as_dict(self) -> dict:
        """JSON-friendly form (stable key order for deterministic dumps)."""
        return {"t": self.t, "rid": self.rid, "kind": self.kind,
                "value": self.value}


class EventLog:
    """Append-only stream of request events from one engine (or merged).

    The engine holds a reference and calls `emit()` from inside
    ``step()``; the cluster router merges its replicas' logs with
    `merge()` (re-sorted by timestamp — per-request ordering survives
    because a request lives on exactly one replica).
    """

    def __init__(self):
        self.events: list[Event] = []

    def __len__(self) -> int:
        return len(self.events)

    def emit(self, t: float, rid: int, kind: str, value: float = 0.0):
        """Append one event (no validation on the hot path)."""
        self.events.append(Event(float(t), rid, kind, float(value)))

    def clear(self):
        """Drop all events (the engine's ``run()`` reset)."""
        self.events.clear()

    def merge(self, other: "EventLog") -> "EventLog":
        """Fold another log into this one, keeping global time order."""
        self.events = EventLog.merge_all([self, other]).events
        return self

    @classmethod
    def merge_all(cls, logs) -> "EventLog":
        """Merge any number of logs with one concatenate-and-sort.

        The single home of the deterministic merge key —
        ``(t, rid, emission index)`` — so pairwise `merge` and the
        cluster router's N-replica merge can never diverge. Ties across
        logs resolve by log order, within a log by emission order.
        """
        combined = [(e.t, e.rid, i, e) for i, e in enumerate(
            e for log in logs for e in log.events)]
        combined.sort(key=lambda x: (x[0], x[1], x[2]))
        merged = cls()
        merged.events = [e for _, _, _, e in combined]
        return merged

    def per_request(self) -> dict[int, list[Event]]:
        """Group events by rid, preserving emission order within each."""
        out: dict[int, list[Event]] = {}
        for e in self.events:
            out.setdefault(e.rid, []).append(e)
        return out

    def as_dicts(self) -> list[dict]:
        """The whole stream as JSON-friendly dicts."""
        return [e.as_dict() for e in self.events]


def check_invariants(log: EventLog) -> None:
    """Raise ``AssertionError`` on any broken per-request invariant.

    Enforced per request: timestamps are non-decreasing in emission
    order; ``arrival <= admit <= first_token <= finish``; TTFT never
    exceeds completion time; a finished request has a first token and
    at least one ``tokens`` event; token events never precede admission.

    Violations are raised explicitly (never via the ``assert``
    statement), so the benchmarks' pre-artifact gates stay armed under
    ``python -O``.
    """
    def _require(cond: bool, msg: str):
        """Explicit raise — immune to python -O assert stripping."""
        if not cond:
            raise AssertionError(msg)

    for rid, evs in log.per_request().items():
        times = [e.t for e in evs]
        _require(all(a <= b for a, b in zip(times, times[1:])),
                 f"rid {rid}: non-monotone event timestamps {times}")
        first: dict[str, float] = {}
        for e in evs:
            first.setdefault(e.kind, e.t)
        order = [first[k] for k in _ORDERED_ONCE if k in first]
        _require(all(a <= b for a, b in zip(order, order[1:])),
                 f"rid {rid}: lifecycle out of order {first}")
        if "admit" in first:
            _require(first.get("arrival", first["admit"]) <= first["admit"],
                     f"rid {rid}: admitted before arrival")
        if "finish" in first:
            _require("first_token" in first,
                     f"rid {rid}: finished w/o token")
            _require("tokens" in first,
                     f"rid {rid}: finished w/o tokens event")
            arr = first.get("arrival", 0.0)
            ttft = first["first_token"] - arr
            completion = first["finish"] - arr
            _require(ttft <= completion + 1e-12,
                     f"rid {rid}: TTFT {ttft} > completion {completion}")
        if "tokens" in first and "admit" in first:
            _require(first["admit"] <= first["tokens"],
                     f"rid {rid}: tokens before admission")
        cancelled = [k for k in CANCEL_KINDS if k in first]
        if cancelled:
            _require("finish" not in first,
                     f"rid {rid}: both cancelled ({cancelled}) and finished")
            if "arrival" in first:
                _require(first["arrival"] <= min(first[k]
                                                 for k in cancelled),
                         f"rid {rid}: cancelled before arrival")
