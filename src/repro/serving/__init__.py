"""TRAIL serving runtime: iteration-level continuous batching.

Embedding-based length prediction feeding SPRPT-limited-preemption
scheduling.
"""
