"""TRAIL serving runtime: iteration-level continuous batching with
embedding-based length prediction and SPRPT-limited-preemption scheduling."""
