"""Request lifecycle objects shared by the engine and the benchmarks."""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.scheduler import ReqState, SchedEntry


@dataclass
class Request:
    """One request's full lifecycle state (identity, progress, metrics)."""

    rid: int
    arrival: float
    prompt: list[int]
    max_new_tokens: int = 512
    # oracle ground truth (sim mode / synthetic EOS): output length in tokens
    true_out_len: int = 0
    tenant: str = ""                              # multi-tenant workload tag
    # resilience knobs (0.0 = none; engine-config defaults apply instead)
    deadline_s: float = 0.0                       # completion budget after
                                                  # arrival (engine clock)
    ttft_deadline_s: float = 0.0                  # first-token budget
    retries: int = 0                              # failover re-dispatches
    cancel_reason: str = ""                       # set when CANCELLED
                                                  # ("cancel"|"timeout"|"shed")

    generated: list[int] = field(default_factory=list)
    entry: SchedEntry = None                      # scheduling metadata
    posterior: object = None                      # Bayesian filter state (k,)
    tap_sum: object = None                        # prompt-phase tap accumulator
    tap_cnt: int = 0
    slot: int = -1                                # cache slot (-1 = none)

    # metrics (in engine-clock seconds)
    first_token_time: float = -1.0
    finish_time: float = -1.0

    def __post_init__(self):
        if self.entry is None:
            self.entry = SchedEntry(
                rid=self.rid, arrival=self.arrival,
                prompt_len=len(self.prompt))

    @property
    def context_len(self) -> int:
        """Prompt + generated tokens (the KV footprint driver)."""
        return len(self.prompt) + len(self.generated)

    @property
    def done(self) -> bool:
        """True once the request reached a terminal state.

        Terminal means FINISHED or CANCELLED — cancelled requests never
        re-enter scheduling.
        """
        return self.entry.state in (ReqState.FINISHED, ReqState.CANCELLED)

    def latency(self) -> float:
        """Completion time: finish minus arrival (engine-clock seconds)."""
        return self.finish_time - self.arrival

    def ttft(self) -> float:
        """Time to first token (engine-clock seconds)."""
        return self.first_token_time - self.arrival
