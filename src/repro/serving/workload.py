"""Workload generation: Alpaca-like request streams and a scenario library.

The Alpaca dataset (the paper's workload) is not available offline, so we
generate a synthetic stream whose *shape* matches its published statistics:
right-skewed prompt lengths (median ≈ 40 tokens; the paper's profiled prompt
tensor is [1, 44, 4096]) and right-skewed output lengths clipped to the
paper's 512-token prediction range (lognormal; most responses < 100 tokens,
a long tail up to 512 — the regime where SRPT-style policies shine).

Arrival processes:

* ``poisson`` — homogeneous Poisson at ``request_rate`` (the paper's
  Figures 5-6 setting).
* ``burst``   — everything at t=0 (the paper's Figure 7).
* ``mmpp``    — 2-state Markov-modulated Poisson (bursty on/off traffic):
  a high-rate ON state and a low-rate OFF state with exponential dwell
  times, normalized so the long-run mean rate equals ``request_rate``.
* ``diurnal`` — non-homogeneous Poisson with a sinusoidal rate curve
  (thinning), mean rate ``request_rate``.

Multi-tenant mixes can go beyond one shared process: when every
`TenantSpec` carries a positive ``rate``, each tenant drives its *own*
arrival process (its own seeded stream, optionally its own process kind
via ``TenantSpec.arrival``) and the per-tenant streams superpose into
the request stream — bursty code traffic over steady chat, say. The
``tenant-arrivals`` scenario is the packaged example.

Named presets combining arrivals with length mixes live in ``SCENARIOS``
and are built with `scenario_config` — reachable from ``launch/serve.py
--scenario`` and ``benchmarks/cluster_curves.py``. Recorded traces are a
scenario source too: ``scenario_config("trace:<path>", ...)`` (or
``trace:sample`` for the bundled fixture) replays arrivals and
prompt/output lengths from an Azure-LLM-inference-style trace through
``repro.traces`` instead of synthesizing them.

RNG streams: historically one ``random.Random(seed)`` drove arrivals,
lengths, *and* prompt-token content, so any arrival-process change
(toggling ``burst``, or an arrival distribution that consumes a
data-dependent number of draws, like MMPP) reshuffled every length and
content draw. With ``split_streams=True`` (the default for every scenario
preset) arrivals, lengths, tenant assignment, and token content each draw
from an independent stream seeded from ``seed`` — the job-size sequence
is invariant under arrival-process and rate changes. The legacy coupled
stream remains the ``WorkloadConfig`` default so experiment JSONs
produced by earlier revisions stay reproducible.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, replace

from repro.serving.request import Request


@dataclass(frozen=True)
class TenantSpec:
    """One tenant in a multi-tenant mix.

    Attributes:
        name: tenant tag stamped onto each generated `Request`.
        weight: sampling weight (normalized over the mix).
        prompt_mean: lognormal location for prompt lengths (tokens).
        prompt_sigma: lognormal sigma for prompt lengths.
        out_median: lognormal median for output lengths (tokens).
        out_sigma: lognormal sigma for output lengths.
        prefix_len: length (tokens) of the tenant's fixed system prompt,
            prepended to every request of the tenant. All requests of one
            tenant share the same prefix token content (drawn once from a
            dedicated RNG stream), so cross-request KV prefix caching can
            serve it after the first prefill. 0 = no shared prefix.
        rate: per-tenant mean arrival rate (req/s). When any tenant in
            the mix sets a positive rate, *every* tenant must: each then
            drives its own independent arrival process (seeded from
            ``{seed}:arrivals:{name}``) and the streams superpose —
            ``weight`` is ignored, the rates set the mix. 0 (the
            default) keeps the legacy single-stream draw where one
            shared arrival process tags requests by ``weight``.
        arrival: per-tenant arrival process (``poisson`` | ``burst`` |
            ``mmpp`` | ``diurnal``); only read in rate-driven mode.
            Empty = inherit the workload-level ``arrival``.
    """

    name: str
    weight: float
    prompt_mean: float = 44.0
    prompt_sigma: float = 0.6
    out_median: float = 48.0
    out_sigma: float = 1.0
    prefix_len: int = 0
    rate: float = 0.0
    arrival: str = ""


@dataclass(frozen=True)
class WorkloadConfig:
    """Parameters for one synthetic request stream.

    Attributes:
        n_requests: number of requests to generate.
        request_rate: long-run mean arrival rate (req/s) for every
            arrival process except ``burst``.
        burst: legacy flag — everything arrives at t=0 (same as
            ``arrival="burst"``; kept for config compatibility).
        arrival: arrival process — ``poisson`` | ``burst`` | ``mmpp`` |
            ``diurnal`` (see module docstring).
        prompt_mean: lognormal location for prompt lengths (tokens).
        prompt_sigma: lognormal sigma for prompt lengths.
        out_median: lognormal median of output lengths (tokens).
        out_sigma: lognormal sigma of output lengths.
        max_out: output-length clip — the paper's 512-token range.
        min_out: lower output-length clip.
        vocab: vocabulary size for random prompt-token content.
        seed: master seed (all streams derive from it).
        split_streams: draw arrivals / lengths / tenants / content from
            independent per-purpose streams (see module docstring). Off
            by default for byte-compatibility with old experiments.
        mmpp_burst_factor: ON-state rate multiplier (mmpp). The OFF rate
            is derived so the long-run mean equals ``request_rate``;
            requires ``mmpp_duty * mmpp_burst_factor <= 1``.
        mmpp_duty: long-run fraction of time spent in the ON state.
        mmpp_cycle: mean ON+OFF cycle length in seconds.
        diurnal_amp: relative amplitude of the sinusoidal rate curve
            (0 = flat Poisson, 1 = rate touches zero at the trough).
        diurnal_period: period of the rate curve in seconds.
        tenants: optional `TenantSpec` mix; empty = single-tenant using
            the top-level length parameters.
        prefix_len: single-tenant shared system-prompt length in tokens
            (per-tenant prefixes come from ``TenantSpec.prefix_len``).
            Requires ``split_streams=True``.
        prefix_hit: probability that a request with a shared prefix
            actually carries the *tenant's* prefix; misses get a fresh
            random prefix of the same length (so footprints match but the
            KV cache cannot serve it) — the hit-rate dial for prefix-cache
            benchmarks.
        trace: replay a recorded trace instead of synthesizing arrivals
            and lengths: a ``.jsonl``/``.csv`` path, or ``sample`` for
            the bundled Azure-style fixture (see ``repro.traces``).
            When set, ``n_requests`` caps the replayed records, ``seed``
            and ``vocab`` drive prompt token content, and the arrival /
            length knobs above are ignored (they come from the trace).
        trace_rate_scale: arrival-rate multiplier for trace replay
            (inter-arrival gaps divide by it; burst structure is kept).
        trace_target_rate: when positive, replay at this mean arrival
            rate (req/s): the rate-scale is derived from the loaded
            trace's native rate at generation time. Ignored when a
            non-default ``trace_rate_scale`` is set — an explicit scale
            wins.
        trace_time_warp: uniform playback-speed multiplier for trace
            replay (see `repro.traces.ReplayConfig`).
        predictor: length-prediction strategy spec the scenario
            recommends (``name[:key=value,...]``, see
            `repro.serving.predictors.STRATEGIES`). Workload generation
            itself never reads it — it rides the config so scenario
            presets and ``scenario_config(..., predictor=...)``
            overrides reach the engine/cluster launchers
            (``launch/serve.py`` uses it when ``--predictor`` is not
            given). Empty = the engine's legacy default.
    """

    n_requests: int = 256
    request_rate: float = 14.0       # the paper's Figure 5 operating point
    burst: bool = False
    arrival: str = "poisson"         # poisson | burst | mmpp | diurnal
    prompt_mean: float = 44.0        # tokens (paper's profiling shape)
    prompt_sigma: float = 0.6        # lognormal sigma
    out_median: float = 48.0
    out_sigma: float = 1.0
    max_out: int = 512
    min_out: int = 1
    vocab: int = 32000
    seed: int = 0
    split_streams: bool = False
    mmpp_burst_factor: float = 3.0
    mmpp_duty: float = 0.25
    mmpp_cycle: float = 8.0
    diurnal_amp: float = 0.8
    diurnal_period: float = 60.0
    tenants: tuple = ()
    prefix_len: int = 0
    prefix_hit: float = 1.0
    trace: str = ""
    trace_rate_scale: float = 1.0
    trace_target_rate: float = 0.0
    trace_time_warp: float = 1.0
    predictor: str = ""


def sample_output_length(rng: random.Random, wc,
                         spec: TenantSpec | None = None) -> int:
    """Draw one lognormal output length, clipped to [min_out, max_out]."""
    med = spec.out_median if spec is not None else wc.out_median
    sig = spec.out_sigma if spec is not None else wc.out_sigma
    v = rng.lognormvariate(math.log(med), sig)
    return max(wc.min_out, min(int(v), wc.max_out))


def sample_prompt_length(rng: random.Random, wc,
                         spec: TenantSpec | None = None) -> int:
    """Draw one lognormal prompt length, clipped to [4, 2048]."""
    mean = spec.prompt_mean if spec is not None else wc.prompt_mean
    sig = spec.prompt_sigma if spec is not None else wc.prompt_sigma
    v = rng.lognormvariate(math.log(mean), sig)
    return max(4, min(int(v), 2048))


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def _poisson_arrivals(rng: random.Random, wc: WorkloadConfig) -> list[float]:
    t, out = 0.0, []
    for _ in range(wc.n_requests):
        t += rng.expovariate(wc.request_rate)
        out.append(t)
    return out


def _mmpp_arrivals(rng: random.Random, wc: WorkloadConfig) -> list[float]:
    """2-state MMPP: exponential ON/OFF dwells, mean rate = request_rate.

    Memorylessness makes discard-and-redraw at state switches exact: an
    exponential inter-arrival that crosses the switch time is simply
    abandoned and redrawn at the new state's rate.
    """
    duty, fb = wc.mmpp_duty, wc.mmpp_burst_factor
    if duty * fb > 1.0:
        raise ValueError("mmpp_duty * mmpp_burst_factor must be <= 1 "
                         "(OFF-state rate would be negative)")
    rate_on = wc.request_rate * fb
    rate_off = wc.request_rate * (1.0 - duty * fb) / (1.0 - duty)
    mean_on = duty * wc.mmpp_cycle
    mean_off = (1.0 - duty) * wc.mmpp_cycle
    t, on, out = 0.0, True, []
    t_switch = rng.expovariate(1.0 / mean_on)
    while len(out) < wc.n_requests:
        rate = rate_on if on else rate_off
        dt = rng.expovariate(rate) if rate > 0 else float("inf")
        if t + dt < t_switch:
            t += dt
            out.append(t)
        else:
            t = t_switch
            on = not on
            t_switch = t + rng.expovariate(
                1.0 / (mean_on if on else mean_off))
    return out


def _diurnal_arrivals(rng: random.Random, wc: WorkloadConfig) -> list[float]:
    """Non-homogeneous Poisson via thinning against the peak rate."""
    base, amp, period = wc.request_rate, wc.diurnal_amp, wc.diurnal_period
    rate_max = base * (1.0 + amp)
    t, out = 0.0, []
    while len(out) < wc.n_requests:
        t += rng.expovariate(rate_max)
        rate_t = base * (1.0 + amp * math.sin(2.0 * math.pi * t / period))
        if rng.random() * rate_max < rate_t:
            out.append(t)
    return out


_ARRIVALS = {"poisson": _poisson_arrivals, "mmpp": _mmpp_arrivals,
             "diurnal": _diurnal_arrivals}


def _pick_tenant(rng: random.Random, wc: WorkloadConfig) -> TenantSpec | None:
    if not wc.tenants:
        return None
    total = sum(s.weight for s in wc.tenants)
    u = rng.random() * total
    acc = 0.0
    for spec in wc.tenants:
        acc += spec.weight
        if u < acc:
            return spec
    return wc.tenants[-1]


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

def _generate_legacy(wc: WorkloadConfig, burst: bool) -> list[Request]:
    """The original coupled-RNG generation path.

    Arrivals, lengths and content share one stream; kept byte-identical
    so old experiment JSONs reproduce.
    """
    rng = random.Random(wc.seed)
    t = 0.0
    reqs = []
    for rid in range(wc.n_requests):
        if not burst:
            t += rng.expovariate(wc.request_rate)
        plen = sample_prompt_length(rng, wc)
        olen = sample_output_length(rng, wc)
        prompt = [rng.randrange(1, wc.vocab) for _ in range(plen)]
        reqs.append(Request(rid=rid, arrival=t if not burst else 0.0,
                            prompt=prompt, true_out_len=olen,
                            max_new_tokens=wc.max_out))
    return reqs


def generate(wc: WorkloadConfig) -> list[Request]:
    """Generate the request stream described by ``wc``.

    With ``split_streams=False`` and a plain poisson/burst arrival this is
    the legacy coupled-RNG generator (byte-identical to earlier
    revisions). Every other combination uses four independent streams
    derived from ``wc.seed`` — ``arrivals``, ``lengths``, ``tenants`` and
    ``content`` — so the job-size sequence is invariant under
    ``request_rate`` (and arrival-process) changes. Tenant mixes whose
    specs carry positive ``rate`` values switch to rate-driven
    superposition (`_generate_per_tenant`): per-tenant arrival processes
    on per-tenant streams.
    """
    if wc.trace:
        return _generate_from_trace(wc)
    arrival = "burst" if wc.burst else wc.arrival
    if arrival not in ("poisson", "burst", "mmpp", "diurnal"):
        raise ValueError(f"unknown arrival process {wc.arrival!r}")
    has_prefix = wc.prefix_len > 0 or any(
        s.prefix_len > 0 for s in wc.tenants)
    if not wc.split_streams and arrival in ("poisson", "burst"):
        if wc.tenants:
            raise ValueError("tenant mixes require split_streams=True")
        if has_prefix:
            raise ValueError("shared prefixes require split_streams=True")
        return _generate_legacy(wc, burst=arrival == "burst")
    if any(s.rate > 0 for s in wc.tenants):
        return _generate_per_tenant(wc, arrival)

    # string seeding is deterministic across processes (hashed via sha512
    # by random.seed, not PYTHONHASHSEED)
    arr_rng = random.Random(f"{wc.seed}:arrivals")
    len_rng = random.Random(f"{wc.seed}:lengths")
    ten_rng = random.Random(f"{wc.seed}:tenants")
    tok_rng = random.Random(f"{wc.seed}:content")

    if arrival == "burst":
        arrivals = [0.0] * wc.n_requests
    else:
        arrivals = _ARRIVALS[arrival](arr_rng, wc)

    # shared system prompts: one fixed token sequence per tenant, drawn
    # from a stream keyed on the tenant name so the content is stable
    # under any change to the mix, rates, or arrival process. The hit
    # dial draws from its own stream for the same invariance.
    hit_rng = random.Random(f"{wc.seed}:prefixhit") if has_prefix else None
    prefixes: dict[str, list[int]] = {}

    def _shared_prefix(name: str, plen: int) -> list[int]:
        if name not in prefixes:
            rng = random.Random(f"{wc.seed}:prefix:{name}")
            prefixes[name] = [rng.randrange(1, wc.vocab)
                              for _ in range(plen)]
        return prefixes[name]

    reqs = []
    for rid, t in enumerate(arrivals):
        spec = _pick_tenant(ten_rng, wc)
        plen = sample_prompt_length(len_rng, wc, spec)
        olen = sample_output_length(len_rng, wc, spec)
        prompt = [tok_rng.randrange(1, wc.vocab) for _ in range(plen)]
        pre_len = spec.prefix_len if spec is not None else wc.prefix_len
        if pre_len > 0:
            if hit_rng.random() < wc.prefix_hit:
                prompt = _shared_prefix(spec.name if spec else "",
                                        pre_len) + prompt
            else:       # miss: same footprint, unshareable content
                prompt = [tok_rng.randrange(1, wc.vocab)
                          for _ in range(pre_len)] + prompt
        reqs.append(Request(rid=rid, arrival=t, prompt=prompt,
                            true_out_len=olen, max_new_tokens=wc.max_out,
                            tenant=spec.name if spec else ""))
    return reqs


def _generate_per_tenant(wc: WorkloadConfig,
                         default_arrival: str) -> list[Request]:
    """Rate-driven multi-tenant generation: superposed arrival processes.

    Every tenant drives its own arrival process on its own RNG stream
    (``{seed}:arrivals:{name}``) at its own ``rate``; the per-tenant
    streams merge in time order (name-tiebroken) and truncate to
    ``n_requests``. Lengths, token content, and prefix-hit draws also
    come from per-tenant streams, so changing one tenant's rate or
    arrival process cannot reshuffle any other tenant's requests — the
    per-tenant extension of the ``split_streams`` invariance.
    """
    if not wc.split_streams:
        raise ValueError("tenant mixes require split_streams=True")
    bad = [s.name for s in wc.tenants if s.rate <= 0]
    if bad:
        raise ValueError("per-tenant arrival mode needs a positive rate "
                         f"for every tenant; missing: {bad} (either give "
                         "all tenants rates or none)")
    merged: list[tuple[float, str, TenantSpec]] = []
    for spec in wc.tenants:
        proc = spec.arrival or default_arrival
        if proc == "burst":
            arrivals = [0.0] * wc.n_requests
        elif proc in _ARRIVALS:
            rng = random.Random(f"{wc.seed}:arrivals:{spec.name}")
            arrivals = _ARRIVALS[proc](rng, replace(wc,
                                                    request_rate=spec.rate))
        else:
            raise ValueError(f"unknown arrival process {proc!r} "
                             f"for tenant {spec.name!r}")
        merged.extend((t, spec.name, spec) for t in arrivals)
    # superposition: each tenant over-generates n_requests arrivals; the
    # merge keeps the earliest n_requests overall. Within one tenant the
    # merged order equals its arrival order, so the i-th surviving
    # request of a tenant always consumes that tenant's i-th
    # length/content draw no matter how the streams interleave.
    merged.sort(key=lambda x: (x[0], x[1]))
    merged = merged[:wc.n_requests]

    len_rngs = {s.name: random.Random(f"{wc.seed}:lengths:{s.name}")
                for s in wc.tenants}
    tok_rngs = {s.name: random.Random(f"{wc.seed}:content:{s.name}")
                for s in wc.tenants}
    hit_rngs = {s.name: random.Random(f"{wc.seed}:prefixhit:{s.name}")
                for s in wc.tenants}
    prefixes: dict[str, list[int]] = {}

    def _shared_prefix(name: str, plen: int) -> list[int]:
        if name not in prefixes:
            rng = random.Random(f"{wc.seed}:prefix:{name}")
            prefixes[name] = [rng.randrange(1, wc.vocab)
                              for _ in range(plen)]
        return prefixes[name]

    reqs = []
    for rid, (t, name, spec) in enumerate(merged):
        plen = sample_prompt_length(len_rngs[name], wc, spec)
        olen = sample_output_length(len_rngs[name], wc, spec)
        prompt = [tok_rngs[name].randrange(1, wc.vocab)
                  for _ in range(plen)]
        if spec.prefix_len > 0:
            if hit_rngs[name].random() < wc.prefix_hit:
                prompt = _shared_prefix(name, spec.prefix_len) + prompt
            else:       # miss: same footprint, unshareable content
                prompt = [tok_rngs[name].randrange(1, wc.vocab)
                          for _ in range(spec.prefix_len)] + prompt
        reqs.append(Request(rid=rid, arrival=t, prompt=prompt,
                            true_out_len=olen, max_new_tokens=wc.max_out,
                            tenant=name))
    return reqs


def _generate_from_trace(wc: WorkloadConfig) -> list[Request]:
    """Trace-backed generation: load + replay-materialize.

    The traces package is imported lazily so the workload module stays
    importable without it. The trace is parsed exactly once; a ``trace_target_rate`` converts
    into a rate-scale against the loaded trace's native mean rate here,
    unless an explicit non-default ``trace_rate_scale`` was given.
    """
    from repro.traces import ReplayConfig, load_trace, requests_from_trace
    trace = load_trace(wc.trace, limit=wc.n_requests or None)
    scale = wc.trace_rate_scale
    if wc.trace_target_rate > 0 and scale == 1.0 and trace.mean_rate > 0:
        scale = wc.trace_target_rate / trace.mean_rate
    rcfg = ReplayConfig(rate_scale=scale,
                        time_warp=wc.trace_time_warp,
                        limit=wc.n_requests or None,
                        max_output=wc.max_out, seed=wc.seed,
                        vocab=wc.vocab)
    return requests_from_trace(trace, rcfg)


# ---------------------------------------------------------------------------
# scenario library
# ---------------------------------------------------------------------------

#: Named presets: scenario name -> WorkloadConfig field overrides. All
#: presets use split RNG streams so job sizes are rate-invariant.
SCENARIOS: dict[str, dict] = {
    # the paper's settings
    "poisson": dict(arrival="poisson"),
    "burst": dict(arrival="burst"),
    # bursty on/off traffic: 3x rate spikes a quarter of the time
    "bursty": dict(arrival="mmpp", mmpp_burst_factor=3.0, mmpp_duty=0.25,
                   mmpp_cycle=8.0),
    # slow sinusoidal load curve (compressed diurnal cycle)
    "diurnal": dict(arrival="diurnal", diurnal_amp=0.8, diurnal_period=60.0),
    # chat-heavy multi-tenant mix: interactive chat, code completion with
    # longer prompts/outputs, and a small batch-summarization tenant with
    # big prompts and short outputs
    "multi-tenant": dict(arrival="poisson", tenants=(
        TenantSpec("chat", 0.6, prompt_mean=44.0, out_median=48.0),
        TenantSpec("code", 0.3, prompt_mean=120.0, prompt_sigma=0.5,
                   out_median=128.0, out_sigma=0.8),
        TenantSpec("summarize", 0.1, prompt_mean=400.0, prompt_sigma=0.4,
                   out_median=24.0, out_sigma=0.5),
    )),
    # long-context-heavy: big prompts, moderate outputs — stresses KV
    # memory and chunked prefill rather than decode
    "long-context": dict(arrival="poisson", prompt_mean=400.0,
                         prompt_sigma=0.8, out_median=96.0),
    # rate-driven multi-tenant mix: each tenant owns an independent
    # arrival process (steady chat, bursty code spikes, diurnal batch
    # summarization) and the streams superpose. Rates below are
    # *relative* shares — scenario_config rescales them so their sum
    # equals the requested aggregate request_rate.
    "tenant-arrivals": dict(arrival="poisson", tenants=(
        TenantSpec("chat", 0.6, prompt_mean=44.0, out_median=48.0,
                   rate=6.0, arrival="poisson"),
        TenantSpec("code", 0.3, prompt_mean=120.0, prompt_sigma=0.5,
                   out_median=128.0, out_sigma=0.8,
                   rate=3.0, arrival="mmpp"),
        TenantSpec("summarize", 0.1, prompt_mean=400.0, prompt_sigma=0.4,
                   out_median=24.0, out_sigma=0.5,
                   rate=1.0, arrival="diurnal"),
    )),
    # multi-tenant mix where every tenant carries a fixed system prompt
    # (RAG preamble / tool schema / style guide): the cross-request
    # prefix-cache scenario. Prefix lengths are page-aligned (multiples
    # of 16) so a full prefix hit links cleanly; dial the hit rate with
    # scenario_config("shared-prefix", ..., prefix_hit=0.5).
    "shared-prefix": dict(arrival="poisson", tenants=(
        TenantSpec("chat", 0.6, prompt_mean=44.0, out_median=48.0,
                   prefix_len=192),
        TenantSpec("code", 0.3, prompt_mean=120.0, prompt_sigma=0.5,
                   out_median=128.0, out_sigma=0.8, prefix_len=384),
        TenantSpec("summarize", 0.1, prompt_mean=400.0, prompt_sigma=0.4,
                   out_median=24.0, out_sigma=0.5, prefix_len=96),
    )),
}


def scenario_config(name: str, *, n_requests: int, request_rate: float,
                    seed: int = 0, vocab: int = 32000,
                    **overrides) -> WorkloadConfig:
    """Build the `WorkloadConfig` for a named scenario preset.

    Args:
        name: a key of ``SCENARIOS``, or a trace source of the form
            ``trace:<path>`` (``trace:sample`` replays the bundled
            Azure-style fixture). Trace sources take their arrivals and
            lengths from the trace itself; ``request_rate``, when
            positive, is interpreted as a target mean arrival rate and
            converted into the replay rate-scale (pass
            ``trace_rate_scale=...`` explicitly to override, with
            ``request_rate=0`` replaying the native rate).
        n_requests: number of requests (for traces: a replay cap).
        request_rate: long-run mean arrival rate (req/s).
        seed: master RNG seed.
        vocab: vocabulary size for prompt content.
        **overrides: any further `WorkloadConfig` field overrides.

    Returns:
        A frozen `WorkloadConfig` with ``split_streams=True``.
    """
    if name.startswith("trace:"):
        source = name[len("trace:"):] or "sample"
        # the rate target resolves against the trace's native rate at
        # generation time (one parse), unless an explicit scale override
        # is given — see WorkloadConfig.trace_target_rate
        target = (request_rate
                  if "trace_rate_scale" not in overrides else 0.0)
        wc = WorkloadConfig(n_requests=n_requests,
                            request_rate=request_rate, seed=seed,
                            vocab=vocab, split_streams=True, trace=source,
                            trace_target_rate=target)
        return replace(wc, **overrides) if overrides else wc
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"choose from {sorted(SCENARIOS)} or 'trace:<path>'")
    wc = WorkloadConfig(n_requests=n_requests, request_rate=request_rate,
                        seed=seed, vocab=vocab, split_streams=True,
                        **SCENARIOS[name])
    if overrides:
        wc = replace(wc, **overrides)
    # rate-driven tenant mixes carry *relative* rates in the preset;
    # rescale so the superposed aggregate equals request_rate (an
    # explicit tenants= override passes through untouched)
    if ("tenants" not in overrides and request_rate > 0
            and any(s.rate > 0 for s in wc.tenants)):
        total = sum(s.rate for s in wc.tenants)
        wc = replace(wc, tenants=tuple(
            replace(s, rate=s.rate * request_rate / total)
            for s in wc.tenants))
    return wc
