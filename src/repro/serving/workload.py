"""Workload generation: Alpaca-like request streams.

The Alpaca dataset (the paper's workload) is not available offline, so we
generate a synthetic stream whose *shape* matches its published statistics:
right-skewed prompt lengths (median ≈ 40 tokens; the paper's profiled prompt
tensor is [1, 44, 4096]) and right-skewed output lengths clipped to the
paper's 512-token prediction range (lognormal; most responses < 100 tokens,
a long tail up to 512 — the regime where SRPT-style policies shine).

Arrival processes: Poisson at a configurable request rate, or the paper's
burst scenario (everything at t=0, Figure 7).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass

from repro.serving.request import Request


@dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 256
    request_rate: float = 14.0       # the paper's Figure 5 operating point
    burst: bool = False
    prompt_mean: float = 44.0        # tokens (paper's profiling shape)
    prompt_sigma: float = 0.6        # lognormal sigma
    out_median: float = 48.0
    out_sigma: float = 1.0
    max_out: int = 512
    min_out: int = 1
    vocab: int = 32000
    seed: int = 0


def sample_output_length(rng: random.Random, wc: WorkloadConfig) -> int:
    v = rng.lognormvariate(math.log(wc.out_median), wc.out_sigma)
    return max(wc.min_out, min(int(v), wc.max_out))


def sample_prompt_length(rng: random.Random, wc: WorkloadConfig) -> int:
    v = rng.lognormvariate(math.log(wc.prompt_mean), wc.prompt_sigma)
    return max(4, min(int(v), 2048))


def generate(wc: WorkloadConfig) -> list[Request]:
    rng = random.Random(wc.seed)
    t = 0.0
    reqs = []
    for rid in range(wc.n_requests):
        if not wc.burst:
            t += rng.expovariate(wc.request_rate)
        plen = sample_prompt_length(rng, wc)
        olen = sample_output_length(rng, wc)
        prompt = [rng.randrange(1, wc.vocab) for _ in range(plen)]
        reqs.append(Request(rid=rid, arrival=t if not wc.burst else 0.0,
                            prompt=prompt, true_out_len=olen,
                            max_new_tokens=wc.max_out))
    return reqs
