"""Prediction providers for the engine.

Two regimes:
  * ``ProbePredictor`` — the real thing: probe logits come back fused from
    ``decode_step`` / ``prefill_chunk`` taps; this class just runs the
    Bayesian filter and converts posteriors to expected remaining lengths.
  * ``OraclePredictor`` — simulation mode: models the *statistics* of a
    trained probe (configurable accuracy) around the ground-truth remaining
    length, so paper-scale serving benchmarks can run without a GPU-scale
    model. ``temp`` controls per-iteration probe sharpness; ``bert_sigma``
    controls the prompt-only baseline's (one-shot) multiplicative error.

Both expose:
  initial(req)                 -> r0 (prompt-only prediction, pre-forward)
  on_prefill(req, tap_mean)    -> posterior from the prompt-phase embedding
  on_token(req, probe_probs)   -> updated predicted-remaining length
"""

from __future__ import annotations

import math
import random

import numpy as np

from repro.config import ProbeConfig
from repro.core import predictor as probe_mod
from repro.core.bins import bin_means
from repro.core.smoothing import bayes_update, transition_matrix


class PredictorBase:
    """Shared Bayesian-filter plumbing for all prediction providers."""

    def __init__(self, pc: ProbeConfig):
        self.pc = pc
        self.T = np.asarray(transition_matrix(pc))
        self.means = bin_means(pc)

    def expected(self, q) -> float:
        """Expected remaining length under a bin posterior ``q``."""
        return float(np.dot(np.asarray(q), self.means))

    def _filter(self, req, p_t):
        q = np.asarray(req.posterior)
        prior = self.T @ q
        post = prior * np.asarray(p_t)
        z = post.sum()
        req.posterior = post / z if z > 0 else prior
        return self.expected(req.posterior)


class OraclePredictor(PredictorBase):
    """Sim-mode stand-in: models a trained probe's *statistics* around the
    ground-truth remaining length (see module docstring)."""

    def __init__(self, pc: ProbeConfig, *, temp: float = 1.0,
                 bert_sigma: float = 0.9, flip_prob: float = 0.1,
                 seed: int = 0, refine: bool = True):
        super().__init__(pc)
        self.temp = temp
        self.bert_sigma = bert_sigma
        self.flip_prob = flip_prob
        self.refine = refine
        self.rng = random.Random(seed)

    def initial(self, req) -> float:
        """Prompt-only r0 estimate (the paper's one-shot "BERT" regime)."""
        # prompt-only "BERT" prediction: multiplicative lognormal error
        err = self.rng.lognormvariate(0.0, self.bert_sigma)
        r0 = min(max(req.true_out_len * err, 1.0), self.pc.max_len)
        req.posterior = self._probs_around(r0)
        return float(r0)

    def on_prefill(self, req, tap_mean=None) -> float:
        """Prompt-phase probe posterior at the end of prefill."""
        # prefill-phase probe: sharper than BERT (paper Figure 3, t=0 point)
        rem = req.true_out_len
        req.posterior = self._probs_around(self._noisy(rem))
        return self.expected(req.posterior)

    def on_token(self, req, probe_probs=None) -> float:
        """Per-token refinement (or r0 - age when refinement is off)."""
        if not self.refine:
            return max(float(req.entry.r0) - req.entry.age, 0.0)
        rem = max(req.true_out_len - len(req.generated), 0)
        p_t = self._probs_around(self._noisy(rem))
        return self._filter(req, p_t)

    def _noisy(self, rem: float) -> float:
        if self.rng.random() < self.flip_prob:
            rem = rem * self.rng.lognormvariate(0.0, 0.5)
        return rem

    def _probs_around(self, length: float) -> np.ndarray:
        b = min(int(length / self.pc.bin_width), self.pc.num_bins - 1)
        idx = np.arange(self.pc.num_bins)
        logits = -np.abs(idx - b) / max(self.temp, 1e-3)
        e = np.exp(logits - logits.max())
        return e / e.sum()


class ProbePredictor(PredictorBase):
    """Uses the real probe outputs (fused into the decode step)."""

    def __init__(self, pc: ProbeConfig, probe_params=None, embed_table=None):
        super().__init__(pc)
        self.probe_params = probe_params
        self.embed_table = embed_table     # for the pre-forward r0 estimate

    def initial(self, req) -> float:
        """Pre-forward r0 from mean prompt embeddings through the probe."""
        if self.probe_params is None or self.embed_table is None:
            return self.pc.max_len / 2.0       # uninformative prior
        emb = np.asarray(self.embed_table)[np.asarray(req.prompt)].mean(0)
        logits = np.asarray(probe_mod.apply_probe(self.probe_params, emb))
        p = np.exp(logits - logits.max())
        p /= p.sum()
        req.posterior = p
        return self.expected(p)

    def on_prefill(self, req, tap_mean) -> float:
        """Posterior from the prompt-phase tap mean (real probe output)."""
        logits = np.asarray(probe_mod.apply_probe(self.probe_params,
                                                  np.asarray(tap_mean)))
        p = np.exp(logits - logits.max())
        p /= p.sum()
        req.posterior = p
        return self.expected(p)

    def on_token(self, req, probe_probs) -> float:
        """Bayes-update with the device-computed probe posterior."""
        return self._filter(req, np.asarray(probe_probs))
