"""Prediction providers: `LengthPredictor` and its strategy family.

Every provider implements the same three-hook protocol (duck-typed; no
ABC so sim-mode providers stay dependency-free):

  initial(req)                 -> r0 (prompt-only prediction, pre-forward)
  on_prefill(req, tap_mean)    -> prediction from the prompt-phase embedding
  on_token(req, probe_probs)   -> updated predicted-remaining length

plus two class-level contracts the engine consults:

  provides_magnitude  — True when predictions are remaining-token
      *magnitudes* (usable for the preemption budget a0, megastep
      lookahead pinning, and the router's predicted-work backlog);
      False for rank-only strategies whose values are ordinal scores
      (the engine then requires the rank-aware scheduler policy and
      falls back to priors for backlog).
  cost accounting     — each strategy declares the FLOPs an external
      implementation of it would spend per call (`flops_initial`,
      `flops_refine`, `flops_per_prompt_token`); calls accumulate into
      ``cost_flops_pending``, which the engine drains every step and
      converts to seconds through `CostModel.predictor_time`, charging
      the simulated clock. The recycled-embedding strategies charge
      zero: their probe rides inside the decode megastep, which is the
      paper's whole point.

Strategy family (``STRATEGIES``; build by name via `make_predictor`):

  * ``trail-probe``  — the existing recycled-embedding probe. In sim
    mode this is `OraclePredictor` (models a trained probe's
    *statistics* around the ground truth — ``temp`` controls probe
    sharpness, ``bert_sigma`` the one-shot prompt-only error); in real
    mode `ProbePredictor` consumes the fused probe outputs.
  * ``oracle``       — `ExactOraclePredictor`: perfect lengths, the
    scheduling-gain upper bound.
  * ``noisy-oracle`` — `NoisyOraclePredictor`: oracle with configurable
    multiplicative lognormal error (the prediction-quality dial).
  * ``bucketed``     — `BucketedOraclePredictor`: the paper's k-bin
    quantization of the oracle (bin-mean predictions).
  * ``prompt-only``  — `PromptOnlyPredictor`: one-shot admission-time
    estimate from an external prompt model (the BERT-baseline regime);
    never refined, charged per prompt token.
  * ``rank-only``    — `RankOnlyPredictor`: learning-to-rank (Fu et
    al., arXiv:2408.15792) — total-order scores, no magnitudes;
    consumed by the scheduler's ``rank`` policy.
  * ``iterative``    — `IterativePredictor`: ELIS-style re-prediction
    (Choi et al., arXiv:2505.09142) every r probe boundaries through a
    proxy estimator; predictions age deterministically in between.
"""

from __future__ import annotations

import math
import random

import numpy as np

from repro.config import ProbeConfig
from repro.core import predictor as probe_mod
from repro.core.bins import bin_means
from repro.core.smoothing import bayes_update, transition_matrix

#: Strategy names accepted by `make_predictor` (and the CLI/benchmark
#: ``--predictor`` spec syntax ``name[:key=value,...]``).
STRATEGIES = ("trail-probe", "oracle", "noisy-oracle", "bucketed",
              "prompt-only", "rank-only", "iterative")

#: Default proxy-model size for externally-priced strategies: a
#: BERT-base-sized estimator (~110M params), 2*N FLOPs per token.
PROXY_FLOPS_PER_TOKEN = 2.0 * 110e6


class PredictorBase:
    """Shared Bayesian-filter plumbing + `LengthPredictor` defaults.

    The defaults: magnitude predictions, zero charged cost.
    """

    #: predictions are remaining-length magnitudes (tokens); rank-only
    #: strategies override to False and emit ordinal scores instead
    provides_magnitude = True
    #: FLOPs an external implementation would charge per call class;
    #: zero everywhere by default (recycled embeddings / free oracles)
    flops_initial = 0.0
    flops_refine = 0.0
    flops_per_prompt_token = 0.0

    def __init__(self, pc: ProbeConfig):
        self.pc = pc
        self.T = np.asarray(transition_matrix(pc))
        self.means = bin_means(pc)
        self.cost_flops_pending = 0.0   # drained by the engine each step
        self.cost_flops_total = 0.0
        self.cost_calls = 0

    def charge(self, flops: float):
        """Book ``flops`` of predictor work (drained by `take_cost_flops`)."""
        self.cost_calls += 1
        if flops:
            self.cost_flops_pending += flops
            self.cost_flops_total += flops

    def take_cost_flops(self) -> float:
        """Return and clear the FLOPs charged since the last drain."""
        f = self.cost_flops_pending
        self.cost_flops_pending = 0.0
        return f

    def expected(self, q) -> float:
        """Expected remaining length under a bin posterior ``q``."""
        return float(np.dot(np.asarray(q), self.means))

    def _filter(self, req, p_t):
        q = np.asarray(req.posterior)
        prior = self.T @ q
        post = prior * np.asarray(p_t)
        z = post.sum()
        req.posterior = post / z if z > 0 else prior
        return self.expected(req.posterior)


class OraclePredictor(PredictorBase):
    """Sim-mode stand-in for a trained probe.

    Models the probe's *statistics* around the ground-truth remaining
    length (see module docstring).
    """

    def __init__(self, pc: ProbeConfig, *, temp: float = 1.0,
                 bert_sigma: float = 0.9, flip_prob: float = 0.1,
                 seed: int = 0, refine: bool = True):
        super().__init__(pc)
        self.temp = temp
        self.bert_sigma = bert_sigma
        self.flip_prob = flip_prob
        self.refine = refine
        self.rng = random.Random(seed)

    def initial(self, req) -> float:
        """Prompt-only r0 estimate (the paper's one-shot "BERT" regime)."""
        # prompt-only "BERT" prediction: multiplicative lognormal error
        err = self.rng.lognormvariate(0.0, self.bert_sigma)
        r0 = min(max(req.true_out_len * err, 1.0), self.pc.max_len)
        req.posterior = self._probs_around(r0)
        return float(r0)

    def on_prefill(self, req, tap_mean=None) -> float:
        """Prompt-phase probe posterior at the end of prefill."""
        # prefill-phase probe: sharper than BERT (paper Figure 3, t=0 point)
        rem = req.true_out_len
        req.posterior = self._probs_around(self._noisy(rem))
        return self.expected(req.posterior)

    def on_token(self, req, probe_probs=None) -> float:
        """Per-token refinement (or r0 - age when refinement is off)."""
        if not self.refine:
            return max(float(req.entry.r0) - req.entry.age, 0.0)
        rem = max(req.true_out_len - len(req.generated), 0)
        p_t = self._probs_around(self._noisy(rem))
        return self._filter(req, p_t)

    def _noisy(self, rem: float) -> float:
        if self.rng.random() < self.flip_prob:
            rem = rem * self.rng.lognormvariate(0.0, 0.5)
        return rem

    def _probs_around(self, length: float) -> np.ndarray:
        b = min(int(length / self.pc.bin_width), self.pc.num_bins - 1)
        idx = np.arange(self.pc.num_bins)
        logits = -np.abs(idx - b) / max(self.temp, 1e-3)
        e = np.exp(logits - logits.max())
        return e / e.sum()


class ProbePredictor(PredictorBase):
    """Uses the real probe outputs (fused into the decode step)."""

    def __init__(self, pc: ProbeConfig, probe_params=None, embed_table=None):
        super().__init__(pc)
        self.probe_params = probe_params
        self.embed_table = embed_table     # for the pre-forward r0 estimate

    def initial(self, req) -> float:
        """Pre-forward r0 from mean prompt embeddings through the probe."""
        if self.probe_params is None or self.embed_table is None:
            return self.pc.max_len / 2.0       # uninformative prior
        emb = np.asarray(self.embed_table)[np.asarray(req.prompt)].mean(0)
        logits = np.asarray(probe_mod.apply_probe(self.probe_params, emb))
        p = np.exp(logits - logits.max())
        p /= p.sum()
        req.posterior = p
        return self.expected(p)

    def on_prefill(self, req, tap_mean) -> float:
        """Posterior from the prompt-phase tap mean (real probe output)."""
        logits = np.asarray(probe_mod.apply_probe(self.probe_params,
                                                  np.asarray(tap_mean)))
        p = np.exp(logits - logits.max())
        p /= p.sum()
        req.posterior = p
        return self.expected(p)

    def on_token(self, req, probe_probs) -> float:
        """Bayes-update with the device-computed probe posterior."""
        return self._filter(req, np.asarray(probe_probs))


# ---------------------------------------------------------------------------
# the strategy family (sim-mode; see module docstring)
# ---------------------------------------------------------------------------

class ExactOraclePredictor(PredictorBase):
    """Perfect length predictions — the scheduling-gain upper bound.

    Every hook returns the exact ground-truth remaining length; cost is
    zero (nothing real computes this). Any realizable predictor's
    scheduling gain is bounded above by this strategy's.
    """

    def initial(self, req) -> float:
        """Exact total output length."""
        return float(max(req.true_out_len, 1))

    def on_prefill(self, req, tap_mean=None) -> float:
        """Exact remaining length at the end of prefill."""
        return float(max(req.true_out_len - len(req.generated), 0))

    def on_token(self, req, probe_probs=None) -> float:
        """Exact remaining length after each probe boundary."""
        return float(max(req.true_out_len - len(req.generated), 0))


class NoisyOraclePredictor(PredictorBase):
    """Oracle with configurable multiplicative error — the quality dial.

    Every prediction is ``truth * lognormal(0, sigma)`` (a fresh draw
    per call), clipped to the probe range. ``sigma`` sweeps continuously
    from the oracle (0.0) to worse-than-prompt-only (>1.0); at
    ``sigma -> 0`` the induced queue ordering converges to the oracle
    ordering (pinned by a hypothesis property test).
    """

    def __init__(self, pc: ProbeConfig, *, sigma: float = 0.6, seed: int = 0):
        super().__init__(pc)
        self.sigma = float(sigma)
        self.rng = random.Random(seed)

    def _noisy(self, truth: float) -> float:
        err = self.rng.lognormvariate(0.0, self.sigma) if self.sigma else 1.0
        return min(max(truth * err, 0.0), float(self.pc.max_len))

    def initial(self, req) -> float:
        """Noisy total output length."""
        return max(self._noisy(float(req.true_out_len)), 1.0)

    def on_prefill(self, req, tap_mean=None) -> float:
        """Noisy remaining length at the end of prefill."""
        return self._noisy(max(req.true_out_len - len(req.generated), 0))

    def on_token(self, req, probe_probs=None) -> float:
        """Noisy remaining length after each probe boundary."""
        return self._noisy(max(req.true_out_len - len(req.generated), 0))


class BucketedOraclePredictor(PredictorBase):
    """The paper's k-bin quantization of the oracle (Section 3.1 regime).

    Predictions are the bin *means* of equal-width bins over
    ``[0, max_len]`` — exactly the information a perfectly-trained
    k-class probe could express. ``bins`` dials quantization coarseness
    independently of noise (2 bins ≈ short/long classification).
    """

    def __init__(self, pc: ProbeConfig, *, bins: int = 0):
        super().__init__(pc)
        self.bins = int(bins) if bins else pc.num_bins
        if self.bins < 1:
            raise ValueError("bucketed predictor needs >= 1 bin")
        self.width = float(pc.max_len) / self.bins

    def _quantize(self, truth: float) -> float:
        b = min(int(truth / self.width), self.bins - 1)
        return self.width * (b + 0.5)

    def initial(self, req) -> float:
        """Bin mean holding the total output length."""
        return self._quantize(float(max(req.true_out_len, 1)))

    def on_prefill(self, req, tap_mean=None) -> float:
        """Bin mean holding the remaining length at end of prefill."""
        return self._quantize(max(req.true_out_len - len(req.generated), 0))

    def on_token(self, req, probe_probs=None) -> float:
        """Bin mean holding the current remaining length."""
        return self._quantize(max(req.true_out_len - len(req.generated), 0))


class PromptOnlyPredictor(PredictorBase):
    """One-shot admission-time estimate, never refined.

    The paper's BERT-baseline regime: an external prompt model predicts
    once at admission. ``initial`` draws one multiplicative-lognormal estimate (the same
    error model as `OraclePredictor.initial`, so ``sigma`` is comparable)
    and charges a BERT-base-sized forward over the prompt; both later
    hooks just age the estimate deterministically (r0 - tokens served) —
    the information content never improves after admission.
    """

    flops_per_prompt_token = PROXY_FLOPS_PER_TOKEN

    def __init__(self, pc: ProbeConfig, *, sigma: float = 0.9, seed: int = 0):
        super().__init__(pc)
        self.sigma = float(sigma)
        self.rng = random.Random(seed)

    def initial(self, req) -> float:
        """One noisy prompt-model estimate; charged per prompt token."""
        self.charge(self.flops_per_prompt_token * len(req.prompt))
        err = self.rng.lognormvariate(0.0, self.sigma) if self.sigma else 1.0
        return min(max(req.true_out_len * err, 1.0), float(self.pc.max_len))

    def on_prefill(self, req, tap_mean=None) -> float:
        """No refinement: the aged admission estimate."""
        return max(float(req.entry.r0) - req.entry.age, 0.0)

    def on_token(self, req, probe_probs=None) -> float:
        """No refinement: the aged admission estimate."""
        return max(float(req.entry.r0) - req.entry.age, 0.0)


class RankOnlyPredictor(PredictorBase):
    """Learning-to-rank scheduling signal (Fu et al., arXiv:2408.15792):

    a total order over the queue with **no magnitudes**.

    Scores are a strictly monotone, scale-free transform of the (noisy)
    remaining length — ``log1p`` normalized into [0, 1] — so comparing
    two scores reproduces the true ordering but no score is a token
    count: the engine must not use them for preemption budgets,
    lookahead pinning, or backlog sums (``provides_magnitude = False``
    enforces this; only the scheduler's ``rank`` policy consumes them).
    ``noise`` is the ranker-error dial: multiplicative lognormal
    perturbation before scoring, so pairwise inversions grow with it.
    With ``noise=0`` the induced `select_batch` ordering is identical
    to magnitude-SRPT (pinned by tests).
    """

    provides_magnitude = False

    def __init__(self, pc: ProbeConfig, *, noise: float = 0.0, seed: int = 0):
        super().__init__(pc)
        self.noise = float(noise)
        self.rng = random.Random(seed)
        self._norm = math.log1p(float(pc.max_len))

    def _score(self, value: float) -> float:
        if self.noise:
            value = value * self.rng.lognormvariate(0.0, self.noise)
        return math.log1p(max(value, 0.0)) / self._norm

    def initial(self, req) -> float:
        """Ordinal score of the total output length."""
        return self._score(float(max(req.true_out_len, 1)))

    def on_prefill(self, req, tap_mean=None) -> float:
        """Ordinal score of the remaining length at end of prefill."""
        return self._score(max(req.true_out_len - len(req.generated), 0))

    def on_token(self, req, probe_probs=None) -> float:
        """Ordinal score of the current remaining length."""
        return self._score(max(req.true_out_len - len(req.generated), 0))


class IterativePredictor(PredictorBase):
    """ELIS-style iterative re-prediction (Choi et al., arXiv:2505.09142):

    a proxy estimator re-predicts the remaining length every ``period``
    probe boundaries; predictions age deterministically in between.

    ``period`` is the staleness dial (1 = re-predict at every boundary,
    the freshest and most expensive; large = admission-estimate-like).
    Each re-prediction draws a fresh ``sigma``-lognormal error around
    the true remaining length and charges one proxy-token forward.
    """

    flops_initial = PROXY_FLOPS_PER_TOKEN
    flops_refine = PROXY_FLOPS_PER_TOKEN

    def __init__(self, pc: ProbeConfig, *, period: int = 8,
                 sigma: float = 0.3, seed: int = 0):
        super().__init__(pc)
        if period < 1:
            raise ValueError("iterative predictor needs period >= 1")
        self.period = int(period)
        self.sigma = float(sigma)
        self.rng = random.Random(seed)
        self._boundaries: dict[int, int] = {}   # rid -> probe-boundary count

    def _estimate(self, truth: float) -> float:
        err = self.rng.lognormvariate(0.0, self.sigma) if self.sigma else 1.0
        return min(max(truth * err, 0.0), float(self.pc.max_len))

    def initial(self, req) -> float:
        """Admission-time proxy estimate (one charged proxy forward)."""
        self.charge(self.flops_initial)
        self._boundaries[req.rid] = 0
        return max(self._estimate(float(req.true_out_len)), 1.0)

    def on_prefill(self, req, tap_mean=None) -> float:
        """Fresh proxy re-prediction at the end of prefill (charged)."""
        self.charge(self.flops_refine)
        return self._estimate(max(req.true_out_len - len(req.generated), 0))

    def on_token(self, req, probe_probs=None) -> float:
        """Re-predict every ``period``-th boundary, else age the estimate."""
        c = self._boundaries.get(req.rid, 0) + 1
        self._boundaries[req.rid] = c
        if c % self.period:
            return max(float(req.entry.pred_remaining) - 1.0, 0.0)
        self.charge(self.flops_refine)
        return self._estimate(max(req.true_out_len - len(req.generated), 0))


# ---------------------------------------------------------------------------
# strategy factory
# ---------------------------------------------------------------------------

def parse_spec(spec: str) -> tuple[str, dict]:
    """Parse a predictor spec string ``name[:key=value,...]``.

    Values parse as int when possible, else float, else string — e.g.
    ``"noisy-oracle:sigma=0.5"`` -> ``("noisy-oracle", {"sigma": 0.5})``.
    """
    name, _, argstr = spec.partition(":")
    kwargs: dict = {}
    for kv in filter(None, argstr.split(",")):
        if "=" not in kv:
            raise ValueError(f"bad predictor spec argument {kv!r} "
                             f"(want key=value)")
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        kwargs[k.strip()] = v
    return name.strip(), kwargs


def make_predictor(spec: str, pc: ProbeConfig, *, seed: int = 0):
    """Build a sim-mode predictor from a strategy spec string.

    ``spec`` is ``name[:key=value,...]`` with ``name`` in `STRATEGIES`;
    unknown keys raise (strategies are keyword-strict). ``trail-probe``
    returns the engine's legacy default `OraclePredictor` with identical
    constructor arguments, so selecting it explicitly is byte-identical
    to not selecting a strategy at all. Real-mode engines keep building
    `ProbePredictor` directly (it needs live probe params).
    """
    name, kwargs = parse_spec(spec)
    builders = {
        "trail-probe": OraclePredictor,
        "oracle": ExactOraclePredictor,
        "noisy-oracle": NoisyOraclePredictor,
        "bucketed": BucketedOraclePredictor,
        "prompt-only": PromptOnlyPredictor,
        "rank-only": RankOnlyPredictor,
        "iterative": IterativePredictor,
    }
    if name not in builders:
        raise ValueError(f"unknown predictor strategy {name!r}; "
                         f"choose from {STRATEGIES}")
    cls = builders[name]
    if cls in (ExactOraclePredictor, BucketedOraclePredictor):
        return cls(pc, **kwargs)            # deterministic: no seed knob
    return cls(pc, seed=seed, **kwargs)
