"""Iteration-level serving engine (the paper's system, Figure 1).

One engine iteration =
  1. admit new arrivals into the request pool (initial prompt-only
     prediction fixes r0 and the preemption budget a0 = floor(C*r0));
  2. run the SPRPT-LP scheduler over running+waiting+preempted requests
     under the slot/memory budget (Section 3.3); apply preemptions
     (discard-and-recompute: slot released, cache invalidated);
  3. chunked prefill for scheduled-but-unprefilled requests (shared
     per-iteration token budget, rank order);
  4. one decode MEGASTEP for every scheduled prefilled request: k =
     probe_interval fused decode+probe steps stay resident on device
     (lax.scan, on-device greedy sampling, donated KV buffers), with the
     probe fused into every step; Bayesian-refine predictions (Section 3.1)
     at each k-token probe boundary;
  5. advance the clock: real wall time, or the roofline cost model
     (CPU-only container; see costmodel.py).

Two execution modes:
  * real  — a JAX model actually prefills/decodes on a fixed slot pool
            (static shapes, one compile per phase); probe predictions are
            real probe outputs. Generation ends at the oracle length or
            EOS/max_new. The decode hot path runs in megasteps: scheduler,
            page allocation and cost model are consulted once per k tokens,
            the host round-trip is O(B*k) token ids + probe posteriors
            (never O(B*vocab) logits), and the KV cache is donated to every
            jit call so XLA updates it in place.
  * sim   — no device math; oracle-noise probe statistics; paper-scale
            models under the cost model (Figures 5-7 reproduction). Sim
            stays a per-token loop: probe_interval only throttles
            refinement there, so scheduling semantics are unchanged.
"""

from __future__ import annotations

import bisect
import time
from dataclasses import dataclass, field

import numpy as np

from repro.config import ModelConfig
from repro.core.scheduler import Decision, ReqState, SchedEntry, select_batch
from repro.serving.costmodel import CostModel, HardwareSpec
from repro.serving.kv_cache import (BlockManager, PagedSlotPool, SlotPool,
                                    bytes_for_context, donating_jit,
                                    page_bytes, paged_bytes_for_context,
                                    pages_for_tokens,
                                    supports_page_retention)
from repro.serving.predictors import (OraclePredictor, PredictorBase,
                                      make_predictor)
from repro.serving.request import Request


@dataclass
class EngineConfig:
    """Knobs for one serving engine (one replica in cluster mode).

    Attributes:
        policy: scheduling policy — ``fcfs`` | ``sjf`` | ``srpt`` |
            ``trail`` | ``trail-bert`` | ``mlfq`` (see core/scheduler.py).
        c_limit: the paper's C — preemption budget fraction; a request is
            preemptable only for its first ``floor(C * r0)`` output tokens.
        max_batch: batch slot count (max concurrently running requests).
        mem_budget: KV-cache byte budget enforced at admission time.
        prefill_chunk: per-iteration chunked-prefill token budget shared by
            all prefilling requests in rank order.
        max_len: cache capacity per sequence, in tokens.
        probe_interval: refine predictions every k-th token (paper Sec 6);
            in real mode also the decode megastep length — k tokens per
            row stay on device between scheduling points.
        oom_mode: ``discard`` (paper's discard-and-recompute) | ``swap``
            (KV to host over DMA; sim-mode cost study only).
        kv_layout: ``contig`` (slot cache) | ``paged`` (block-table pages;
            preemption frees / retains / swaps at page granularity).
        page_size: tokens per KV page (paged layout only).
        prefix_cache: share identical KV prefixes across requests (paged
            layout, pure global-attention archs only): admission links
            the longest content-hash-matched page chain instead of
            prefilling it, ranks and admission bytes charge only uncached
            work, and finished requests' prompt pages stay warm in a
            reusable LRU pool. Off by default — disabled results are
            byte-identical to the pre-prefix-cache engine.
        predictor: length-prediction strategy spec
            (``name[:key=value,...]``, see
            `repro.serving.predictors.STRATEGIES`), e.g.
            ``"noisy-oracle:sigma=0.5"``. Empty (the default) keeps the
            legacy sim-mode `OraclePredictor` — byte-identical to
            pre-strategy-layer engines. An explicitly passed predictor
            instance always wins over this spec. Rank-only strategies
            (``provides_magnitude == False``) require an ordinal
            scheduling policy (``rank`` / ``fcfs`` / ``mlfq``).
        mode: ``sim`` (cost-model clock, oracle-noise probe) | ``real``
            (JAX model actually prefills/decodes).
        hardware: roofline constants that drive the simulated clock.
        seed: seed for the engine's decode-token RNG (sim mode).
        deadline_s: default per-request completion deadline (seconds
            after arrival, engine clock); expired requests are cancelled
            with reason ``timeout`` at the next megastep boundary. 0 =
            no deadline. A request's own ``deadline_s`` overrides it.
        ttft_deadline_s: default first-token budget (seconds after
            arrival); a request still waiting for its first token past
            it is cancelled with reason ``timeout``. 0 = none.
        shed_watermark: predicted-backlog watermark in tokens (the TRAIL
            signal `Engine.backlog` already computes). While the live
            backlog exceeds it, the worst-ranked WAITING requests are
            shed (cancelled with reason ``shed``) at megastep
            boundaries. 0 (the default) disables shedding — results are
            byte-identical to the pre-resilience engine.
        admission_control: with ``shed_watermark`` set, refuse arrivals
            at admission time while the live backlog is over the
            watermark (reject-at-the-door instead of shedding queued
            work). Refused requests emit ``arrival`` + ``shed`` and
            never enter the pool.
        age_boost: rank-aging boost — rank units (predicted tokens for
            the magnitude policies) subtracted per second a request has
            been in the system beyond the ``age_delay_s`` grace window,
            for trail / srpt / trail-bert / rank. Any value > 0 bounds
            waiting time (no starvation); larger values dial the
            post-window ordering from pure SRPT toward FCFS, buying
            completion-p99 at a small mean cost. 0 (the default) keeps
            ranks byte-identical to the un-aged scheduler.
        age_delay_s: rank-aging grace window in seconds — ordering stays
            pure SRPT for requests that have waited less than this; only
            the excess wait is boosted. Read only when ``age_boost`` >
            0. 0 ages from arrival, which preserves *relative* order
            between any two queued requests (both fall at the same
            rate): a real starvation rescue wants a window around the
            tolerable-wait budget.
        deadline_slack_s: deadline-aware limited preemption — a RUNNING
            request whose completion deadline (per-request or engine
            ``deadline_s``) is within this many seconds is pinned into
            the batch (never preempted) under every preemptive policy,
            generalizing the paper's served-token C-limit to wall-clock
            urgency. 0 (the default) = off; no effect on requests
            without a deadline.
        prefill_only: disaggregated-prefill role. The engine runs
            chunked prefill only: a request whose prefill completes is
            *parked* (slot released, KV pages retained — no preemption
            is booked) instead of decoding, and surfaces in
            ``handoff_ready()`` for the router to ``export_request()``
            to a decode replica. Requires ``kv_layout='paged'`` on a
            page-retention arch (the handoff ships retained pages).
            Off by default — the engine is byte-identical without it.
    """

    policy: str = "trail"           # fcfs | sjf | srpt | trail | trail-bert
                                    # | mlfq | rank
    c_limit: float = 0.8            # the paper's C
    max_batch: int = 16             # slot count
    mem_budget: int = 1 << 62       # cache bytes budget
    prefill_chunk: int = 256        # per-iteration prefill token budget
    max_len: int = 1024             # cache slots per sequence
    probe_interval: int = 1         # refine every k-th token (paper Sec 6
                                    # future work; k>1 cuts probe cost k x).
                                    # real mode: also the decode MEGASTEP
                                    # length — k tokens per row stay on
                                    # device between scheduling points
    oom_mode: str = "discard"       # "discard" (paper's choice: recompute)
                                    # | "swap" (KV to host; sim mode only)
    kv_layout: str = "contig"       # "contig" (slot cache) | "paged"
                                    # (block-table pages; preemption frees /
                                    #  retains / swaps at page granularity)
    page_size: int = 16             # tokens per KV page (paged layout)
    prefix_cache: bool = False      # share identical KV prefixes across
                                    # requests (paged layout only)
    predictor: str = ""             # strategy spec "name[:k=v,...]"; empty
                                    # = legacy OraclePredictor default
    mode: str = "sim"               # "sim" | "real"
    hardware: HardwareSpec = field(default_factory=HardwareSpec)
    seed: int = 0
    deadline_s: float = 0.0         # default completion deadline (0 = none)
    ttft_deadline_s: float = 0.0    # default first-token budget (0 = none)
    shed_watermark: float = 0.0     # predicted-backlog shed threshold in
                                    # tokens (0 = shedding off)
    admission_control: bool = False  # refuse (vs queue) arrivals while the
                                     # backlog is over the watermark
    age_boost: float = 0.0          # rank-aging boost (rank units/second
                                    # waited past the grace window;
                                    # 0 = aging off)
    age_delay_s: float = 0.0        # rank-aging grace window (seconds)
    deadline_slack_s: float = 0.0   # deadline-slack non-preemption window
                                    # in seconds (0 = off)
    prefill_only: bool = False      # disaggregated-prefill role: park
                                    # finished prefills for KV handoff
                                    # instead of decoding (paged +
                                    # page-retention archs only)


@dataclass
class EngineStats:
    """Counters accumulated over an engine run (or a `step()` stream)."""

    latencies: list = field(default_factory=list)
    ttfts: list = field(default_factory=list)
    n_preemptions: int = 0
    recomputed_tokens: int = 0
    swapped_bytes: int = 0
    peak_mem_bytes: int = 0
    peak_batch: int = 0
    iterations: int = 0
    sim_time: float = 0.0
    prefilled_tokens: int = 0       # prefill tokens actually computed
    prefix_hit_tokens: int = 0      # prompt tokens served from the cache
    predictor_time_s: float = 0.0   # clock charged for predictor work
    predictor_calls: int = 0        # predictor invocations booked
    n_cancelled: int = 0            # total cancellations (any reason)
    n_timeouts: int = 0             # ...of which deadline/TTFT expiries
    n_shed: int = 0                 # ...of which load-shedding drops

    def summary(self) -> dict:
        """Aggregate the counters into the benchmark-facing dict."""
        lat = sorted(self.latencies)
        tt = sorted(self.ttfts)
        med = lambda v: v[len(v) // 2] if v else 0.0
        return {
            "mean_latency": float(np.mean(lat)) if lat else 0.0,
            "median_latency": med(lat),
            "mean_ttft": float(np.mean(tt)) if tt else 0.0,
            "median_ttft": med(tt),
            "p99_latency": lat[int(len(lat) * 0.99)] if lat else 0.0,
            "preemptions": self.n_preemptions,
            "recomputed_tokens": self.recomputed_tokens,
            "swapped_gb": self.swapped_bytes / 1e9,
            "peak_mem_gb": self.peak_mem_bytes / 1e9,
            "iterations": self.iterations,
            "peak_batch": self.peak_batch,
            "makespan": self.sim_time,
            "prefilled_tokens": self.prefilled_tokens,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "predictor_time_s": self.predictor_time_s,
            "predictor_calls": self.predictor_calls,
            "cancelled": self.n_cancelled,
            "timeouts": self.n_timeouts,
            "shed": self.n_shed,
        }


class StepResult:
    """Outcome of one `Engine.step()` call.

    Attributes:
        completed: requests that reached FINISHED during this step.
        now: the engine's virtual clock after the step.
        backlog: predicted remaining work (tokens) still queued/running —
            the join-shortest-predicted-work routing signal. Computed
            lazily on first access (an O(live requests) pass), so the
            batch ``run()`` loop, which never reads it, pays nothing.
        ran: False for idle steps (clock jump to the next arrival, or a
            fully drained engine); no device/sim work was performed.
        kv_headroom: free-page fraction of the KV pool after the step
            (1.0 = empty pool / effectively unlimited budget, 0.0 = full)
            — the routing-under-memory-pressure signal: dispatching a
            long-context arrival to a replica near its budget triggers
            avoidable preemptions, so `jspw` tie-breaks on it.
        events: the metrics-layer `Event`s this step emitted (arrival /
            admit / first-token / tokens / finish / preempt / swap);
            empty unless the engine was built with an ``event_log``.
    """

    __slots__ = ("completed", "now", "ran", "kv_headroom", "events",
                 "_backlog_fn", "_backlog")

    def __init__(self, completed=None, now=0.0, ran=False, backlog_fn=None,
                 kv_headroom=1.0, events=()):
        self.completed = completed if completed is not None else []
        self.now = now
        self.ran = ran
        self.kv_headroom = kv_headroom
        self.events = list(events)
        self._backlog_fn = backlog_fn
        self._backlog = None

    @property
    def backlog(self) -> float:
        """Predicted-work backlog at the end of the step (lazy, cached)."""
        if self._backlog is None:
            self._backlog = self._backlog_fn() if self._backlog_fn else 0.0
        return self._backlog


@dataclass
class KVHandoff:
    """One exported request's migration package (KV-page shipping).

    Produced by `Engine.export_request` on the source replica and
    consumed by `Engine.import_request` on the destination; the router
    charges `CostModel.kv_transfer_time(nbytes)` as delayed availability
    in between. The `Request` object travels whole, so arrival,
    first_token_time, generated tokens and the live prediction state all
    survive the migration.

    Attributes:
        req: the request (entry/arrival/first_token_time intact).
        kv_tokens: materialized prefix tokens shipped; 0 means the
            destination re-prefills from scratch.
        n_pages: KV pages on the wire (the transfer-size unit).
        nbytes: page bytes on the wire (``n_pages * page_bytes``).
        payload: real mode only — the host-side page payload gathered by
            `PagedSlotPool.export_pages` (one batched copy); None in sim
            mode, where the descriptor is the whole transfer.
        pred_tokens: predicted remaining decode tokens at export, or
            None under a rank-only predictor (ordinal score — the
            router must not read it as work).
        src_now: source replica clock at export (the transfer starts
            here).
    """

    req: Request
    kv_tokens: int = 0
    n_pages: int = 0
    nbytes: int = 0
    payload: object = None
    pred_tokens: float | None = None
    src_now: float = 0.0


class Engine:
    """Iteration-level serving engine (one replica).

    Two entry styles share one state machine:

    * batch — ``run(requests)`` drives the whole trace to completion
      (the original API; byte-identical results).
    * incremental — ``submit(req)`` enqueues an arrival at any time and
      ``step()`` executes exactly one engine iteration (one decode
      megastep + prefill chunk), returning a `StepResult`. The cluster
      `Router` uses this to interleave N replicas in virtual time.
    """

    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig,
                 predictor: PredictorBase | None = None,
                 model=None, params=None, event_log=None):
        """Build one engine.

        Args:
            cfg: the model/architecture configuration it serves.
            ecfg: engine knobs (see `EngineConfig`).
            predictor: remaining-length predictor instance; overrides
                any ``ecfg.predictor`` strategy spec. Default: the spec
                (built via `make_predictor`) when given, else the
                legacy sim-mode `OraclePredictor`.
            model: the JAX model (real mode only).
            params: its parameters (real mode only).
            event_log: optional `repro.metrics.EventLog`; when given the
                engine records per-request lifecycle events (arrival /
                admit / first-token / tokens / finish / preempt / swap)
                into it during ``step()``. Pure observation — results
                are byte-identical with or without a log.
        """
        self.cfg = cfg
        self.ecfg = ecfg
        self.events = event_log
        if predictor is not None:
            self.predictor = predictor
        elif ecfg.predictor:
            self.predictor = make_predictor(ecfg.predictor, cfg.probe,
                                            seed=ecfg.seed)
        else:
            self.predictor = OraclePredictor(cfg.probe, seed=ecfg.seed)
        # rank-only strategies emit ordinal scores, not token counts:
        # magnitude-consuming policies (preemption budget a0, megastep
        # lookahead, remaining-work ranks) would misread them
        self._magnitude = getattr(self.predictor, "provides_magnitude", True)
        if not self._magnitude and ecfg.policy not in ("rank", "fcfs",
                                                       "sjf", "mlfq"):
            raise ValueError(
                f"predictor provides ordinal ranks, not magnitudes; "
                f"policy {ecfg.policy!r} consumes token-count predictions "
                f"— use policy='rank' (or a prediction-free baseline)")
        self.paged = ecfg.kv_layout == "paged"
        if ecfg.kv_layout not in ("contig", "paged"):
            raise ValueError(f"unknown kv_layout {ecfg.kv_layout!r}")
        self.prefix_cache = ecfg.prefix_cache
        if self.prefix_cache:
            if not self.paged:
                raise ValueError("prefix_cache requires kv_layout='paged'")
            if not supports_page_retention(cfg):
                raise ValueError(
                    "prefix_cache requires a pure global-attention arch: "
                    "only there is the whole per-token state page-resident "
                    "and position-consistent across requests")
        self.cost = CostModel(cfg, ecfg.hardware,
                              page_size=ecfg.page_size if self.paged else 0)
        self.model = model
        self.params = params
        self.pool: SlotPool | None = None
        self.blocks: BlockManager | None = None
        self._retain = self.paged and supports_page_retention(cfg)
        if ecfg.prefill_only and not self._retain:
            raise ValueError(
                "prefill_only requires kv_layout='paged' on a "
                "page-retention arch: the KV handoff ships retained pages")
        self._page_bytes = page_bytes(cfg, ecfg.page_size)
        self._swap_pending_s = 0.0
        if ecfg.oom_mode == "swap" and ecfg.mode == "real":
            raise ValueError("swap OOM mode is a cost-model study (sim only);"
                             " the real engine uses the paper's"
                             " discard-and-recompute")
        # megastep length: real mode decodes k = probe_interval tokens per
        # row per engine iteration without host round-trips; sim mode stays
        # per-token (probe_interval only throttles refinement there).
        self._k = max(1, ecfg.probe_interval) if ecfg.mode == "real" else 1
        if ecfg.mode == "real":
            assert model is not None and params is not None
            if self.paged:
                self.pool = PagedSlotPool(model, ecfg.max_batch, ecfg.max_len,
                                          page_size=ecfg.page_size,
                                          retain=self._retain,
                                          prefix_cache=self.prefix_cache)
                self.blocks = self.pool.blocks
            else:
                self.pool = SlotPool(model, ecfg.max_batch, ecfg.max_len)
            # cache donated in both phases: XLA writes KV in place instead
            # of copying the whole cache pytree every generated token. The
            # jit wrappers live on the model so that repeated Engine
            # constructions over the same model (benchmark sweeps, repeated
            # run_policy calls) reuse the compiled executables instead of
            # recompiling every phase per engine.
            jit_cache = getattr(model, "_engine_jit_cache", None)
            if jit_cache is None:
                jit_cache = model._engine_jit_cache = {
                    "decode_multi": donating_jit(
                        model.decode_multi,
                        static_argnames=("k", "eos_id")),
                    "prefill_chunk": donating_jit(model.prefill_chunk),
                }
            self._decode_fn = jit_cache["decode_multi"]
            self._prefill_fn = jit_cache["prefill_chunk"]
        elif self.paged:
            # sim mode: unbounded id space — capacity pressure is enforced
            # in bytes against mem_budget by the reclamation loop. The
            # warm prefix pool is itself capped at budget-equivalent
            # pages (or a large fixed cap under an effectively unlimited
            # budget) so index/LRU bookkeeping cannot grow with every
            # unique prompt ever served; admission charges hits at full
            # bytes, so used pages stay budget-bounded independently.
            cap = None
            if self.prefix_cache:
                cap = (ecfg.mem_budget // max(self._page_bytes, 1)
                       if ecfg.mem_budget < (1 << 60) else 1 << 20)
            self.blocks = BlockManager(0, ecfg.page_size,
                                       prefix_cache=self.prefix_cache,
                                       reusable_cap=cap)
        self._rng = np.random.default_rng(ecfg.seed)
        self._token_rate = None     # lazy decode_token_rate() cache
        self.alive = True           # cleared by crash(); router health
        self._slowdown = 1.0        # straggler time-dilation factor
        # resilience fast-path gate: the deadline scan only runs when a
        # deadline is actually configured (engine default or any
        # submitted request), so default runs pay nothing
        self._deadlines = ecfg.deadline_s > 0 or ecfg.ttft_deadline_s > 0
        self._reset_stream()

    def _reset_stream(self):
        """(Re)initialize the incremental-loop state.

        Empty request pool, clock at zero, fresh stats. Called by
        ``__init__`` and ``run()``.
        """
        self.stats = EngineStats()
        self._pending: list[Request] = []       # sorted by arrival
        self._p_idx = 0                         # next pending to admit
        self._subs: dict[int, object] = {}      # rid -> on_token callback
        self._pool_reqs: dict[int, Request] = {}
        self._entries: dict[int, SchedEntry] = {}
        self._now = 0.0
        self._r0_sum = 0.0                      # running mean of initial
        self._r0_cnt = 0                        # predictions (backlog prior)
        self._prefix_hint: dict[int, int] = {}  # rid -> prospective hit
        self._hint_gen: dict[int, int] = {}     # index_gen the hint saw
        self._parked: set[int] = set()          # prefill-complete rids
                                                # awaiting KV handoff
        self._last_mem = 0                      # bytes at last step end
        self._wall0 = time.perf_counter()
        if self.events is not None:
            self.events.clear()

    def _bytes_for(self, context_len: int) -> int:
        if self.paged:
            return paged_bytes_for_context(self.cfg, context_len,
                                           self.ecfg.page_size)
        return bytes_for_context(self.cfg, context_len)

    def _match_tokens(self, req) -> list[int]:
        """Prompt tokens eligible for prefix matching.

        Everything except the final token, which decode always consumes
        fresh — so a full hit still leaves the request one decode step of
        work and shared pages are never written by the sharer.
        """
        return req.prompt[:max(len(req.prompt) - 1, 0)]

    def _sync_prefill_left(self, req, hint: int = 0):
        """Refresh the entry's rank-visible remaining prefill work.

        Prefix-cache mode only: what is still uncached and unprefilled.
        ``hint`` discounts a WAITING request's prospective cache hit.
        """
        req.entry.prefill_left = float(max(
            req.context_len - 1 - req.entry.prefill_done - hint, 0))

    def kv_headroom(self) -> float:
        """Free fraction of the KV capacity (1.0 = empty, 0.0 = full).

        Real-mode paged pools report the free-page fraction of the
        physical pool (`BlockManager.free_pages()`); sim-mode engines
        report the unused fraction of ``mem_budget`` as of the last step
        (1.0 under an effectively unlimited budget).
        """
        if self.blocks is not None and self.blocks.bounded:
            return self.blocks.free_pages() / max(self.blocks.num_pages, 1)
        budget = self.ecfg.mem_budget
        if budget >= (1 << 60):
            return 1.0
        return max(0.0, 1.0 - self._last_mem / budget)

    # ------------------------------------------------------------------
    # incremental API: submit / step / accessors
    # ------------------------------------------------------------------
    @property
    def now(self) -> float:
        """The engine's virtual clock (seconds; sim-clock in sim mode)."""
        return self._now

    def has_work(self) -> bool:
        """True while any submitted request has not yet finished."""
        return self._p_idx < len(self._pending) or any(
            e.state is not ReqState.FINISHED for e in self._entries.values())

    def queue_len(self) -> int:
        """Number of unfinished requests known to this engine.

        Counts admitted-but-unfinished requests plus submitted arrivals
        not yet admitted — the join-shortest-queue routing signal.
        """
        n = sum(1 for e in self._entries.values()
                if e.state is not ReqState.FINISHED)
        return n + (len(self._pending) - self._p_idx)

    def backlog(self, truncate: float | None = None,
                include_pending: bool = True) -> float:
        """Predicted remaining work, in tokens, across unfinished requests.

        For admitted requests this is the live TRAIL prediction
        (``pred_remaining``, refined every probe boundary) plus the
        remaining prefill tokens. Submitted-but-unadmitted arrivals have
        no probe output yet, so they are charged their prompt length plus
        a workload-adaptive prior: the running mean of the initial
        predictions seen so far (falling back to ``max_len / 2``, the same
        uninformative prior `ProbePredictor.initial` uses). A fixed large
        prior would swamp the live-prediction signal during bursts and
        collapse join-shortest-predicted-work into round-robin.

        Args:
            truncate: if given, each job's predicted remaining tokens are
                clipped to this value before summing. With SPRPT inside
                every replica, the work a new job actually waits behind is
                the work *shorter than itself* — longer jobs yield to it —
                so the router truncates at the incoming job's own size
                estimate (SRPT-interfering work) instead of summing raw
                backlog, which is the right signal only for FCFS replicas.
                Under rank aging (``age_boost`` > 0) a queued job j also
                interferes once its aged rank
                ``r_j - boost*max(waited_j - age_delay_s, 0)`` beats the
                arrival's ``truncate``, so each admitted job's clip rises
                by that same hinge term — at boost=0 this is exactly the
                legacy cap.
            include_pending: charge submitted-but-unadmitted arrivals
                too (the default). The shedding/admission-control paths
                pass False — overload decisions at time t must not count
                work that has not arrived yet.
        """
        cap = float("inf") if truncate is None else truncate
        boost = self.ecfg.age_boost
        prior = (self._r0_sum / self._r0_cnt if self._r0_cnt
                 else self.predictor.pc.max_len / 2.0)
        tot = 0.0
        for rid, e in self._entries.items():
            if e.state is ReqState.FINISHED:
                continue
            req = self._pool_reqs[rid]
            cap_e = cap
            if boost > 0.0 and truncate is not None:
                cap_e = cap + boost * max(
                    self._now - e.arrival - self.ecfg.age_delay_s, 0.0)
            if self._magnitude:
                tot += min(max(e.pred_remaining, 0.0), cap_e)
            else:
                # rank-only: scores are not token counts — charge the
                # uninformative prior, decayed by tokens already served
                tot += min(max(prior - e.age, 0.0), cap_e)
            hint = (self._prefix_hint.get(rid, 0)
                    if self.prefix_cache and e.state is ReqState.WAITING
                    else 0)
            tot += max(req.context_len - 1 - e.prefill_done - hint, 0)
        if include_pending:
            for req in self._pending[self._p_idx:]:
                tot += len(req.prompt) + min(prior, cap)
        return tot

    def backlog_seconds(self, truncate: float | None = None) -> float:
        """`backlog()` normalized into estimated seconds of replica work.

        Predicted remaining tokens divide by this replica's decode rate
        (`CostModel.decode_token_rate`, a function of its `HardwareSpec`)
        — the unit the router needs once replicas stop being identical:
        5k tokens queued on a 2x-faster replica is *less* wait, which a
        token-count comparison cannot see. ``truncate`` stays in tokens
        (the arrival's size estimate), applied before conversion. With
        identical replicas the conversion is one shared positive scale,
        so `jspw` dispatch decisions are unchanged — the router's
        ``backlog_unit="seconds"`` flag relies on exactly that.
        """
        rate = self._token_rate
        if rate is None:
            rate = self._token_rate = self.cost.decode_token_rate()
        return self.backlog(truncate=truncate) / rate

    def cached_prefix_tokens(self, prompt) -> int:
        """Longest prompt prefix (tokens) resident in the prefix cache.

        The router's ``prefix-affinity`` signal. Zero when prefix caching
        is off. Pure lookup: no refcounts or LRU moves.
        """
        if not self.prefix_cache:
            return 0
        return self.blocks.match_len(prompt[:max(len(prompt) - 1, 0)])

    def submit(self, req: Request):
        """Enqueue one arrival, admitted once the clock reaches it.

        Arrivals may be submitted in any order, but never
        earlier than an already-admitted arrival (the router's virtual-time
        frontier guarantees this).
        """
        if req.deadline_s > 0 or req.ttft_deadline_s > 0:
            self._deadlines = True
        i = bisect.bisect_right(self._pending, req.arrival,
                                lo=self._p_idx, key=lambda r: r.arrival)
        self._pending.insert(i, req)

    def on_token(self, rid: int, cb) -> None:
        """Subscribe a per-request streaming callback.

        ``cb(t, kind, value)`` fires synchronously from inside ``step()``
        (or ``cancel()``) whenever request ``rid`` emits ``first_token``,
        ``tokens`` (value = tokens this megastep), ``finish``, or a
        terminal cancel kind (``cancel`` / ``timeout`` / ``shed``), in
        emission order. This is the O(1) hook the serving front door uses
        instead of re-scanning ``StepResult.events`` every megastep. One
        callback per rid — a second call replaces the first — and the
        subscription is dropped automatically after a terminal kind
        (detach earlier with :meth:`off_token`). Works with or without an
        attached EventLog; engines with no subscribers skip the dispatch
        entirely, so default runs are unchanged.
        """
        self._subs[rid] = cb

    def off_token(self, rid: int) -> None:
        """Drop the :meth:`on_token` callback for ``rid`` (idempotent)."""
        self._subs.pop(rid, None)

    def _notify(self, t: float, rid: int, kind: str, value: float = 0.0):
        """Dispatch one stream event to the rid's subscriber, if any.

        Terminal kinds (``finish`` and the cancel kinds) auto-unsubscribe
        before the callback runs, so a raising callback cannot leak its
        subscription and a terminal event is delivered at most once.
        """
        cb = self._subs.get(rid)
        if cb is None:
            return
        if kind in ("finish", "cancel", "timeout", "shed"):
            del self._subs[rid]
        cb(t, kind, value)

    def _admit_arrivals(self, t: float):
        ecfg = self.ecfg
        gate = ecfg.admission_control and ecfg.shed_watermark > 0.0
        while (self._p_idx < len(self._pending)
               and self._pending[self._p_idx].arrival <= t):
            req = self._pending[self._p_idx]
            if (gate and self.backlog(include_pending=False)
                    > ecfg.shed_watermark):
                # admission control: the door is shut while live backlog
                # exceeds the watermark — the arrival is observed, then
                # immediately shed (never enters pool or scheduler)
                self._p_idx += 1
                req.entry.state = ReqState.CANCELLED
                req.cancel_reason = "shed"
                self.stats.n_cancelled += 1
                self.stats.n_shed += 1
                if self.events is not None:
                    self.events.emit(req.arrival, req.rid, "arrival")
                    self.events.emit(max(t, req.arrival), req.rid, "shed")
                if self._subs:
                    self._notify(max(t, req.arrival), req.rid, "shed")
                continue
            r0 = self.predictor.initial(req)
            req.entry.r0 = r0
            req.entry.pred_remaining = r0
            req.entry.c_limit = ecfg.c_limit
            req.entry.finish_len = req.true_out_len
            dl = req.deadline_s or ecfg.deadline_s
            if dl > 0:
                # absolute deadline on the engine clock: feeds both the
                # expiry scan and the deadline-slack non-preemption rule
                req.entry.deadline_at = req.arrival + dl
            if self._magnitude:
                # ordinal scores must not pollute the token-count prior
                self._r0_sum += r0
                self._r0_cnt += 1
            if self.prefix_cache:
                # prospective hit: lets the scheduler's ranks and the
                # backlog signal see the cached prefix before admission
                hint = self.blocks.match_len(self._match_tokens(req))
                self._prefix_hint[req.rid] = hint
                self._hint_gen[req.rid] = self.blocks.index_gen
                self._sync_prefill_left(req, hint)
            self._pool_reqs[req.rid] = req
            self._entries[req.rid] = req.entry
            self._p_idx += 1
            if self.events is not None:
                self.events.emit(req.arrival, req.rid, "arrival")

    def step(self) -> StepResult:
        """Execute one engine iteration (one megastep) and return it.

        Admits due arrivals, consults the scheduler once, runs one prefill
        chunk + one decode megastep, and advances the clock. If no request
        is live the clock jumps to the next pending arrival (an idle step,
        ``ran=False``); a drained engine returns immediately.
        """
        ecfg = self.ecfg
        stats = self.stats
        pool_reqs = self._pool_reqs
        entries = self._entries
        now = self._now
        ev = self.events
        ev_mark = len(ev) if ev is not None else 0

        self._admit_arrivals(now)
        # resilience checks run at megastep boundaries, before the
        # scheduler sees the pool; both are gated so the default engine
        # (no deadlines, no watermark) takes neither branch
        if self._deadlines:
            self._expire_deadlines(now)
        if ecfg.shed_watermark > 0.0:
            self._shed_overload()
        if ecfg.prefill_only:
            # disaggregated-prefill role: a request whose prefill is
            # complete parks for KV handoff instead of decoding. Parking
            # is not a preemption (no stats/events) — the request simply
            # leaves the schedulable set with its pages retained, where
            # it stays evictable under memory pressure until the router
            # exports it.
            for r in pool_reqs.values():
                if (not r.done and r.rid not in self._parked
                        and r.entry.prefill_done >= r.context_len - 1):
                    if r.entry.state is ReqState.RUNNING:
                        self._suspend(r)
                    self._parked.add(r.rid)
        live = [r for r in pool_reqs.values()
                if not r.done and r.rid not in self._parked]
        if not live:
            if self._p_idx < len(self._pending):
                # idle: jump to next arrival
                self._now = self._pending[self._p_idx].arrival
            return StepResult(now=self._now, backlog_fn=self.backlog,
                              kv_headroom=self.kv_headroom(),
                              events=ev.events[ev_mark:] if ev is not None
                              else ())

        # admission charges each candidate's bytes at the END of the
        # upcoming megastep (context + k), so a k-token megastep can
        # never outgrow the budget mid-flight. A prefix-cache hit is NOT
        # discounted here: linking flips warm (refcount-zero) pages into
        # used pages, so the budget must cover them or resident memory
        # could exceed what the mirrored physical pool holds. The cached
        # win is charged where it belongs — zero prefill compute
        # (costmodel) and a smaller remaining-work rank (prefill_left) —
        # while the *memory* saving of sharing shows up in the
        # unique-page accounting (shared pages counted once).
        sched_entries = entries
        if self._parked:
            sched_entries = {rid: e for rid, e in entries.items()
                             if rid not in self._parked}
        decision = select_batch(
            sched_entries, policy=ecfg.policy, max_batch=ecfg.max_batch,
            mem_budget=ecfg.mem_budget,
            bytes_fn=lambda e: self._bytes_for(
                pool_reqs[e.rid].context_len + self._k),
            lookahead=self._k, now=now, age_boost=ecfg.age_boost,
            age_delay=ecfg.age_delay_s,
            deadline_slack=ecfg.deadline_slack_s)

        self._apply_preemptions(decision, pool_reqs, stats)
        if self.paged:
            # page-granular memory pressure: suspended (preempted but
            # resident) pages yield before any admitted request starts
            self._reclaim_pages(decision, pool_reqs, entries, stats)
        self._apply_admissions(decision, pool_reqs, stats)

        # Prefill covers context_len - 1 tokens; the final known token is
        # always consumed by decode_step (which emits the next one). This
        # keeps fresh and preemption-resumed requests on one code path.
        sched = [pool_reqs[rid] for rid in decision.scheduled]
        prefilling = [r for r in sched
                      if r.entry.prefill_done < r.context_len - 1]
        decoding = [r for r in sched
                    if r.entry.prefill_done >= r.context_len - 1]

        if not sched:
            if self._p_idx < len(self._pending):
                self._now = max(now, self._pending[self._p_idx].arrival)
                return StepResult(now=self._now, backlog_fn=self.backlog,
                                  kv_headroom=self.kv_headroom(),
                                  events=ev.events[ev_mark:]
                                  if ev is not None else ())
            raise RuntimeError(
                "scheduler deadlock: nothing fits the memory budget")
        stats.peak_batch = max(stats.peak_batch, len(sched))

        # ---- chunked prefill (shared token budget, rank order) --------
        budget = ecfg.prefill_chunk
        pf_plan: list[tuple[Request, int]] = []
        for r in prefilling:
            if budget <= 0:
                break
            todo = (r.context_len - 1) - r.entry.prefill_done
            take = min(todo, budget)
            pf_plan.append((r, take))
            budget -= take

        if self.paged:
            # allocate pages ahead of the writes this iteration performs
            # (decode rows pre-reserve their whole megastep budget: the
            # block table is frozen while the k steps run on device)
            for r, take in pf_plan:
                self._ensure_pages(r, r.entry.prefill_done + take, entries)
            for r in decoding:
                self._ensure_pages(
                    r, r.context_len + self._row_budget(r) - 1, entries)
        if self.prefix_cache:
            # COW guard: any shared page covering a position about to be
            # written is replaced by a private copy first (a no-op in the
            # standard flow — shared pages are full and writes land past
            # them — but it makes the immutability invariant enforced)
            make_writable = (self.pool.make_writable if self.pool is not None
                             else self.blocks.make_writable)
            for r, _take in pf_plan:
                make_writable(r.rid, r.entry.prefill_done)
            for r in decoding:
                make_writable(r.rid, max(r.context_len - 1, 0))

        # capture per-row decode contexts before tokens are appended:
        # the cost model charges context c+1..c+n for a row emitting n
        dec_ctxs = [r.context_len + 1 for r in decoding]
        if ecfg.mode == "real":
            emitted = self._device_step(pf_plan, decoding)
        else:
            emitted = self._sim_step(pf_plan, decoding)

        # ---- bookkeeping / clock -------------------------------------
        pf_tokens = sum(t for _, t in pf_plan)
        pf_ctx = max((r.context_len for r, _ in pf_plan), default=0)
        dt = self.cost.megastep_time(
            dec_ctxs, [emitted.get(r.rid, 0) for r in decoding],
            pf_tokens, pf_ctx)
        dt += self._swap_pending_s              # DMA stalls the batch
        self._swap_pending_s = 0.0
        # externally-priced predictor work (BERT-sized prompt models,
        # ELIS proxy re-predictions) charged this step stalls the clock;
        # zero-flop strategies (recycled probe, analysis oracles) add
        # exactly 0.0 — legacy results stay byte-identical
        pred_flops = self.predictor.take_cost_flops()
        if pred_flops:
            pred_s = self.cost.predictor_time(pred_flops)
            dt += pred_s
            stats.predictor_time_s += pred_s
        stats.predictor_calls = self.predictor.cost_calls
        if self._slowdown != 1.0:
            # straggler fault injection: the replica's hardware runs
            # slower, dilating the whole megastep (compute, DMA stalls,
            # predictor work). 1.0 — the default — leaves the clock
            # byte-identical to the pre-resilience engine.
            dt *= self._slowdown
        now_next = now + dt
        completed: list[Request] = []
        for r, take in pf_plan:
            r.entry.prefill_done += take
            # tokens actually materialized in the cache (never credited
            # past what was written: a mid-prefill preemption must not
            # mark unwritten positions as retained). The prefilled_tokens
            # stat counts only the newly materialized portion: a decoded
            # row re-enters the prefill classification to catch
            # prefill_done up to its grown context, but those positions
            # were already KV-written by decode and are not fresh prefill
            # work.
            kv_before = getattr(r, "_kv_written", 0)
            stats.prefilled_tokens += max(r.entry.prefill_done - kv_before, 0)
            r._kv_written = max(kv_before, r.entry.prefill_done)
        for r in decoding:
            n = emitted.get(r.rid, 0)
            r._kv_written = max(getattr(r, "_kv_written", 0),
                                r.context_len - 1)
            r.entry.age += n
            if r.first_token_time < 0 and n > 0:
                r.first_token_time = now_next
                if ev is not None:
                    ev.emit(now_next, r.rid, "first_token")
                if self._subs:
                    self._notify(now_next, r.rid, "first_token")
            if ev is not None and n > 0:
                ev.emit(now_next, r.rid, "tokens", n)
            if self._subs and n > 0:
                self._notify(now_next, r.rid, "tokens", float(n))
            if (len(r.generated) >= r.true_out_len
                    or len(r.generated) >= r.max_new_tokens):
                r.entry.state = ReqState.FINISHED
                r.finish_time = now_next
                stats.latencies.append(r.latency())
                stats.ttfts.append(r.ttft())
                completed.append(r)
                if ev is not None:
                    ev.emit(now_next, r.rid, "finish")
                if self._subs:
                    self._notify(now_next, r.rid, "finish")
                if self.prefix_cache:
                    # publish the finished request's prompt pages before
                    # release parks them in the reusable pool
                    self._register_prompt(r)
                if self.pool is not None:
                    self.pool.release(r.rid)
                elif r.slot >= 0:
                    r.slot = -1
                if self.blocks is not None and self.pool is None:
                    # sim mode only: real-mode release() freed the pages
                    self.blocks.free_request(r.rid)

        if self.blocks is not None:
            for rid in decision.scheduled:
                r = pool_reqs[rid]
                if not r.done:
                    self.blocks.note_cached(
                        rid, getattr(r, "_kv_written", 0))
                    if self.prefix_cache:
                        self._register_prompt(r)
        if self.prefix_cache:
            gen = self.blocks.index_gen
            for r in pool_reqs.values():
                if r.done:
                    continue
                if r.entry.state is ReqState.WAITING:
                    # refresh the prospective hit only when the index
                    # actually changed (generation-gated): match_prefix
                    # is O(prompt pages) and a long WAITING queue would
                    # otherwise pay it every step for nothing
                    if self._hint_gen.get(r.rid) != gen:
                        self._prefix_hint[r.rid] = self.blocks.match_len(
                            self._match_tokens(r))
                        self._hint_gen[r.rid] = gen
                    self._sync_prefill_left(
                        r, self._prefix_hint.get(r.rid, 0))
                else:
                    self._sync_prefill_left(r)

        if self.prefix_cache:
            # page-accurate under sharing: each physical page counts once
            # however many block tables reference it, and reusable cache
            # pages (refcount zero) are reclaimable, hence free
            mem = self.cost.resident_page_bytes(self.blocks.used_pages())
        else:
            mem = sum(self._bytes_for(pool_reqs[rid].context_len)
                      for rid in decision.scheduled)
            if self.blocks is not None:
                mem += self._page_bytes * sum(
                    self.blocks.resident_pages(e.rid)
                    for e in entries.values()
                    if e.state is ReqState.PREEMPTED)
        stats.peak_mem_bytes = max(stats.peak_mem_bytes, mem)
        self._last_mem = mem
        stats.iterations += 1
        self._now = now_next
        stats.sim_time = (self._now if ecfg.mode == "sim"
                          else time.perf_counter() - self._wall0)
        return StepResult(completed=completed, now=self._now,
                          backlog_fn=self.backlog, ran=True,
                          kv_headroom=self.kv_headroom(),
                          events=ev.events[ev_mark:] if ev is not None
                          else ())

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> EngineStats:
        """Drive a whole request trace to completion (the batch API).

        Reimplemented on top of ``submit()``/``step()``: results are
        byte-identical to the original monolithic loop. Resets any prior
        incremental state — an engine is either batch- or step-driven.
        """
        if self.ecfg.prefill_only:
            raise ValueError(
                "prefill_only engines never decode, so run() cannot "
                "drain: drive them incrementally (submit/step + "
                "export_request), e.g. via run_cluster(prefill_replicas=N)")
        self._reset_stream()
        for req in sorted(requests, key=lambda r: r.arrival):
            self.submit(req)
        while self.has_work():
            self.step()
        stats = self.stats
        stats.sim_time = (self._now if self.ecfg.mode == "sim"
                          else time.perf_counter() - self._wall0)
        return stats

    # ------------------------------------------------------------------
    # resilience: cancellation, deadlines, load shedding, fault hooks
    # ------------------------------------------------------------------
    def cancel(self, rid: int, reason: str = "cancel") -> bool:
        """Cancel one request in any state; returns True if it was live.

        Works for WAITING / RUNNING / PREEMPTED (suspended) requests, in
        prefill or decode, and for submitted-but-unadmitted arrivals.
        The KV footprint is released through the normal refcount paths:
        shared prefix pages are deregistered (they stay with their other
        owners or park warm in the reusable pool), host-swapped pages
        are reclaimed, and real-mode slots/pages queue device resets.
        The entry leaves scheduler state and backlog accounting
        entirely, so a cancelled request can never be scheduled again.

        Args:
            rid: the request id.
            reason: ``cancel`` (explicit) | ``timeout`` (deadline
                expiry) | ``shed`` (load shedding) — doubles as the
                emitted event kind.

        Returns:
            True if the request existed and was still unfinished; False
            for unknown, already-finished, or already-cancelled rids
            (cancellation is idempotent).
        """
        if reason not in ("cancel", "timeout", "shed"):
            raise ValueError(f"unknown cancel reason {reason!r}")
        # still queued behind the arrival frontier? (submitted, unadmitted)
        for i in range(self._p_idx, len(self._pending)):
            if self._pending[i].rid == rid:
                req = self._pending.pop(i)
                req.entry.state = ReqState.CANCELLED
                req.cancel_reason = reason
                self._book_cancel(reason)
                if self.events is not None:
                    # the arrival was never admitted, so its arrival
                    # event is emitted here — goodput counts it
                    self.events.emit(req.arrival, rid, "arrival")
                    self.events.emit(max(self._now, req.arrival), rid,
                                     reason)
                if self._subs:
                    self._notify(max(self._now, req.arrival), rid, reason)
                return True
        req = self._pool_reqs.get(rid)
        if req is None or req.done:
            return False
        # release the KV footprint through the standard machinery
        if self.pool is not None:
            if rid in self.pool.slot_of:        # RUNNING on a device slot
                if self.paged:
                    self.pool.release(rid, retain=False)
                else:
                    self.pool.release(rid)
            elif self.paged:
                # suspended: retained/host pages but no slot — free via
                # the block manager (resets queue for flush_resets)
                self.blocks.free_request(rid)
        elif self.blocks is not None:           # sim-mode paged
            self.blocks.free_request(rid)
        req.slot = -1
        req._swapped = False                    # host copy abandoned
        req._kv_written = 0
        self._prefix_hint.pop(rid, None)
        self._hint_gen.pop(rid, None)
        req.entry.state = ReqState.CANCELLED
        req.cancel_reason = reason
        # out of scheduler state and backlog/queue accounting
        self._parked.discard(rid)
        del self._entries[rid]
        del self._pool_reqs[rid]
        self._book_cancel(reason)
        if self.events is not None:
            self.events.emit(self._now, rid, reason)
        if self._subs:
            self._notify(self._now, rid, reason)
        return True

    def _book_cancel(self, reason: str):
        self.stats.n_cancelled += 1
        if reason == "timeout":
            self.stats.n_timeouts += 1
        elif reason == "shed":
            self.stats.n_shed += 1

    def _expire_deadlines(self, now: float):
        """Cancel requests whose completion/TTFT budget has expired.

        Runs at megastep boundaries on the engine clock (a deadline that
        expires mid-megastep is enforced at the next boundary). A
        request-level deadline overrides the engine default; 0 = none.
        """
        ecfg = self.ecfg
        for rid in [r.rid for r in self._pool_reqs.values() if not r.done]:
            req = self._pool_reqs[rid]
            dl = req.deadline_s or ecfg.deadline_s
            if dl > 0 and now - req.arrival > dl:
                self.cancel(rid, reason="timeout")
                continue
            tdl = req.ttft_deadline_s or ecfg.ttft_deadline_s
            if (tdl > 0 and req.first_token_time < 0
                    and now - req.arrival > tdl):
                self.cancel(rid, reason="timeout")

    def _shed_overload(self):
        """Shed worst-ranked WAITING requests while over the watermark.

        Shedding cancels with reason ``shed`` until the predicted backlog
        fits again.

        Only never-started requests are shed — dropping RUNNING or
        suspended work would discard compute already spent. The victim
        order is the scheduler's own rank, worst first (latest arrival
        breaks ties), so with a magnitude predictor the longest
        predicted jobs go first — exactly the jobs SRPT would have
        served last anyway. Rank aging folds in here too: a long job
        that has already waited out most of its starvation bound ranks
        better than a fresh one, so shedding under ``age_boost`` > 0
        prefers the newest long work over the most-starved.
        """
        wm = self.ecfg.shed_watermark
        policy = self.ecfg.policy
        boost = self.ecfg.age_boost
        while self.backlog(include_pending=False) > wm:
            waiting = [e for e in self._entries.values()
                       if e.state is ReqState.WAITING]
            if not waiting:
                break           # backlog is all in-flight work: keep it
            victim = max(waiting,
                         key=lambda e: (e.rank(policy, now=self._now,
                                               age_boost=boost,
                                               age_delay=self.ecfg
                                               .age_delay_s),
                                        e.arrival))
            self.cancel(victim.rid, reason="shed")

    def crash(self, t: float | None = None) -> list[Request]:
        """Kill this replica: reclaim every page/slot, drop all state.

        Models a replica failure for the router's fault injection. All
        unfinished requests (admitted and still-pending) are returned so
        the router can redispatch them to survivors; the entire KV
        footprint is reclaimed through the standard release paths (the
        BlockManager ends with ``used_pages() == 0`` — the zero-leak
        invariant the resilience benchmark enforces). No per-request
        events are emitted here — the router records ``replica_down``
        and per-request ``retry`` events.

        Args:
            t: fault time; the clock advances to it if ahead (events the
               replica already emitted stay in its past).

        Returns:
            The unfinished `Request` objects, in arrival order.
        """
        if t is not None:
            self._now = max(self._now, t)
        lost = [r for r in self._pool_reqs.values() if not r.done]
        lost += self._pending[self._p_idx:]
        if self.pool is not None:
            for rid in list(self.pool.slot_of):
                if self.paged:
                    self.pool.release(rid, retain=False)
                else:
                    self.pool.release(rid)
        if self.blocks is not None:
            for rid in list(self.blocks.pages):
                self.blocks.free_request(rid)
            for rid in list(self.blocks.host_pages):
                self.blocks.free_request(rid)
        for r in lost:
            r.slot = -1
            r._swapped = False
            r._kv_written = 0
            r._reg_pages = 0
        self._pending = []
        self._p_idx = 0
        self._pool_reqs = {}
        self._entries = {}
        self._prefix_hint = {}
        self._hint_gen = {}
        self._parked = set()
        self.alive = False
        return sorted(lost, key=lambda r: r.arrival)

    def revive(self, t: float):
        """Bring a crashed replica back (empty) at time ``t``."""
        self.alive = True
        self._now = max(self._now, t)

    def set_slowdown(self, factor: float):
        """Set the straggler time-dilation factor (1.0 = healthy)."""
        if factor <= 0:
            raise ValueError(f"slowdown factor must be positive: {factor}")
        self._slowdown = factor

    # ------------------------------------------------------------------
    # disaggregation: KV handoff export/import (doubles as suspended-
    # request migration between any two paged engines)
    # ------------------------------------------------------------------
    def _suspend(self, req: Request):
        """Take a RUNNING request off its slot for parking/export.

        The handoff/migration twin of the scheduler's preemption path,
        minus the preemption bookkeeping (no preempt event, no ``n_preemptions``: parking a
        finished prefill is not a scheduling decision). Page-retention
        archs keep the KV resident; everything else discards it (the
        destination re-prefills).
        """
        rid = req.rid
        req.entry.state = ReqState.PREEMPTED
        if self._retain:
            cached = getattr(req, "_kv_written", 0)
            if self.pool is not None:   # real pool is max_len-bounded
                cached = min(cached, self.ecfg.max_len)
            self.blocks.ensure(rid, cached)
            self.blocks.note_cached(rid, cached)
        else:
            req.entry.prefill_done = 0
            req._kv_written = 0
            if self.blocks is not None and self.pool is None:
                self.blocks.free_request(rid)
        if self.pool is not None:
            if self.paged:
                self.pool.release(rid, retain=self._retain)
            else:
                self.pool.release(rid)
        req.slot = -1

    def handoff_ready(self) -> list[int]:
        """Rids parked for export, oldest arrival first.

        Parked means prefill complete on a ``prefill_only`` engine, slot
        released, pages retained. Always empty on non-disaggregated
        engines.
        """
        return sorted(self._parked,
                      key=lambda rid: (self._entries[rid].arrival, rid))

    def export_request(self, rid: int) -> KVHandoff:
        """Detach one unfinished request for migration to another engine.

        Valid in any live state (WAITING / RUNNING / PREEMPTED —
        RUNNING requests are suspended first), so it serves both the
        disaggregation handoff and generic suspended-request migration.
        On a page-retention engine the materialized KV prefix ships:
        sim mode ships the descriptor only, real mode additionally
        gathers the page payload in one batched device->host copy.
        The source side then releases everything through the standard
        refcount paths — shared prefix pages stay with their other
        owners, and a drained source ends with ``used_pages() == 0``
        (the zero-leak invariant the disagg benchmark gates on).

        Returns the `KVHandoff`; the request is gone from this engine.
        """
        req = self._pool_reqs.get(rid)
        if req is None or req.done:
            raise ValueError(f"rid {rid} is not exportable")
        if req.entry.state is ReqState.RUNNING:
            self._suspend(req)
        kv_tokens = n_pages = 0
        payload = None
        if self.blocks is not None and self._retain:
            # real mode ships only device-resident pages (host-swapped
            # tails have no gatherable payload); sim descriptors cover
            # the whole cached prefix, host pages included
            cached = (self.blocks.resident_tokens(rid)
                      if self.pool is not None
                      else self.blocks.cached_tokens.get(rid, 0))
            kv_tokens = min(cached, max(req.context_len - 1, 0))
            if self.pool is not None and kv_tokens > 0:
                payload = self.pool.export_pages(rid)
            snap = self.blocks.export_request(rid)
            if kv_tokens > 0:
                n_pages = snap["resident_pages"] + snap["host_pages"]
        elif self.blocks is not None:
            self.blocks.free_request(rid)
        req.slot = -1
        req._swapped = False
        self._parked.discard(rid)
        self._prefix_hint.pop(rid, None)
        self._hint_gen.pop(rid, None)
        del self._entries[rid]
        del self._pool_reqs[rid]
        if self.events is not None:
            self.events.emit(self._now, rid, "handoff", n_pages)
        pred = req.entry.pred_remaining if self._magnitude else None
        return KVHandoff(req=req, kv_tokens=kv_tokens, n_pages=n_pages,
                         nbytes=n_pages * self._page_bytes,
                         payload=payload, pred_tokens=pred,
                         src_now=self._now)

    def import_request(self, handoff: KVHandoff,
                       t: float | None = None) -> int:
        """Adopt a migrated request; returns the KV tokens resumed.

        The request enters the pool directly (its arrival is in the
        past by construction — the transfer only ever delays it), with
        arrival, first_token_time, generated tokens and prediction
        state preserved. Shipped KV lands as retained pages, so the
        normal copy-on-admit resume path re-links it at the next
        scheduling point with zero recompute; if the pool cannot hold
        the import (or the engine cannot retain pages) the request
        falls back to WAITING and re-prefills from scratch — correct
        either way, since greedy decode over re-computed KV is
        byte-identical.

        Args:
            handoff: the package from `export_request`.
            t: availability time on this engine's clock (dispatch time
                plus `CostModel.kv_transfer_time`); the clock advances
                to it if behind.
        """
        req = handoff.req
        rid = req.rid
        if rid in self._pool_reqs or rid in self._entries:
            raise ValueError(f"rid {rid} already present on this engine")
        if t is not None:
            self._now = max(self._now, t)
        entry = req.entry
        kv = 0
        if handoff.kv_tokens > 0 and self.blocks is not None and self._retain:
            want = min(handoff.kv_tokens, max(req.context_len - 1, 0))
            if self.pool is not None:
                if self.pool.import_pages(rid, min(want, self.ecfg.max_len),
                                          handoff.payload):
                    kv = min(want, self.ecfg.max_len)
            elif self.blocks.import_request(rid, want):
                kv = want
        entry.state = ReqState.PREEMPTED if kv > 0 else ReqState.WAITING
        entry.prefill_done = min(entry.prefill_done, kv)
        entry.c_limit = self.ecfg.c_limit
        entry.prefill_left = 0.0    # rank-visible only under prefix_cache
        if self.prefix_cache:
            self._sync_prefill_left(req)
        req._kv_written = kv
        req._swapped = False
        req.slot = -1
        if self._magnitude and handoff.pred_tokens is not None:
            # fold the migrant's prediction into the backlog prior, as
            # admission would have
            self._r0_sum += entry.r0
            self._r0_cnt += 1
        if (entry.deadline_at > 0 or req.ttft_deadline_s > 0
                or self.ecfg.ttft_deadline_s > 0):
            self._deadlines = True
        self._pool_reqs[rid] = req
        self._entries[rid] = entry
        return kv

    # ------------------------------------------------------------------
    def _apply_preemptions(self, decision: Decision, pool_reqs, stats):
        for rid in decision.preempted:
            req = pool_reqs[rid]
            req.entry.state = ReqState.PREEMPTED
            req.entry.preemptions += 1
            stats.n_preemptions += 1
            if self.events is not None:
                self.events.emit(self._now, rid, "preempt",
                                 req.entry.preemptions)
            if self._retain:
                # paged: pages stay resident ("suspended"); the reclamation
                # loop evicts/swaps them tail-first only under real memory
                # pressure, and resume accounting charges exactly the
                # evicted tokens. No recompute is booked here.
                cached = getattr(req, "_kv_written", 0)
                if self.pool is not None:       # real pool is max_len-bounded
                    cached = min(cached, self.ecfg.max_len)
                self.blocks.ensure(rid, cached)
                self.blocks.note_cached(rid, cached)
            elif self.ecfg.oom_mode == "swap":
                # KV moves to host; prefill progress is kept but the
                # DMA stalls the whole batch (paper Section 3.3 discussion)
                nbytes = self._bytes_for(req.context_len)
                stats.swapped_bytes += nbytes
                self._swap_pending_s += nbytes / self.ecfg.hardware.dma_bw
                req._swapped = True
                if self.events is not None:
                    self.events.emit(self._now, rid, "swap", nbytes)
                if self.blocks is not None:
                    # the whole cache is on host now; its device pages are
                    # free (swap-in is charged once at re-admission)
                    self.blocks.free_request(rid)
            else:
                # discard-and-recompute: cache gone, re-prefill everything
                stats.recomputed_tokens += req.entry.prefill_done
                req.entry.prefill_done = 0
                req._kv_written = 0     # nothing materialized any more: the
                                        # re-prefill is fresh compute and
                                        # counts as prefilled work again
                if self.blocks is not None and self.pool is None:
                    # sim mode only: in real mode pool.release() below frees
                    # the pages itself (and queues their device reset)
                    self.blocks.free_request(rid)
            if self.pool is not None:
                if self.paged:
                    self.pool.release(rid, retain=self._retain)
                else:
                    self.pool.release(rid)
            req.slot = -1

    def _register_prompt(self, req):
        """Publish ``req``'s fully-written prompt pages to the hash index.

        A per-request watermark skips the (O(prompt pages) hashing) walk
        once everything registerable has been offered — the ratchet only
        moves forward, so a rare eviction of already-offered pages just
        forgoes re-registration, never corrupts the index.
        """
        written = min(getattr(req, "_kv_written", 0), len(req.prompt))
        pages = written // self.ecfg.page_size
        if pages > getattr(req, "_reg_pages", 0):
            self.blocks.register_prefix(req.rid, req.prompt, written)
            req._reg_pages = pages

    def _apply_admissions(self, decision: Decision, pool_reqs, stats):
        for rid in decision.admitted:
            req = pool_reqs[rid]
            was_preempted = req.entry.state is ReqState.PREEMPTED
            req.entry.state = ReqState.RUNNING
            if self.events is not None:
                self.events.emit(self._now, rid, "admit")
            if (self.prefix_cache and not was_preempted
                    and req.entry.prefill_done == 0
                    and not self.blocks.pages.get(rid)):
                # link the longest cached prefix: block-table writes only,
                # no prefill compute; the costmodel is charged just for
                # the uncached tokens because prefill starts at the hit
                hit = self.blocks.link_prefix(rid, self._match_tokens(req))
                if hit:
                    stats.prefix_hit_tokens += hit
                    req.entry.prefill_done = hit
                    req._kv_written = hit
                    if self.events is not None:
                        self.events.emit(self._now, rid, "prefix_hit", hit)
                self._prefix_hint.pop(rid, None)
                self._hint_gen.pop(rid, None)
                self._sync_prefill_left(req)
            if getattr(req, "_swapped", False):     # swap back in (whole seq)
                nbytes = self._bytes_for(req.context_len)
                stats.swapped_bytes += nbytes
                self._swap_pending_s += nbytes / self.ecfg.hardware.dma_bw
                req._swapped = False
                if self.events is not None:
                    self.events.emit(self._now, rid, "swap", nbytes)
            if self._retain and was_preempted:
                n_host = self.blocks.host_pages.get(rid, 0)
                if n_host:                          # page-granular swap-in
                    nbytes = n_host * self._page_bytes
                    stats.swapped_bytes += nbytes
                    self._swap_pending_s += nbytes / self.ecfg.hardware.dma_bw
                    self.blocks.swap_in(rid)
                    if self.events is not None:
                        self.events.emit(self._now, rid, "swap", nbytes)
                # copy-on-admit: retained prefix re-links (block-table
                # write); only the evicted tail is ever recomputed
                retained = min(self.blocks.resume(rid),
                               max(req.context_len - 1, 0))
                lost = req.entry.prefill_done - retained
                if lost > 0:
                    stats.recomputed_tokens += lost
                req.entry.prefill_done = retained
                req._kv_written = retained
            if self.pool is not None:
                req.slot = self.pool.assign(rid)

    # ------------------------------------------------------------------
    # paged-layout memory management
    # ------------------------------------------------------------------
    def _suspended(self, entries, exclude=()):
        return [e for e in entries.values()
                if e.state is ReqState.PREEMPTED and e.rid not in exclude
                and self.blocks.resident_pages(e.rid) > 0]

    def _victim_key(self, e):
        """Eviction-victim ordering key.

        Prefer victims that can actually
        yield memory (an unshared tail page — shared pages free nothing
        and would force recompute for their other owners), then the
        least-urgent prediction. Without sharing every resident victim
        has an unshared tail, so the order is unchanged.
        """
        return (min(self.blocks.unshared_tail_pages(e.rid), 1),
                e.pred_remaining, e.rid)

    def _reclaim_pages(self, decision: Decision, pool_reqs, entries, stats):
        """Evict or swap out suspended pages until the budget fits.

        Tail-first from the least-urgent victim, until scheduled +
        suspended bytes fit.
        """
        sched = set(decision.scheduled)
        susp = self._suspended(entries, exclude=sched)
        if self.prefix_cache:
            # unique-page accounting: per-request byte sums would charge a
            # shared prefix once per owner and trigger evictions the real
            # footprint never required. Project end-of-megastep usage as
            # pages held now (each counted once) plus the growth scheduled
            # rows still need; a WAITING row's prospective hit counts as
            # growth too — linking flips warm pages into used ones.
            ps = self.ecfg.page_size
            growth = sum(
                max(pages_for_tokens(pool_reqs[rid].context_len + self._k,
                                     ps)
                    - self.blocks.resident_pages(rid), 0)
                for rid in decision.scheduled)
            over = ((self.blocks.used_pages() + growth) * self._page_bytes
                    - self.ecfg.mem_budget)
        else:
            need = sum(self._bytes_for(pool_reqs[rid].context_len + self._k)
                       for rid in decision.scheduled)
            resident = sum(self.blocks.resident_pages(e.rid) for e in susp)
            over = need + resident * self._page_bytes - self.ecfg.mem_budget
        swap = self.ecfg.oom_mode == "swap"
        while over > 0 and susp:
            victim = max(susp, key=self._victim_key)
            n_pages = -(-over // self._page_bytes)       # all we still need
            if swap:
                freed = self.blocks.swap_out_tail(victim.rid, n_pages)
                if freed:
                    nbytes = len(freed) * self._page_bytes
                    stats.swapped_bytes += nbytes
                    self._swap_pending_s += nbytes / self.ecfg.hardware.dma_bw
                    if self.events is not None:
                        self.events.emit(self._now, victim.rid, "swap",
                                         nbytes)
            elif self.pool is not None:
                freed = self.pool.evict_tail(victim.rid, n_pages)
            else:
                freed = self.blocks.evict_tail(victim.rid, n_pages)
            if not freed:
                break
            over -= len(freed) * self._page_bytes
            susp = [e for e in susp if self.blocks.resident_pages(e.rid) > 0]

    def _ensure_pages(self, req, tokens: int, entries):
        """Grow a scheduled request's page list to cover ``tokens``.

        Evicts suspended pages when the (real-mode) physical pool is
        exhausted.
        """
        if self.pool is not None:
            # only the real device pool is max_len-bounded; sim-mode paged
            # accounting must track contexts as far as the contig baseline
            tokens = min(tokens, self.ecfg.max_len)
        exhausted: set[int] = set()     # victims whose tail is all shared
        while True:
            ok = (self.pool.ensure_pages(req.rid, tokens)
                  if self.paged and self.pool is not None
                  else self.blocks.ensure(req.rid, tokens))
            if ok:
                return
            susp = self._suspended(entries, exclude=(req.rid, *exhausted))
            if not susp:
                raise RuntimeError("paged KV pool exhausted: no suspended "
                                   "pages left to evict")
            victim = max(susp, key=self._victim_key)
            shortfall = max(
                1, (-(-tokens // self.ecfg.page_size)
                    - self.blocks.resident_pages(req.rid)
                    - self.blocks.free_pages()))
            if self.pool is not None:
                freed = self.pool.evict_tail(victim.rid, shortfall)
            else:
                freed = self.blocks.evict_tail(victim.rid, shortfall)
            if not freed:
                # every remaining tail page is shared: evicting it frees
                # nothing — move on to the next victim
                exhausted.add(victim.rid)

    def _row_budget(self, r) -> int:
        """Decode tokens this row may emit in the upcoming megastep."""
        rem = min(r.true_out_len, r.max_new_tokens) - len(r.generated)
        return max(1, min(self._k, rem))

    # ------------------------------------------------------------------
    # sim mode: oracle probe statistics, no device math
    # ------------------------------------------------------------------
    def _sim_step(self, pf_plan, decoding):
        for r, take in pf_plan:
            if r.entry.prefill_done + take >= r.context_len - 1:
                pred = self.predictor.on_prefill(r)
                r.entry.pred_remaining = pred
        if decoding:
            # one vectorized draw per iteration (stream-identical to the
            # old per-request scalar draws, ~10x less RNG overhead)
            toks = self._rng.integers(1, self.cfg.vocab_size,
                                      size=len(decoding))
            for r, tok in zip(decoding, toks):
                r.generated.append(int(tok))
                if len(r.generated) % self.ecfg.probe_interval == 0:
                    r.entry.pred_remaining = self.predictor.on_token(r)
                else:   # between probes: predictions age deterministically
                    r.entry.pred_remaining = max(
                        r.entry.pred_remaining - 1.0, 0.0)
        return {r.rid: 1 for r in decoding}

    # ------------------------------------------------------------------
    # real mode: batched device megasteps over the slot pool
    # ------------------------------------------------------------------
    def _device_step(self, pf_plan, decoding) -> dict[int, int]:
        """Dispatch one prefill chunk + one decode megastep.

        Returns the tokens emitted per rid.

        Both device calls are dispatched before any output is fetched, so
        (on an async backend) the host runs the prefill-side probe
        bookkeeping while the k-step decode megastep is still executing.
        The only decode-side host transfer is O(B*k) token ids plus
        O(B*k*num_bins) probe posteriors — never the (B, vocab) logits.
        """
        import jax.numpy as jnp
        pool = self.pool
        B = pool.n_slots
        pool.flush_resets()
        pf_out = None
        # The scheduler's prefill classification runs on prefill_done, which
        # trails _kv_written after a decode megastep (decode writes KV for
        # the tokens it consumes but only the prefill bookkeeping advances
        # prefill_done). Feeding those caught-up positions to the device
        # again would append duplicate KV at cache["lengths"] and desync
        # the device cache from the logical context — so the device call
        # covers only the genuinely unwritten slice of each chunk, keeping
        # lengths == _kv_written at every megastep boundary (the invariant
        # page export/import relies on).
        feed: list[tuple[Request, int, int]] = []
        for r, take in pf_plan:
            done = r.entry.prefill_done
            skip = min(max(getattr(r, "_kv_written", 0) - done, 0), take)
            if take > skip:
                feed.append((r, done + skip, take - skip))
        if feed:
            # bucketize the chunk width (powers of two) to bound recompiles
            need = max(n for _, _, n in feed)
            chunk = 8
            while chunk < need:
                chunk *= 2
            chunk = min(chunk, self.ecfg.prefill_chunk)
            tokens = np.zeros((B, chunk), np.int32)
            valid = np.zeros((B, chunk), bool)
            for r, start, n in feed:
                full = r.prompt + r.generated
                seg = full[start:start + n]
                tokens[r.slot, :len(seg)] = seg
                valid[r.slot, :len(seg)] = True
            _, pool.cache, tap_sum, n_new = self._prefill_fn(
                self.params, pool.cache, jnp.asarray(tokens),
                valid=jnp.asarray(valid))
            pf_out = (tap_sum, n_new)
        dec_out = None
        if decoding:
            tokens = np.zeros((B, 1), np.int32)
            active = np.zeros((B,), bool)
            budget = np.zeros((B,), np.int32)
            for r in decoding:
                tokens[r.slot, 0] = (r.generated[-1] if r.generated
                                     else (r.prompt[-1] if r.prompt else 1))
                active[r.slot] = True
                budget[r.slot] = self._row_budget(r)
            toks, pool.cache, probs, n_emit = self._decode_fn(
                self.params, pool.cache, jnp.asarray(tokens),
                jnp.asarray(active), jnp.asarray(budget), k=self._k)
            dec_out = (toks, probs, n_emit)

        if pf_out is not None:
            tap_sum = np.asarray(pf_out[0])
            n_new = np.asarray(pf_out[1])
            for r, start, n in feed:
                if r.tap_sum is None:
                    r.tap_sum = np.zeros(self.cfg.d_model, np.float32)
                r.tap_sum = r.tap_sum + tap_sum[r.slot]
                r.tap_cnt += int(n_new[r.slot])
                if start + n >= r.context_len - 1:
                    tap_mean = r.tap_sum / max(r.tap_cnt, 1)
                    pred = self.predictor.on_prefill(r, tap_mean)
                    r.entry.pred_remaining = pred
        emitted: dict[int, int] = {}
        if dec_out is not None:
            toks_np = np.asarray(dec_out[0])
            probs_np = np.asarray(dec_out[1])
            n_np = np.asarray(dec_out[2])
            for r in decoding:
                n = int(n_np[r.slot])
                for t in range(n):
                    r.generated.append(int(toks_np[r.slot, t]))
                    if len(r.generated) % self.ecfg.probe_interval == 0:
                        # device-side softmax posterior at the probe boundary
                        r.entry.pred_remaining = self.predictor.on_token(
                            r, probs_np[r.slot, t])
                    else:   # between probes: deterministic aging
                        r.entry.pred_remaining = max(
                            r.entry.pred_remaining - 1.0, 0.0)
                emitted[r.rid] = n
        return emitted


def run_policy(cfg: ModelConfig, policy: str, requests, *, c_limit=0.8,
               max_batch=16, mem_budget=1 << 62, mode="sim",
               predictor=None, model=None, params=None,
               hardware: HardwareSpec | None = None, seed=0,
               probe_interval=1, oom_mode="discard", kv_layout="contig",
               page_size=16, max_len=1024,
               prefix_cache=False, event_log=None,
               deadline_s=0.0, ttft_deadline_s=0.0,
               shed_watermark=0.0,
               admission_control=False,
               age_boost=0.0, age_delay_s=0.0,
               deadline_slack_s=0.0) -> EngineStats:
    """One-shot convenience: build an `Engine` and run a request trace.

    The requests are deep-copied and run under the given policy,
    returning the engine's `EngineStats`.
    ``predictor`` accepts either a `PredictorBase` instance or a
    strategy spec string (``"noisy-oracle:sigma=0.5"``, see
    `repro.serving.predictors.make_predictor`); None keeps the legacy
    default. Pass a `repro.metrics.EventLog` as ``event_log`` to
    capture the per-request event stream alongside. The resilience
    knobs (``deadline_s`` / ``ttft_deadline_s`` / ``shed_watermark`` /
    ``admission_control``) and the tail knobs (``age_boost`` /
    ``age_delay_s`` / ``deadline_slack_s``) mirror `EngineConfig` and
    default off.
    """
    spec = predictor if isinstance(predictor, str) else ""
    if spec:
        predictor = None
    ecfg = EngineConfig(policy=policy, c_limit=c_limit, max_batch=max_batch,
                        mem_budget=mem_budget, mode=mode, seed=seed,
                        probe_interval=probe_interval, oom_mode=oom_mode,
                        kv_layout=kv_layout, page_size=page_size,
                        max_len=max_len, prefix_cache=prefix_cache,
                        predictor=spec,
                        deadline_s=deadline_s,
                        ttft_deadline_s=ttft_deadline_s,
                        shed_watermark=shed_watermark,
                        admission_control=admission_control,
                        age_boost=age_boost,
                        age_delay_s=age_delay_s,
                        deadline_slack_s=deadline_slack_s,
                        hardware=hardware or HardwareSpec())
    import copy
    reqs = copy.deepcopy(requests)
    eng = Engine(cfg, ecfg, predictor=predictor, model=model, params=params,
                 event_log=event_log)
    return eng.run(reqs)
