"""Roofline-derived iteration cost model (simulated clock).

This container is CPU-only, so wall-clock timings of an A100/TPU serving run
are meaningless. The engine instead advances a simulated clock using the
same three-term roofline as EXPERIMENTS.md section Roofline:

  t_iter = max(compute, memory) + fixed overhead

  compute = FLOPs / peak_flops          (2 * active_params per token
                                         + attention O(ctx) term)
  memory  = bytes / hbm_bw              (params once per iteration batch
                                         + the KV bytes actually touched)

Defaults model one TPU v5e chip (197 bf16 TFLOP/s, 819 GB/s) — substitute
A100 constants to mimic the paper's testbed. The absolute numbers are a
model; every claim we validate is a *ratio* between policies under the same
cost model, matching the paper's relative speedups.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.config import KIND_LOCAL, KIND_SSM, ModelConfig
from repro.serving.kv_cache import (bytes_for_context, page_bytes,
                                    paged_bytes_for_context)


@dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for one accelerator (drives the simulated clock).

    Attributes:
        name: identifier recorded in benchmark artifacts.
        peak_flops: peak bf16 FLOP/s; the compute-roofline term. Lowering
            it models compute-bound serving, where iteration time scales
            with batch tokens (see benchmarks/cluster_curves.py).
        hbm_bw: HBM bytes/s; the memory-roofline term (params + KV).
        dma_bw: device<->host bytes/s (the KV swap path).
        link_bw: replica<->replica interconnect bytes/s (the KV handoff
            hop of prefill/decode disaggregation; ~200 Gb/s Ethernet by
            default).
        overhead_s: fixed per-iteration dispatch overhead in seconds.
    """

    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16
    hbm_bw: float = 819e9             # bytes/s
    dma_bw: float = 32e9              # device<->host (KV swap path)
    link_bw: float = 25e9             # replica<->replica (KV handoff hop)
    overhead_s: float = 2.0e-4        # per-iteration dispatch overhead


A100 = HardwareSpec(name="a100-80g", peak_flops=312e12, hbm_bw=2039e9,
                    overhead_s=1.5e-4)


class CostModel:
    """Evaluates the three-term roofline for engine iterations/megasteps."""

    def __init__(self, cfg: ModelConfig, hw: HardwareSpec = HardwareSpec(),
                 weight_dtype_bytes: int = 2, page_size: int = 0):
        self.cfg = cfg
        self.hw = hw
        self.page_size = page_size          # >0: paged KV — decode streams
                                            # whole pages, not exact tokens
        self.active_params = cfg.active_param_count()
        self.param_bytes = cfg.param_count() * weight_dtype_bytes

    def _cache_bytes(self, ctx: int) -> int:
        if self.page_size:
            return paged_bytes_for_context(self.cfg, ctx, self.page_size)
        return bytes_for_context(self.cfg, ctx)

    def resident_page_bytes(self, n_unique_pages: int) -> int:
        """Page-accurate resident KV footprint for unique physical pages.

        With cross-request prefix caching the
        per-request sum over ``bytes_for`` double-counts shared pages;
        the engine's memory accounting switches to this unique-page form
        (refcounted pages counted once) whenever sharing is enabled.
        """
        if not self.page_size:
            raise ValueError("resident_page_bytes requires a paged layout")
        return n_unique_pages * page_bytes(self.cfg, self.page_size)

    def _attn_flops_per_token(self, ctx: int) -> float:
        """Attention score+value FLOPs for one new token at context ctx."""
        cfg = self.cfg
        f = 0.0
        for kind in cfg.layer_kinds:
            if kind == KIND_SSM:
                # SSD decode: state update + readout
                f += 4.0 * cfg.ssm_expand * cfg.d_model * cfg.ssm_state
                continue
            eff = min(ctx, cfg.sliding_window) if kind == KIND_LOCAL else ctx
            f += 4.0 * cfg.q_dim * eff
        return f

    def iteration_time(self, decode_ctxs: list[int],
                       prefill_tokens: int = 0,
                       prefill_ctx: int = 0) -> float:
        """One engine iteration: a batch of decode rows + a prefill chunk."""
        return self.megastep_time(decode_ctxs, [1] * len(decode_ctxs),
                                  prefill_tokens, prefill_ctx)

    def megastep_time(self, decode_ctxs: list[int], emitted: list[int],
                      prefill_tokens: int = 0,
                      prefill_ctx: int = 0) -> float:
        """One decode megastep's wall-clock time under the roofline.

        Row i starts at context ``decode_ctxs[i]`` and generates
        ``emitted[i]`` tokens without returning to the host. Per-token compute and cache streaming are unchanged (each of the k
        scanned steps still reads the weights and the growing KV), but the
        fixed dispatch/host overhead is paid ONCE per megastep instead of
        once per token — the amortization the engine's megastep loop buys.
        With all-ones ``emitted`` this is exactly ``iteration_time``.
        """
        flops = 0.0
        steps = max(emitted, default=0)
        mem = float(self.param_bytes) * max(steps, 1)
        for ctx, n in zip(decode_ctxs, emitted):
            for j in range(n):
                flops += (2.0 * self.active_params
                          + self._attn_flops_per_token(ctx + j))
                mem += self._cache_bytes(ctx + j)       # stream the cache
        if prefill_tokens:
            flops += 2.0 * self.active_params * prefill_tokens
            flops += self._attn_flops_per_token(prefill_ctx) * prefill_tokens / 2.0
            mem += self._cache_bytes(prefill_ctx)
        t = max(flops / self.hw.peak_flops, mem / self.hw.hbm_bw)
        return t + self.hw.overhead_s

    def predictor_time(self, flops: float) -> float:
        """Seconds of predictor work for ``flops`` charged FLOPs.

        The length-prediction strategies (`repro.serving.predictors`)
        book the FLOPs an external implementation would spend (a
        BERT-sized prompt model, an ELIS proxy re-prediction); the
        engine drains them every step and charges the simulated clock
        through here — compute-roofline only, since estimator weights
        are tiny next to the serving model's. Zero FLOPs (the recycled
        trail-probe, the analysis oracles) cost exactly 0.0 seconds, so
        legacy results stay byte-identical.
        """
        return flops / self.hw.peak_flops

    def kv_transfer_time(self, nbytes: int) -> float:
        """Seconds to ship ``nbytes`` of paged KV replica-to-replica.

        Host-bounce path, mirroring the swap machinery: one batched
        device->host DMA on the source, the interconnect hop, one batched
        host->device DMA on the destination, plus a single dispatch
        overhead for the whole batch (transfer batching: a handoff is one
        charge, never per-page). The router charges this as *delayed
        availability* of the migrated request on the destination's
        virtual clock — decode megasteps keep running underneath, so the
        transfer overlaps compute instead of stalling the batch the way
        an in-step swap charge would.
        """
        if nbytes <= 0:
            return 0.0
        return (2.0 * nbytes / self.hw.dma_bw + nbytes / self.hw.link_bw
                + self.hw.overhead_s)

    def decode_token_rate(self, ctx: int = 256) -> float:
        """Steady-state decode tokens/s of one lone row at context ``ctx``.

        The per-replica service-rate normalizer for the router's
        seconds-unit backlog (`Engine.backlog_seconds`): predicted
        remaining *tokens* divide by this to become estimated seconds.
        One fixed reference context keeps the conversion strictly
        monotone in tokens — identical replicas rank identically in
        either unit, while heterogeneous hardware specs (the roadmap
        item this preps) scale by their true relative speed.
        """
        return 1.0 / self.iteration_time([ctx])

    def ideal_service_time(self, prompt_len: int, out_len: int) -> float:
        """Isolated completion time for one request on an empty engine.

        A single megastep evaluation: the whole prompt prefilled in one
        chunk plus all ``out_len`` decode tokens, overhead paid once —
        the denominator of the metrics layer's *slowdown* distribution
        (observed completion ÷ this).
        """
        ctx0 = max(prompt_len, 1)
        return self.megastep_time([ctx0 + 1], [max(out_len, 1)],
                                  prefill_tokens=max(prompt_len - 1, 0),
                                  prefill_ctx=ctx0)
