"""KV-cache managers: contiguous slot pool and block-granular paged pool.

Two layouts coexist behind the engine's ``kv_layout`` switch:

* ``contig`` — :class:`SlotPool`: a fixed pool of ``slots`` sequence slots
  allocated once per engine (static shapes for XLA); requests map onto slots
  for their lifetime in the batch. The OOM mode is the paper's choice:
  *discard and recompute* — a preempted request's slot is released, its
  cache garbage-collected lazily (kpos=-1 kills stale attention entries;
  SSM state zeroed), and on re-admission the engine re-prefills
  prompt + generated-so-far.

* ``paged`` — :class:`BlockManager` + :class:`PagedSlotPool`: the KV store
  is a pool of fixed-size pages (``page_size`` tokens each) shared by all
  sequences, addressed through per-request block tables. Preemption can
  then free *or retain* memory at page granularity: a preempted request's
  pages stay resident while memory allows, and re-admission re-links them
  into the new slot's block-table row without any copy ("copy-on-admit" is
  a table write, not a cache move), so only evicted pages are recomputed.
  This is the mechanism the paper's Section 3.3 preemption-cost discussion
  assumes away — paging makes the C-limit sweep's recompute term smaller.

With ``prefix_cache=True`` the paged layout additionally shares identical
KV *prefixes across requests*: full prompt pages are registered in a
chained content-hash index, later requests link matching pages by
refcount bump + block-table write (no prefill compute), writes into
shared pages copy-on-write, and refcount-zero indexed pages park in a
reusable LRU pool — warm for the next hit, reclaimable under pressure.
See :class:`BlockManager` for the invariants.

``bytes_for_context`` is the arch-aware preemption-cost function m(age)
from DESIGN.md section 4: dense KV grows linearly with context,
sliding-window layers clamp at the window, SSM layers cost O(1) state.
``paged_bytes_for_context`` is its page-granular counterpart (token counts
round up to whole pages — the fragmentation the scheduler must budget
for). The scheduler uses these both for the admission budget and
(implicitly, via the paper's C*r rule) for limiting preemption.
"""

from __future__ import annotations

import functools
import math
import warnings
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (KIND_ATTN, KIND_HYBRID, KIND_LOCAL, KIND_MOE,
                          KIND_SSM, ModelConfig)
from repro.models.ssm import ssm_dims


def _silence_cpu_donation_warning():
    """Silence the CPU backend's unhonored-donation warning.

    Buffer donation lets XLA update the KV cache in place instead of
    copying the whole pytree every jit call. The CPU backend (this
    container / the CI runner) can never honor donation and warns once per
    compiled function with identical semantics either way, so the warning
    is pure noise there — but ONLY there: on GPU/TPU an unexpectedly
    undonatable buffer means XLA is back to copying the cache every
    megastep, and the warning is the signal. Install the filter lazily
    (first donating jit / pool construction) and only on CPU.
    """
    if jax.default_backend() == "cpu":
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")


def donating_jit(fn, donate: tuple[str, ...] = ("cache",), **jit_kwargs):
    """jit with the cache pytree donated.

    XLA may alias the input buffers
    into the outputs (in-place KV update). Callers MUST drop every
    reference to the donated argument and use the returned cache — the
    engine's single-owner ``pool.cache`` reassignment pattern.
    """
    _silence_cpu_donation_warning()
    return jax.jit(fn, donate_argnames=donate, **jit_kwargs)


def dtype_bytes(cfg: ModelConfig) -> int:
    """Bytes per element of the cache dtype."""
    return jnp.dtype(cfg.dtype).itemsize


def bytes_per_token_kind(cfg: ModelConfig, kind: str) -> int:
    """KV bytes one token adds in one layer of this kind (0 for SSM)."""
    if kind == KIND_SSM:
        return 0
    if cfg.kv_quant:     # int8 payload + f32 per-(token,head) scales
        return 2 * (cfg.kv_dim * 1 + cfg.num_kv_heads * 4)
    return 2 * cfg.kv_dim * dtype_bytes(cfg)


def ssm_state_bytes(cfg: ModelConfig) -> int:
    """Fixed per-request SSM recurrent-state bytes (f32 state + conv)."""
    d_in, nh, conv_ch = ssm_dims(cfg)
    n = cfg.ssm_groups * cfg.ssm_state
    return 4 * (nh * cfg.ssm_head_dim * n) + 4 * (cfg.ssm_conv - 1) * conv_ch


@functools.lru_cache(maxsize=1 << 16)
def bytes_for_context(cfg: ModelConfig, context_len: int) -> int:
    """Total per-request cache bytes at a given context length.

    Memoized on the (hashable, frozen) config and length: ``select_batch``
    evaluates this per candidate per iteration, and at large request
    counts the layer_kinds walk dominated sim-mode scheduling cost.
    """
    total = 0
    for kind in cfg.layer_kinds:
        per_tok = bytes_per_token_kind(cfg, kind)
        if kind in (KIND_LOCAL, KIND_HYBRID) and cfg.sliding_window:
            total += per_tok * min(context_len, cfg.sliding_window)
        else:
            total += per_tok * context_len
        if kind in (KIND_SSM, KIND_HYBRID):
            total += ssm_state_bytes(cfg)
    if cfg.cross_attention and cfg.encoder_seq:
        total += (cfg.num_layers * 2 * cfg.kv_dim * dtype_bytes(cfg)
                  * cfg.encoder_seq)
    return total


def pages_for_tokens(tokens: int, page_size: int) -> int:
    """Whole pages needed to hold ``tokens`` tokens."""
    return max(0, math.ceil(tokens / page_size))


@functools.lru_cache(maxsize=4096)
def page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """KV bytes of one page across all non-SSM layers (window layers too:

    their ring buffers are page-sized in the accounting model).
    """
    per_tok = sum(bytes_per_token_kind(cfg, kind) for kind in cfg.layer_kinds)
    return per_tok * page_size


@functools.lru_cache(maxsize=1 << 16)
def paged_bytes_for_context(cfg: ModelConfig, context_len: int,
                            page_size: int) -> int:
    """Page-granular m(age).

    Like ``bytes_for_context`` but every token
    count rounds up to whole pages, exposing allocation fragmentation.
    SSM state and cross-attention caches are unpaged (fixed-size).
    Memoized like ``bytes_for_context`` (same per-entry-per-iteration
    call pattern in the scheduler's bytes_fn).
    """
    rounded = pages_for_tokens(context_len, page_size) * page_size
    total = 0
    for kind in cfg.layer_kinds:
        per_tok = bytes_per_token_kind(cfg, kind)
        if kind in (KIND_LOCAL, KIND_HYBRID) and cfg.sliding_window:
            win = min(context_len, cfg.sliding_window)
            total += per_tok * pages_for_tokens(win, page_size) * page_size
        else:
            total += per_tok * rounded
        if kind in (KIND_SSM, KIND_HYBRID):
            total += ssm_state_bytes(cfg)
    if cfg.cross_attention and cfg.encoder_seq:
        total += (cfg.num_layers * 2 * cfg.kv_dim * dtype_bytes(cfg)
                  * cfg.encoder_seq)
    return total


def supports_page_retention(cfg: ModelConfig) -> bool:
    """Whether this arch can keep preempted KV pages resident.

    Retention is only coherent when the
    *whole* recurrent state lives in pages: pure global-attention
    stacks (dense/MoE). SSM state, ring buffers and cross caches are
    per-slot and reset on release, so such archs fall back to
    discard-and-recompute (still with page-accurate accounting).
    """
    return (all(k in (KIND_ATTN, KIND_MOE) for k in cfg.layer_kinds)
            and not cfg.cross_attention and not cfg.kv_quant)


class BlockManager:
    """Free-list page allocator with per-request block tables.

    Physical page ids run ``first_id .. first_id + num_pages - 1``; id 0 is
    reserved as the null page (device ``pkpos`` stays -1 there forever, so
    unallocated block-table entries mask out cleanly). ``num_pages=0``
    means unbounded (sim-mode accounting, no device pool behind it).

    Per request the manager tracks the ordered list of *resident* pages
    (covering logical pages ``[0, len(pages))``), a count of tail pages
    swapped to host memory, and ``cached_tokens`` — how many prefix tokens
    the resident+host pages actually hold. Eviction and swap are tail-first
    so the retained portion is always a clean prefix.

    With ``prefix_cache=True`` pages become shareable across requests:

    * every allocated page carries a **refcount** (owners among live
      requests); pages are physically reclaimed only at refcount zero;
    * full prompt pages are registered in a **content-hash index** keyed
      on ``(parent_physical_id, token_block)`` — a chained key, so a hit
      on page *j* proves the whole prefix up to *j* matches;
    * a request whose prompt matches a chain of cached pages **links**
      them (block-table writes, refcount bumps) instead of re-prefilling;
    * pages whose refcount drops to zero while still indexed move to a
      **reusable** LRU pool: warm for future hits, yet counted as free
      capacity — the allocator reclaims LRU-first (deregistering the page
      and its now-unreachable descendants) when the free list runs dry;
    * writes into a shared page go through **copy-on-write**
      (`make_writable`): the writer gets a private copy, the shared page
      is never mutated in place.

    With the default ``prefix_cache=False`` nothing is indexed or shared
    and every refcount is 1, so behaviour is exactly the pre-prefix-cache
    manager. ``track_resets=True`` (set by :class:`PagedSlotPool`) logs
    page ids whose device state must be invalidated or copied; sim-mode
    managers leave it off so nothing accumulates.
    """

    def __init__(self, num_pages: int, page_size: int, first_id: int = 1,
                 prefix_cache: bool = False, track_resets: bool = False,
                 reusable_cap: int | None = None):
        """See the class docstring.

        ``reusable_cap`` bounds the reusable
        pool (warm refcount-zero pages). A bounded pool is naturally
        capped at ``num_pages``; unbounded (sim-mode) managers must pass
        a cap or the index/LRU bookkeeping grows with every unique prompt
        ever served — and, worse, models an infinitely large always-warm
        cache no physical pool could provide.
        """
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.page_size = page_size
        self.num_pages = num_pages
        self.bounded = num_pages > 0
        self.free: list[int] = (
            list(range(first_id, first_id + num_pages))[::-1]
            if self.bounded else [])
        self._next_id = first_id + num_pages
        self.pages: dict[int, list[int]] = {}
        self.host_pages: dict[int, int] = {}
        self.cached_tokens: dict[int, int] = {}
        self.prefix_cache = prefix_cache
        self.track_resets = track_resets
        self.reusable_cap = reusable_cap
        # refcount: physical id -> live owners (0 while parked in _reusable)
        self.refcount: dict[int, int] = {}
        self._used = 0              # pages with refcount > 0 (incremental:
                                    # the refcount dict retains warm pages,
                                    # so scanning it per step would cost
                                    # O(pages ever registered))
        self.index_gen = 0          # bumped whenever index contents change
                                    # (register/deregister) — lets callers
                                    # cache match_prefix results
        self._index: dict[tuple, int] = {}     # (parent_pid, tokens) -> pid
        self._key_of: dict[int, tuple] = {}    # pid -> its index key
        self._kids: dict[int, set[int]] = {}   # pid -> registered children
        self._reusable: OrderedDict[int, None] = OrderedDict()  # LRU order
        self._reset_log: list[int] = []        # device invalidation queue
        self._cow_log: list[tuple[int, int]] = []   # (src, dst) page copies

    # -- allocation ------------------------------------------------------
    def available_pages(self) -> int:
        """Pages allocatable right now.

        Free-listed plus reusable (warm refcount-zero cache pages,
        reclaimed on demand).
        """
        if not self.bounded:
            return 1 << 30
        return len(self.free) + len(self._reusable)

    def _take_page(self) -> int | None:
        if self.free:
            pid = self.free.pop()
        elif not self.bounded:
            pid = self._next_id
            self._next_id += 1
        elif self._reusable:
            pid, _ = self._reusable.popitem(last=False)      # LRU reclaim
            del self.refcount[pid]
            self._deregister(pid)
            if self.track_resets:
                self._reset_log.append(pid)
        else:
            return None
        self.refcount[pid] = 1
        self._used += 1
        return pid

    def _take_pages(self, n: int) -> list[int] | None:
        """Atomically allocate ``n`` pages.

        Validates capacity first and either returns all ``n`` or None,
        never a partial allocation.
        """
        if self.bounded and self.available_pages() < n:
            return None
        return [self._take_page() for _ in range(n)]

    def _release_ref(self, pid: int) -> bool:
        """Drop one reference.

        Returns True when the page left the used set (refcount hit zero)
        — whether free-listed or parked reusable.
        """
        self.refcount[pid] -= 1
        if self.refcount[pid] > 0:
            return False
        self._used -= 1
        if self.prefix_cache and pid in self._key_of:
            self._reusable[pid] = None          # stays warm, counts as free
            self._reusable.move_to_end(pid)
            if (self.reusable_cap is not None
                    and len(self._reusable) > self.reusable_cap):
                old, _ = self._reusable.popitem(last=False)   # LRU out
                del self.refcount[old]
                self._deregister(old)
                if self.bounded:
                    self.free.append(old)
                if self.track_resets:
                    self._reset_log.append(old)
            return True
        del self.refcount[pid]
        if self.bounded:
            self.free.append(pid)
        if self.track_resets:
            self._reset_log.append(pid)
        return True

    def free_pages(self) -> int:
        """Unallocated page count (effectively infinite when unbounded)."""
        return self.available_pages() if self.bounded else 1 << 30

    def used_pages(self) -> int:
        """Unique physical pages referenced by at least one request.

        Shared pages count once — the page-accurate resident footprint.
        """
        return self._used

    def ensure(self, rid: int, tokens: int) -> bool:
        """Grow ``rid``'s resident page list to cover ``tokens``.

        Returns False (allocating nothing) on pool exhaustion.
        """
        have = self.pages.setdefault(rid, [])
        need = pages_for_tokens(tokens, self.page_size) - len(have)
        if need <= 0:
            return True
        got = self._take_pages(need)
        if got is None:
            return False
        have.extend(got)
        return True

    def note_cached(self, rid: int, tokens: int):
        """Record that the prefix up to ``tokens`` is now materialized."""
        cap = ((len(self.pages.get(rid, ())) + self.host_pages.get(rid, 0))
               * self.page_size)
        self.cached_tokens[rid] = min(tokens, cap)

    # -- queries ---------------------------------------------------------
    def block_table(self, rid: int) -> list[int]:
        """The request's ordered resident physical page ids (a copy)."""
        return list(self.pages.get(rid, ()))

    def resident_pages(self, rid: int) -> int:
        """Number of device-resident pages held by ``rid``."""
        return len(self.pages.get(rid, ()))

    def resident_tokens(self, rid: int) -> int:
        """Materialized prefix tokens covered by device-resident pages."""
        return min(self.cached_tokens.get(rid, 0),
                   self.resident_pages(rid) * self.page_size)

    def total_resident_pages(self) -> int:
        """Device-resident pages across all requests."""
        return sum(len(p) for p in self.pages.values())

    # -- eviction / swap (tail-first) -----------------------------------
    def evict_tail(self, rid: int, n_pages: int) -> list[int]:
        """Discard up to ``n_pages`` tail pages.

        The discarded tokens must be
        recomputed on resume. Host-swapped tail pages are dropped first —
        they are beyond the resident prefix. Shared pages (refcount > 1)
        stop the walk: reclaiming them frees no memory and would force a
        recompute of tokens other requests still serve, so eviction
        prefers — and only ever takes — unshared tail pages. Returns the
        physical ids that actually left the used set.
        """
        dropped_host = min(self.host_pages.get(rid, 0), n_pages)
        if dropped_host:
            self.host_pages[rid] -= dropped_host
            n_pages -= dropped_host
        have = self.pages.get(rid, [])
        freed = []
        for _ in range(min(n_pages, len(have))):
            if self.refcount.get(have[-1], 1) > 1:
                break                           # shared: not reclaimable
            pid = have.pop()
            if self._release_ref(pid):
                freed.append(pid)
        self.note_cached(rid, self.cached_tokens.get(rid, 0))
        return freed

    def unshared_tail_pages(self, rid: int) -> int:
        """Contiguous run of evictable (refcount == 1) pages at the tail.

        This is how much relief evicting the request can actually yield.
        """
        n = 0
        for pid in reversed(self.pages.get(rid, [])):
            if self.refcount.get(pid, 1) > 1:
                break
            n += 1
        return n

    def swap_out_tail(self, rid: int, n_pages: int) -> list[int]:
        """Move up to ``n_pages`` tail pages to host memory.

        The physical pages
        are freed but their tokens stay cached (swap-in restores them).
        Shared pages stop the walk (their device copy serves other
        requests). Returns the freed physical ids.
        """
        have = self.pages.get(rid, [])
        freed = []
        for _ in range(min(n_pages, len(have))):
            if self.refcount.get(have[-1], 1) > 1:
                break
            pid = have.pop()
            if self._release_ref(pid):
                freed.append(pid)
        if freed:
            self.host_pages[rid] = self.host_pages.get(rid, 0) + len(freed)
        return freed

    def swap_in(self, rid: int) -> int:
        """Re-allocate physical pages for host-swapped tail pages.

        Returns the number of pages brought back (0 if none or if the pool
        cannot hold them — caller must evict first). Atomic: a failed
        swap-in leaves ``pages``/``host_pages`` untouched.
        """
        n = self.host_pages.get(rid, 0)
        if not n:
            return 0
        got = self._take_pages(n)
        if got is None:
            return 0
        self.pages.setdefault(rid, []).extend(got)
        self.host_pages[rid] = 0
        return n

    # -- lifecycle -------------------------------------------------------
    def resume(self, rid: int) -> int:
        """Copy-on-admit: re-link the retained prefix on re-admission.

        A block-table write, no cache copy. Returns retained token count.
        """
        return self.resident_tokens(rid)

    def free_request(self, rid: int) -> list[int]:
        """Drop all of ``rid``'s references and bookkeeping.

        Returns the
        physical ids that left the used set: shared pages stay with their
        other owners (and are not returned), while indexed pages are
        returned but park in the reusable pool — still warm for future
        prefix hits, device-reset only if later reclaimed.
        """
        freed = [pid for pid in self.pages.pop(rid, [])
                 if self._release_ref(pid)]
        self.host_pages.pop(rid, None)
        self.cached_tokens.pop(rid, None)
        return freed

    # -- migration export/import -----------------------------------------
    def export_request(self, rid: int) -> dict:
        """Detach ``rid`` for migration.

        Snapshots its footprint, then drops
        every reference exactly like :meth:`free_request` (shared pages
        stay with their other owners; indexed pages park reusable). The
        source side therefore ends zero-leak by construction — the caller
        ships the snapshot plus, in real mode, the gathered page payload.
        Returns ``{"tokens", "resident_pages", "host_pages"}``.
        """
        snap = {"tokens": self.cached_tokens.get(rid, 0),
                "resident_pages": self.resident_pages(rid),
                "host_pages": self.host_pages.get(rid, 0)}
        self.free_request(rid)
        return snap

    def import_request(self, rid: int, tokens: int) -> bool:
        """Adopt a migrated request.

        Allocates fresh private pages covering
        ``tokens`` prefix tokens and marks them materialized. Imported
        pages are unshared (refcount 1) and unindexed — COW/index state
        never crosses replicas; the destination may re-register the
        prompt itself later. Atomic like :meth:`ensure`: returns False
        (allocating nothing) on pool exhaustion, in which case the caller
        falls back to re-prefilling from scratch.
        """
        if self.pages.get(rid) or self.host_pages.get(rid):
            raise ValueError(f"import for rid {rid}: already owns pages")
        if tokens <= 0:
            return True
        if not self.ensure(rid, tokens):
            return False
        self.note_cached(rid, tokens)
        return True

    # -- cross-request prefix cache --------------------------------------
    def match_prefix(self, tokens) -> tuple[list[int], int]:
        """Longest chain of cached full pages matching ``tokens``.

        Pure lookup (no refcount or LRU side effects): walks page-sized
        blocks of ``tokens`` through the chained hash index and returns
        ``(physical_ids, matched_token_count)``. The chained key — each
        block hashed against its parent's *physical id* — makes a hit on
        block j a proof that blocks 0..j all match, with one dict probe
        per block.
        """
        if not self.prefix_cache:
            return [], 0
        ps = self.page_size
        parent, pids = 0, []
        for j in range(len(tokens) // ps):
            pid = self._index.get((parent, tuple(tokens[j * ps:(j + 1) * ps])))
            if pid is None:
                break
            pids.append(pid)
            parent = pid
        return pids, len(pids) * ps

    def match_len(self, tokens) -> int:
        """Matched-prefix token count only (the router's affinity probe)."""
        return self.match_prefix(tokens)[1]

    def link_prefix(self, rid: int, tokens) -> int:
        """Link the longest cached prefix of ``tokens`` into ``rid``.

        Refcount bumps and block-table writes, no prefill compute.
        Only valid before ``rid`` owns any pages (fresh admission).
        Returns the number of prefix tokens now materialized for ``rid``.
        """
        if not self.prefix_cache or self.pages.get(rid):
            return 0
        pids, hit = self.match_prefix(tokens)
        if not pids:
            return 0
        for pid in pids:
            if self.refcount.get(pid, 0) == 0:
                self._used += 1                 # warm page back in use
            self.refcount[pid] = self.refcount.get(pid, 0) + 1
            self._reusable.pop(pid, None)
        self.pages[rid] = list(pids)
        self.cached_tokens[rid] = hit
        return hit

    def register_prefix(self, rid: int, tokens, upto: int) -> int:
        """Publish ``rid``'s full prompt pages into the hash index.

        Later requests can then link them. ``tokens`` is the prompt;
        only pages fully covered by ``min(upto, len(tokens))`` written
        tokens are registered (partial tail pages never enter the index,
        so indexed pages are immutable by construction). Duplicate content
        chains through the existing canonical page instead of forking the
        index. Returns how many pages were newly registered.
        """
        if not self.prefix_cache:
            return 0
        ps = self.page_size
        have = self.pages.get(rid, ())
        n_full = min(upto, len(tokens)) // ps
        parent, registered = 0, 0
        for j in range(min(n_full, len(have))):
            pid = have[j]
            if pid in self._key_of:             # already canonical
                parent = pid
                continue
            key = (parent, tuple(tokens[j * ps:(j + 1) * ps]))
            canon = self._index.get(key)
            if canon is not None:               # duplicate content: chain
                parent = canon                  # through the canonical page
                continue
            self._index[key] = pid
            self._key_of[pid] = key
            self._kids.setdefault(parent, set()).add(pid)
            parent = pid
            registered += 1
        if registered:
            self.index_gen += 1
        return registered

    def make_writable(self, rid: int, from_token: int) -> list[tuple[int, int]]:
        """Copy-on-write guard before KV writes.

        Gives ``rid`` private copies of any shared
        (refcount > 1) pages covering positions >= ``from_token``, so the
        upcoming KV writes never mutate a page other requests attend to.
        Returns the ``(src, dst)`` page copies performed (also queued for
        the device in the COW log). In the standard admission flow shared
        pages are always full and writes land beyond them, so this is a
        no-op backstop — but it is what makes the immutability invariant
        enforced rather than emergent.
        """
        if not self.prefix_cache:
            return []
        have = self.pages.get(rid, [])
        ops = []
        for j in range(from_token // self.page_size, len(have)):
            pid = have[j]
            if self.refcount.get(pid, 1) <= 1:
                continue
            new = self._take_page()
            if new is None:
                raise RuntimeError("paged KV pool exhausted during "
                                   "copy-on-write")
            self.refcount[pid] -= 1
            have[j] = new
            ops.append((pid, new))
            if self.track_resets:
                self._cow_log.append((pid, new))
        return ops

    def _deregister(self, pid: int):
        """Remove ``pid`` from the hash index, cascading to descendants.

        Registered descendants' chained keys name ``pid`` as parent, so once it
        is reclaimed (and its id possibly reused for other content) they
        must not be matchable. Unreferenced descendants move from the
        reusable pool to the free list.
        """
        key = self._key_of.pop(pid, None)
        if key is None:
            return
        self.index_gen += 1
        if self._index.get(key) == pid:
            del self._index[key]
        self._kids.get(key[0], set()).discard(pid)
        for kid in list(self._kids.pop(pid, ())):
            self._deregister(kid)
            if self.refcount.get(kid) == 0:
                del self._reusable[kid]
                del self.refcount[kid]
                if self.bounded:
                    self.free.append(kid)
                if self.track_resets:
                    self._reset_log.append(kid)

    def pop_resets(self) -> list[int]:
        """Drain the device-invalidation queue.

        Yields page ids whose content is dead: freed outright or
        reclaimed from the reusable pool.
        """
        out, self._reset_log = self._reset_log, []
        return out

    def pop_cow_copies(self) -> list[tuple[int, int]]:
        """Drain the pending (src, dst) device page copies from COW."""
        out, self._cow_log = self._cow_log, []
        return out


class SlotPool:
    """Host-side slot bookkeeping + device-side cache reset."""

    def __init__(self, model, slots: int, max_len: int):
        _silence_cpu_donation_warning()    # covers the donating reset jits
        self.model = model
        self.cfg = model.cfg
        self.n_slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.slot_of: dict[int, int] = {}
        self.free = list(range(slots))[::-1]
        self._dirty: list[int] = []              # slots needing device reset

    # -- allocation ------------------------------------------------------
    def assign(self, rid: int) -> int:
        """Claim a free slot for ``rid``; returns the slot index."""
        slot = self.free.pop()
        self.slot_of[rid] = slot
        return slot

    def release(self, rid: int) -> int:
        """Return ``rid``'s slot to the free list, queueing a device reset."""
        slot = self.slot_of.pop(rid)
        self.free.append(slot)
        self._dirty.append(slot)
        return slot

    def flush_resets(self):
        """Apply pending slot resets on device (batched into one call)."""
        if not self._dirty:
            return
        mask = jnp.zeros((self.n_slots,), bool).at[
            jnp.asarray(self._dirty, jnp.int32)].set(True)
        self.cache = _reset_slots(self.cache, mask)
        self._dirty.clear()

    # -- accounting --------------------------------------------------------
    def bytes_for(self, context_len: int) -> int:
        """Cache bytes this pool charges a context (clamped to max_len)."""
        return bytes_for_context(self.cfg, min(context_len, self.max_len))

    def used_slots(self) -> int:
        """Slots currently assigned."""
        return self.n_slots - len(self.free)


class PagedSlotPool(SlotPool):
    """Slot pool whose global-attention KV lives in shared device pages.

    Pages are addressed through per-slot block tables.

    Slots still carry the per-sequence state that cannot be paged (lengths,
    SSM state, ring buffers, cross caches); the :class:`BlockManager` owns
    the page pool. With ``retain=True`` (pure-attention archs) a preempted
    request keeps its pages across release/assign — resumption re-points
    the new slot's block-table row at them and restores ``lengths``, so
    decode continues over the retained prefix with zero recompute.
    """

    def __init__(self, model, slots: int, max_len: int, page_size: int = 16,
                 retain: bool | None = None, prefix_cache: bool = False):
        _silence_cpu_donation_warning()    # covers the donating reset jits
        self.page_size = page_size
        self.pages_per_seq = pages_for_tokens(max_len, page_size)
        self.model = model
        self.cfg = model.cfg
        self.n_slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len, kv_layout="paged",
                                      page_size=page_size)
        self.slot_of: dict[int, int] = {}
        self.free = list(range(slots))[::-1]
        self._dirty: list[int] = []
        self._dirty_pages: list[int] = []
        self._table_stale = True
        # physical ids 1..N; page 0 is the null page (pkpos stays -1)
        self.blocks = BlockManager(slots * self.pages_per_seq, page_size,
                                   prefix_cache=prefix_cache,
                                   track_resets=True)
        self.table = np.zeros((slots, self.pages_per_seq), np.int32)
        if retain is None:
            retain = supports_page_retention(self.cfg)
        self.retain = retain

    # -- allocation ------------------------------------------------------
    def assign(self, rid: int) -> int:
        """Claim a slot and re-link any retained pages (copy-on-admit)."""
        slot = super().assign(rid)
        self._write_table_row(slot, self.blocks.block_table(rid))
        retained = self.blocks.resume(rid)
        if retained:
            # the slot's pending reset (from its previous occupant) must
            # land before we restore the resumed request's length, or the
            # deferred wipe would clobber it
            self.flush_resets()
            self.cache["lengths"] = self.cache["lengths"].at[slot].set(
                retained)
        return slot

    def release(self, rid: int, retain: bool = False) -> int:
        """Release the slot; with ``retain`` the pages stay for resumption.

        Device invalidation is driven by the block manager's reset log
        (drained in ``flush_resets``), so pages parked in the reusable
        prefix pool keep their contents.
        """
        slot = self.slot_of[rid]
        if not retain:
            self.blocks.free_request(rid)
        self._write_table_row(slot, [])
        return super().release(rid)

    # -- pages -----------------------------------------------------------
    def ensure_pages(self, rid: int, tokens: int) -> bool:
        """Allocate pages for a ``tokens``-long prefix of ``rid``.

        Also refreshes the block-table row. False only on true pool
        exhaustion.
        """
        tokens = min(tokens, self.max_len)
        ok = self.blocks.ensure(rid, tokens)
        if ok and rid in self.slot_of:
            self._write_table_row(self.slot_of[rid],
                                  self.blocks.block_table(rid))
        return ok

    def evict_tail(self, rid: int, n_pages: int) -> list[int]:
        """Tail-evict pages, queueing device invalidation via resets.

        Returns the ids that left the used set.
        """
        freed = self.blocks.evict_tail(rid, n_pages)
        if rid in self.slot_of:
            self._write_table_row(self.slot_of[rid],
                                  self.blocks.block_table(rid))
        return freed

    def make_writable(self, rid: int, from_token: int) -> list:
        """COW guard before KV writes (see `BlockManager.make_writable`).

        Refreshes the table row when pages were swapped for copies.
        """
        ops = self.blocks.make_writable(rid, from_token)
        if ops and rid in self.slot_of:
            self._write_table_row(self.slot_of[rid],
                                  self.blocks.block_table(rid))
        return ops

    # -- migration export/import -----------------------------------------
    def export_pages(self, rid: int):
        """Gather ``rid``'s resident page payload for shipping.

        One batched device->host copy of pk/pv/pkpos per paged
        layer run — the host bounce of
        a KV handoff ships the whole request at once instead of a copy
        per page. Bookkeeping is untouched (pair with
        ``blocks.export_request``). Returns None when nothing is
        resident.
        """
        pids = self.blocks.block_table(rid)
        if not pids:
            return None
        self.flush_resets()        # pending wipes/COW must land first
        idx = jnp.asarray(pids, jnp.int32)
        payload = {}
        for key, run in self.cache.items():
            if not key.startswith("run_"):
                continue
            payload[key] = tuple(
                {leaf: sub[leaf][:, idx] for leaf in ("pk", "pv", "pkpos")}
                if "pkpos" in sub else None
                for sub in run)
        return jax.device_get(payload)   # one transfer, whole pytree

    def import_pages(self, rid: int, tokens: int, payload) -> bool:
        """Adopt a migrated request's KV.

        Allocates fresh pages covering
        ``tokens`` (clamped to ``max_len``) and scatters the shipped
        payload into them with one batched host->device write per layer
        run. ``flush_resets`` runs first so a queued wipe of a recycled
        physical page cannot land after the import and destroy the new
        content. False on pool exhaustion (nothing allocated; the caller
        re-prefills from scratch).
        """
        self.flush_resets()
        tokens = min(tokens, self.max_len)
        if not self.blocks.import_request(rid, tokens):
            return False
        pids = self.blocks.block_table(rid)
        if payload is None or not pids:
            return True
        dst = jnp.asarray(pids, jnp.int32)
        n = len(pids)            # may be < shipped pages (clamp/partial)
        new = dict(self.cache)
        for key, run in self.cache.items():
            if not key.startswith("run_"):
                continue
            subs = []
            for sub, pay in zip(run, payload[key]):
                if "pkpos" in sub and pay is not None:
                    sub = dict(sub)
                    for leaf in ("pk", "pv", "pkpos"):
                        sub[leaf] = sub[leaf].at[:, dst].set(
                            jnp.asarray(pay[leaf][:, :n]))
                subs.append(sub)
            new[key] = tuple(subs)
        self.cache = new
        return True

    def _write_table_row(self, slot: int, pages: list[int]):
        row = np.zeros((self.pages_per_seq,), np.int32)
        row[:len(pages)] = pages
        self.table[slot] = row
        self._table_stale = True

    # -- device sync -----------------------------------------------------
    def flush_resets(self):
        """Apply pending resets and COW copies; sync the block table.

        Resets run before copies so a page reclaimed
        from the reusable pool and immediately used as a COW destination
        ends up holding the copied content.
        """
        super().flush_resets()
        self._dirty_pages.extend(self.blocks.pop_resets())
        if self._dirty_pages:
            n_pages = 1 + self.blocks.num_pages
            mask = jnp.zeros((n_pages,), bool).at[
                jnp.asarray(self._dirty_pages, jnp.int32)].set(True)
            self.cache = _reset_pages(self.cache, mask)
            self._dirty_pages.clear()
        cow = self.blocks.pop_cow_copies()
        if cow:
            src = jnp.asarray([s for s, _ in cow], jnp.int32)
            dst = jnp.asarray([d for _, d in cow], jnp.int32)
            self.cache = _copy_pages(self.cache, src, dst)
        if self._table_stale:
            self.cache["block_table"] = jnp.asarray(self.table)
            self._table_stale = False

    # -- accounting ------------------------------------------------------
    def bytes_for(self, context_len: int) -> int:
        """Page-rounded cache bytes for a context (clamped to max_len)."""
        return paged_bytes_for_context(
            self.cfg, min(context_len, self.max_len), self.page_size)


@functools.partial(jax.jit, donate_argnames=("cache",))
def _reset_pages(cache, page_mask):
    """Invalidate freed pages: pkpos=-1 so stale entries never attend.

    The cache is donated (reset queue is donation-safe): the pool holds
    the only live reference and immediately replaces it with the result,
    so XLA can flip pkpos in place instead of copying the page pool.
    """
    new = dict(cache)
    for key, run in cache.items():
        if not key.startswith("run_"):
            continue
        subs = []
        for sub in run:
            if "pkpos" in sub:
                sub = dict(sub)
                sub["pkpos"] = jnp.where(page_mask[None, :, None], -1,
                                         sub["pkpos"])
            subs.append(sub)
        new[key] = tuple(subs)
    return new


@functools.partial(jax.jit, donate_argnames=("cache",))
def _copy_pages(cache, src, dst):
    """Copy-on-write: duplicate physical pages ``src`` into ``dst``.

    K/V payload and pkpos copy across every paged layer run. Donated like
    ``_reset_pages`` — the pool holds the only live cache reference.
    """
    new = dict(cache)
    for key, run in cache.items():
        if not key.startswith("run_"):
            continue
        subs = []
        for sub in run:
            if "pkpos" in sub:
                sub = dict(sub)
                for leaf in ("pk", "pv", "pkpos"):
                    sub[leaf] = sub[leaf].at[:, dst].set(sub[leaf][:, src])
            subs.append(sub)
        new[key] = tuple(subs)
    return new


@functools.partial(jax.jit, donate_argnames=("cache",))
def _reset_slots(cache, mask):
    """Invalidate slots: kpos=-1, lengths=0, SSM state zeroed.

    Donates the cache like ``_reset_pages`` (see note there).
    """
    def reset_sub(r):
        """Wipe one layer's per-slot recurrent leaves under the mask."""
        r = dict(r)
        if "kpos" in r:
            r["kpos"] = jnp.where(mask[None, :, None], -1, r["kpos"])
        for leaf in ("ssm_state", "conv_buf"):
            if leaf in r:
                m = mask.reshape((1, -1) + (1,) * (r[leaf].ndim - 2))
                r[leaf] = jnp.where(m, 0, r[leaf].astype(r[leaf].dtype))
        return r

    new = dict(cache)
    new["lengths"] = jnp.where(mask, 0, cache["lengths"])
    for key, run in cache.items():
        if not key.startswith("run_"):
            continue
        new[key] = tuple(reset_sub(sub) for sub in run)
    return new
