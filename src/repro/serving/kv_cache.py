"""Slot-pool KV cache manager (the TPU-native replacement for PagedAttention).

A fixed pool of ``slots`` sequence slots is allocated once per engine
(static shapes for XLA); requests map onto slots for their lifetime in the
batch. The OOM mode is the paper's choice: *discard and recompute* — a
preempted request's slot is released, its cache garbage-collected lazily by
``reset_slots`` (kpos=-1 kills stale attention entries; SSM state zeroed),
and on re-admission the engine re-prefills prompt + generated-so-far.

``bytes_for`` is the arch-aware preemption-cost function m(age) from
DESIGN.md section 4: dense KV grows linearly with context, sliding-window
layers clamp at the window, SSM layers cost O(1) state. The scheduler uses
it both for the admission budget and (implicitly, via the paper's C*r rule)
for limiting preemption.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import (KIND_ATTN, KIND_HYBRID, KIND_LOCAL, KIND_MOE,
                          KIND_SSM, ModelConfig)
from repro.models.ssm import ssm_dims


def dtype_bytes(cfg: ModelConfig) -> int:
    return jnp.dtype(cfg.dtype).itemsize


def bytes_per_token_kind(cfg: ModelConfig, kind: str) -> int:
    """KV bytes one token adds in one layer of this kind (0 for SSM)."""
    if kind == KIND_SSM:
        return 0
    if cfg.kv_quant:     # int8 payload + f32 per-(token,head) scales
        return 2 * (cfg.kv_dim * 1 + cfg.num_kv_heads * 4)
    return 2 * cfg.kv_dim * dtype_bytes(cfg)


def ssm_state_bytes(cfg: ModelConfig) -> int:
    d_in, nh, conv_ch = ssm_dims(cfg)
    n = cfg.ssm_groups * cfg.ssm_state
    return 4 * (nh * cfg.ssm_head_dim * n) + 4 * (cfg.ssm_conv - 1) * conv_ch


def bytes_for_context(cfg: ModelConfig, context_len: int) -> int:
    """Total per-request cache bytes at a given context length."""
    total = 0
    for kind in cfg.layer_kinds:
        per_tok = bytes_per_token_kind(cfg, kind)
        if kind in (KIND_LOCAL, KIND_HYBRID) and cfg.sliding_window:
            total += per_tok * min(context_len, cfg.sliding_window)
        else:
            total += per_tok * context_len
        if kind in (KIND_SSM, KIND_HYBRID):
            total += ssm_state_bytes(cfg)
    if cfg.cross_attention and cfg.encoder_seq:
        total += (cfg.num_layers * 2 * cfg.kv_dim * dtype_bytes(cfg)
                  * cfg.encoder_seq)
    return total


class SlotPool:
    """Host-side slot bookkeeping + device-side cache reset."""

    def __init__(self, model, slots: int, max_len: int):
        self.model = model
        self.cfg = model.cfg
        self.n_slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.slot_of: dict[int, int] = {}
        self.free = list(range(slots))[::-1]
        self._dirty: list[int] = []              # slots needing device reset

    # -- allocation ------------------------------------------------------
    def assign(self, rid: int) -> int:
        slot = self.free.pop()
        self.slot_of[rid] = slot
        return slot

    def release(self, rid: int) -> int:
        slot = self.slot_of.pop(rid)
        self.free.append(slot)
        self._dirty.append(slot)
        return slot

    def flush_resets(self):
        """Apply pending slot resets on device (batched into one call)."""
        if not self._dirty:
            return
        mask = jnp.zeros((self.n_slots,), bool).at[
            jnp.asarray(self._dirty, jnp.int32)].set(True)
        self.cache = _reset_slots(self.cache, mask)
        self._dirty.clear()

    # -- accounting --------------------------------------------------------
    def bytes_for(self, context_len: int) -> int:
        return bytes_for_context(self.cfg, min(context_len, self.max_len))

    def used_slots(self) -> int:
        return self.n_slots - len(self.free)


@jax.jit
def _reset_slots(cache, mask):
    """Invalidate slots: kpos=-1, lengths=0, SSM state zeroed."""

    def reset_sub(r):
        r = dict(r)
        if "kpos" in r:
            r["kpos"] = jnp.where(mask[None, :, None], -1, r["kpos"])
        for leaf in ("ssm_state", "conv_buf"):
            if leaf in r:
                m = mask.reshape((1, -1) + (1,) * (r[leaf].ndim - 2))
                r[leaf] = jnp.where(m, 0, r[leaf].astype(r[leaf].dtype))
        return r

    new = dict(cache)
    new["lengths"] = jnp.where(mask, 0, cache["lengths"])
    for key, run in cache.items():
        if not key.startswith("run_"):
            continue
        new[key] = tuple(reset_sub(sub) for sub in run)
    return new
