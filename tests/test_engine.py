"""Serving-engine integration tests: policy behaviour, cache accounting,
real-model end-to-end, preemption/recompute semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.config import get_config, get_smoke_config
from repro.serving.engine import EngineConfig, Engine, run_policy
from repro.serving.kv_cache import SlotPool, bytes_for_context
from repro.serving.predictors import OraclePredictor, ProbePredictor
from repro.serving.request import Request
from repro.serving.workload import WorkloadConfig, generate

CFG = get_config("granite-3-8b")


def small_workload(n=60, rate=20.0, seed=0, burst=False):
    wc = WorkloadConfig(n_requests=n, request_rate=rate, seed=seed,
                        burst=burst, vocab=CFG.vocab_size)
    return generate(wc)


def test_all_requests_finish_every_policy():
    reqs = small_workload()
    for pol in ("fcfs", "sjf", "srpt", "trail", "trail-bert"):
        s = run_policy(CFG, pol, reqs, mode="sim", seed=1)
        assert len(s.latencies) == len(reqs), pol
        assert all(l > 0 for l in s.latencies)
        assert all(t > 0 for t in s.ttfts)


def test_trail_beats_fcfs_mean_latency():
    reqs = small_workload(n=200, rate=14.0, seed=2)
    fcfs = run_policy(CFG, "fcfs", reqs, mode="sim", seed=3).summary()
    trail = run_policy(CFG, "trail", reqs, mode="sim", seed=3).summary()
    # the paper's headline: 1.66-2.01x mean latency, big TTFT wins
    assert trail["mean_latency"] < fcfs["mean_latency"]
    assert trail["mean_ttft"] < fcfs["mean_ttft"]


def test_fcfs_no_preemptions_trail_some():
    reqs = small_workload(n=150, rate=20.0, seed=4)
    fcfs = run_policy(CFG, "fcfs", reqs, mode="sim", seed=5)
    trail = run_policy(CFG, "trail", reqs, mode="sim", seed=5)
    assert fcfs.n_preemptions == 0
    assert trail.n_preemptions > 0
    assert trail.recomputed_tokens > 0      # discard-and-recompute mode


def test_memory_budget_respected():
    reqs = small_workload(n=80, rate=30.0, seed=6)
    budget = 40 * bytes_for_context(CFG, 256)
    s = run_policy(CFG, "trail", reqs, mode="sim", seed=7,
                   mem_budget=budget, max_batch=64)
    assert s.peak_mem_bytes <= budget * 1.25   # pinned growth slack
    assert len(s.latencies) == len(reqs)


def test_burst_scenario_all_finish():
    reqs = small_workload(n=100, rate=1.0, seed=8, burst=True)
    for pol in ("fcfs", "trail"):
        s = run_policy(CFG, pol, reqs, mode="sim", seed=9)
        assert len(s.latencies) == len(reqs)


def test_probe_interval_throttling():
    """Beyond-paper: refining every k-th token must still complete all
    requests and stay within a few % of per-token refinement latency."""
    reqs = small_workload(n=100, rate=14.0, seed=12)
    res = {}
    for k in (1, 4, 16):
        s = run_policy(CFG, "trail", reqs, mode="sim", seed=13,
                       probe_interval=k)
        assert len(s.latencies) == len(reqs), k
        res[k] = s.summary()["mean_latency"]
    assert res[16] < res[1] * 1.15


def test_mlfq_policy_runs_and_preempts():
    """FastServe-style MLFQ: prediction-free, demotes long requests."""
    reqs = small_workload(n=120, rate=20.0, seed=14)
    s = run_policy(CFG, "mlfq", reqs, mode="sim", seed=15)
    assert len(s.latencies) == len(reqs)
    assert s.n_preemptions > 0
    fcfs = run_policy(CFG, "fcfs", reqs, mode="sim", seed=15)
    assert s.summary()["mean_ttft"] < fcfs.summary()["mean_ttft"]


def test_swap_oom_mode():
    """Swap keeps prefill progress (no recompute) but pays DMA time."""
    from repro.serving.kv_cache import bytes_for_context
    reqs = small_workload(n=100, rate=25.0, seed=16)
    budget = 8 * bytes_for_context(CFG, 320)
    disc = run_policy(CFG, "trail", reqs, mode="sim", seed=17,
                      max_batch=48, mem_budget=budget, oom_mode="discard")
    swap = run_policy(CFG, "trail", reqs, mode="sim", seed=17,
                      max_batch=48, mem_budget=budget, oom_mode="swap")
    assert disc.recomputed_tokens > 0 and disc.swapped_bytes == 0
    assert swap.swapped_bytes > 0 and swap.recomputed_tokens == 0
    assert len(swap.latencies) == len(reqs)
    with pytest.raises(ValueError):
        from repro.serving.engine import Engine, EngineConfig
        Engine(CFG, EngineConfig(mode="real", oom_mode="swap"))


def test_c_sweep_changes_preemptions():
    reqs = small_workload(n=150, rate=20.0, seed=10)
    pre = {}
    for c in (0.2, 0.8, 1.0):
        s = run_policy(CFG, "trail", reqs, mode="sim", seed=11, c_limit=c)
        pre[c] = s.n_preemptions
    assert pre[0.2] <= pre[0.8] <= pre[1.0]


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------

def test_slot_pool_reset_invalidates_cache():
    cfg = get_smoke_config("granite-3-8b")
    from repro.models.model import Model
    m = Model(cfg)
    m.init(jax.random.key(0))
    pool = SlotPool(m, slots=3, max_len=16)
    s0 = pool.assign(7)
    pool.cache["lengths"] = pool.cache["lengths"].at[s0].set(9)
    pool.release(7)
    pool.flush_resets()
    assert int(pool.cache["lengths"][s0]) == 0
    for k, run in pool.cache.items():
        if not k.startswith("run_"):
            continue
        for sub in run:
            if "kpos" in sub:
                assert bool(jnp.all(sub["kpos"][:, s0] == -1))


@given(st.lists(st.integers(0, 9), min_size=1, max_size=40))
@settings(max_examples=50, deadline=None)
def test_slot_pool_assign_release_invariant(ops):
    cfg = get_smoke_config("granite-3-8b")
    from repro.models.model import Model
    m = Model(cfg)
    m.init(jax.random.key(0))
    pool = SlotPool(m, slots=4, max_len=8)
    held = set()
    for rid in ops:
        if rid in held:
            pool.release(rid)
            held.discard(rid)
        elif len(held) < 4:
            pool.assign(rid)
            held.add(rid)
    assert pool.used_slots() == len(held)
    assert len(set(pool.slot_of.values())) == len(held)  # distinct slots
    assert set(pool.slot_of) == held


def test_bytes_for_context_memoized():
    """bytes accounting is lru_cached on the frozen config: repeat lookups
    (one per entry per select_batch call) must hit the cache, and the
    cached value must match a fresh computation."""
    from repro.serving.kv_cache import paged_bytes_for_context
    v1 = bytes_for_context(CFG, 12345)
    h0 = bytes_for_context.cache_info().hits
    assert bytes_for_context(CFG, 12345) == v1
    assert bytes_for_context.cache_info().hits == h0 + 1
    p1 = paged_bytes_for_context(CFG, 12345, 16)
    assert paged_bytes_for_context(CFG, 12345, 16) == p1
    assert p1 >= v1        # page round-up can only add bytes (dense arch)


def test_bytes_for_context_arch_awareness():
    dense = get_config("granite-3-8b")
    ssm = get_config("mamba2-370m")
    g3 = get_config("gemma3-1b")
    # dense grows linearly; SSM is constant; windowed clamps
    assert bytes_for_context(dense, 2048) > bytes_for_context(dense, 1024)
    assert bytes_for_context(ssm, 2048) == bytes_for_context(ssm, 64)
    w = g3.sliding_window
    grow = bytes_for_context(g3, 8 * w) - bytes_for_context(g3, 4 * w)
    # only the few global layers keep growing past the window
    n_global = sum(k == "attn" for k in g3.layer_kinds)
    assert grow == n_global * 2 * g3.kv_dim * 2 * 4 * w


# ---------------------------------------------------------------------------
# real mode end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.real
@pytest.mark.parametrize("arch", ["trail-llama", "mamba2-370m"])
def test_real_mode_end_to_end(arch):
    cfg = get_smoke_config(arch)
    from repro.models.model import Model
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    wc = WorkloadConfig(n_requests=6, request_rate=100.0, seed=1,
                        vocab=cfg.vocab_size, prompt_mean=8.0,
                        out_median=6.0, max_out=16)
    reqs = generate(wc)
    pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                          embed_table=params["embed"])
    s = run_policy(cfg, "trail", reqs, max_batch=3, mode="real",
                   model=m, params=params, predictor=pred)
    assert len(s.latencies) == len(reqs)
    # every request generated its oracle-many tokens
    assert s.iterations > 0
