"""Per-kernel shape/dtype sweeps: pallas_call(interpret=True) vs ref.py."""

import jax
import jax.numpy as jnp
import pytest

import numpy as np

from repro.config import ProbeConfig
from repro.core.smoothing import transition_matrix
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_decode_attention import (
    paged_decode_attention, paged_decode_attention_multi)
from repro.kernels.probe import probe_update
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.key(0)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,hd,win,cap", [
    (2, 64, 4, 2, 32, 0, 0.0),
    (1, 100, 4, 1, 64, 0, 0.0),      # MQA + ragged S
    (2, 128, 8, 8, 32, 32, 0.0),     # MHA + sliding window
    (1, 96, 4, 2, 32, 0, 50.0),      # softcap
    (2, 80, 4, 2, 32, 24, 30.0),     # window + softcap
])
def test_flash_attention(B, S, H, KH, hd, win, cap, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S + H), 3)
    q = rand(ks[0], (B, S, H, hd), dtype)
    k = rand(ks[1], (B, S, KH, hd), dtype)
    v = rand(ks[2], (B, S, KH, hd), dtype)
    o = flash_attention(q, k, v, window=win, softcap=cap,
                        block_q=32, block_k=32, interpret=True)
    r = ref.flash_attention_ref(q, k, v, window=win, softcap=cap)
    assert o.dtype == q.dtype
    err = jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)))
    assert float(err) < TOL[dtype], float(err)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,M,H,KH,hd,win,cap", [
    (2, 64, 4, 2, 32, 0, 0.0),
    (3, 100, 4, 1, 64, 0, 0.0),
    (2, 128, 8, 8, 32, 48, 0.0),
    (1, 96, 4, 2, 32, 0, 50.0),
])
def test_decode_attention(B, M, H, KH, hd, win, cap, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, M + H), 5)
    q = rand(ks[0], (B, H, hd), dtype)
    k = rand(ks[1], (B, M, KH, hd), dtype)
    v = rand(ks[2], (B, M, KH, hd), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, M)
    kpos = jnp.where(jnp.arange(M)[None] < lengths[:, None],
                     jnp.arange(M)[None], -1)
    o = decode_attention(q, k, v, kpos, lengths, window=win, softcap=cap,
                         block_k=32, interpret=True)
    r = ref.decode_attention_ref(q, k, v, kpos, lengths, window=win,
                                 softcap=cap)
    err = jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)))
    assert float(err) < TOL[dtype], float(err)


def _paged_fixture(key, B, H, KH, hd, ps, pmax, dtype):
    """Random page pool + scrambled per-sequence block tables.

    Pages are assigned to sequences in a random order so physical layout
    is non-contiguous; unallocated table entries point at null page 0."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 1 << 30)))
    P = 1 + B * pmax
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (B, H, hd), dtype)
    k = rand(ks[1], (P, ps, KH, hd), dtype)
    v = rand(ks[2], (P, ps, KH, hd), dtype)
    lengths = rng.integers(1, pmax * ps, size=(B,))
    bt = np.zeros((B, pmax), np.int32)
    kpos = np.full((P, ps), -1, np.int32)
    perm = rng.permutation(np.arange(1, P))
    pi = 0
    for b in range(B):
        for lp in range(-(-int(lengths[b]) // ps)):
            pid = int(perm[pi]); pi += 1
            bt[b, lp] = pid
            n = min(ps, int(lengths[b]) - lp * ps)
            kpos[pid, :n] = np.arange(lp * ps, lp * ps + n)
    return (q, k, v, jnp.asarray(kpos), jnp.asarray(bt),
            jnp.asarray(lengths - 1, jnp.int32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KH,hd,ps,pmax,win,cap", [
    (2, 4, 2, 32, 16, 4, 0, 0.0),
    (3, 4, 1, 64, 8, 6, 0, 0.0),       # MQA, small pages
    (2, 8, 8, 32, 16, 3, 24, 0.0),     # MHA + sliding window
    (1, 4, 2, 32, 8, 5, 0, 50.0),      # softcap
])
def test_paged_decode_attention(B, H, KH, hd, ps, pmax, win, cap, dtype):
    key = jax.random.fold_in(KEY, B * 1000 + pmax * 10 + ps)
    q, k, v, kpos, bt, q_pos = _paged_fixture(key, B, H, KH, hd, ps, pmax,
                                              dtype)
    o = paged_decode_attention(q, k, v, kpos, bt, q_pos, window=win,
                               softcap=cap, interpret=True)
    r = ref.paged_decode_attention_ref(q, k, v, kpos, bt, q_pos,
                                       window=win, softcap=cap)
    assert o.dtype == q.dtype
    err = jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)))
    assert float(err) < max(TOL[dtype], 1e-4), float(err)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,KH,hd,ps,pmax,win,cap", [
    (2, 4, 4, 2, 32, 16, 4, 0, 0.0),
    (3, 2, 4, 1, 64, 8, 6, 0, 0.0),    # MQA, small pages
    (2, 8, 8, 8, 32, 16, 3, 24, 0.0),  # MHA + sliding window
    (1, 3, 4, 2, 32, 8, 5, 0, 50.0),   # softcap
])
def test_paged_decode_attention_multi(B, T, H, KH, hd, ps, pmax, win, cap,
                                      dtype):
    """Multi-query variant (decode megasteps / chunked prefill): the last
    T cached positions of each sequence attend over its pages together."""
    key = jax.random.fold_in(KEY, B * 999 + T * 31 + ps)
    q1, k, v, kpos, bt, last_pos = _paged_fixture(key, B, H, KH, hd, ps,
                                                  pmax, dtype)
    q = rand(jax.random.fold_in(key, 7), (B, T, H, hd), dtype)
    # query positions: the T trailing tokens (clamped >= 0 via fixture
    # lengths >= 1; earlier-than-start rows mask to inactive -1)
    q_pos = last_pos[:, None] - jnp.arange(T - 1, -1, -1, dtype=jnp.int32)
    q_pos = jnp.where(q_pos >= 0, q_pos, -1)
    o = paged_decode_attention_multi(q, k, v, kpos, bt, q_pos, window=win,
                                     softcap=cap, interpret=True)
    r = ref.paged_decode_attention_multi_ref(q, k, v, kpos, bt, q_pos,
                                             window=win, softcap=cap)
    assert o.dtype == q.dtype
    err = jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)))
    assert float(err) < max(TOL[dtype], 1e-4), float(err)


def test_paged_multi_t1_matches_single_query():
    """T=1 multi-query degenerates to the single-query kernel exactly."""
    B, H, KH, hd, ps, pmax = 2, 4, 2, 32, 8, 4
    q, k, v, kpos, bt, q_pos = _paged_fixture(
        jax.random.fold_in(KEY, 123), B, H, KH, hd, ps, pmax, jnp.float32)
    o_multi = paged_decode_attention_multi(q[:, None], k, v, kpos, bt,
                                           q_pos[:, None], interpret=True)
    o_single = paged_decode_attention(q, k, v, kpos, bt, q_pos,
                                      interpret=True)
    assert float(jnp.max(jnp.abs(o_multi[:, 0] - o_single))) == 0.0


def test_paged_matches_contiguous_decode():
    """The paged reference over a gathered view must equal the contiguous
    decode reference on the same logical cache (acceptance: atol=1e-4)."""
    B, H, KH, hd, ps, pmax = 2, 4, 2, 32, 8, 4
    q, k, v, kpos, bt, q_pos = _paged_fixture(
        jax.random.fold_in(KEY, 77), B, H, KH, hd, ps, pmax, jnp.float32)
    k_seq = k[bt].reshape(B, -1, KH, hd)
    v_seq = v[bt].reshape(B, -1, KH, hd)
    kpos_seq = kpos[bt].reshape(B, -1)
    o_paged = paged_decode_attention(q, k, v, kpos, bt, q_pos,
                                     interpret=True)
    o_contig = decode_attention(q, k_seq, v_seq, kpos_seq, q_pos,
                                block_k=32, interpret=True)
    err = float(jnp.max(jnp.abs(o_paged - o_contig)))
    assert err < 1e-4, err


def test_paged_decode_attention_null_pages_no_nan():
    """A sequence whose table is all null pages must stay finite."""
    B, H, KH, hd, ps, pmax = 2, 4, 2, 32, 8, 3
    ks = jax.random.split(KEY, 3)
    P = 1 + B * pmax
    q = rand(ks[0], (B, H, hd), jnp.float32)
    k = rand(ks[1], (P, ps, KH, hd), jnp.float32)
    v = rand(ks[2], (P, ps, KH, hd), jnp.float32)
    kpos = jnp.full((P, ps), -1)
    bt = jnp.zeros((B, pmax), jnp.int32)
    o = paged_decode_attention(q, k, v, kpos, bt,
                               jnp.zeros((B,), jnp.int32), interpret=True)
    assert bool(jnp.all(jnp.isfinite(o)))


def test_decode_attention_empty_rows_no_nan():
    """Rows with an empty cache must produce finite output (NaN-free)."""
    B, M, H, KH, hd = 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, hd), jnp.float32)
    k = rand(ks[1], (B, M, KH, hd), jnp.float32)
    v = rand(ks[2], (B, M, KH, hd), jnp.float32)
    kpos = jnp.full((B, M), -1)                     # nothing valid
    o = decode_attention(q, k, v, kpos, jnp.zeros((B,), jnp.int32),
                         block_k=32, interpret=True)
    assert bool(jnp.all(jnp.isfinite(o)))


@pytest.mark.parametrize("B,L,nh,hp,N,chunk", [
    (2, 64, 4, 32, 16, 16),
    (1, 128, 2, 64, 32, 32),
    (2, 96, 3, 32, 8, 32),
])
def test_ssd_scan(B, L, nh, hp, N, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, L + nh), 5)
    x = jax.random.normal(ks[0], (B, L, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y_ref, s_ref = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    y, s = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-3
    assert float(jnp.max(jnp.abs(s - s_ref))) < 1e-3


def test_ssd_scan_initial_state_continuation():
    """Scanning [a;b] equals scanning a then b from a's final state."""
    B, L, nh, hp, N = 1, 64, 2, 32, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, L, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y_full, s_full = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    h = L // 2
    y1, s1 = ref.ssd_scan_ref(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h])
    y2, s2 = ref.ssd_scan_ref(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:],
                              init_state=s1)
    assert float(jnp.max(jnp.abs(y2 - y_full[:, h:]))) < 1e-4
    assert float(jnp.max(jnp.abs(s2 - s_full))) < 1e-4


@pytest.mark.parametrize("B,d,hid,k", [(4, 64, 32, 10), (7, 768, 512, 10),
                                       (1, 128, 64, 5)])
def test_probe_kernel(B, d, hid, k):
    ks = jax.random.split(jax.random.fold_in(KEY, B + d), 6)
    tap = jax.random.normal(ks[0], (B, d))
    w1 = jax.random.normal(ks[1], (d, hid)) * 0.1
    b1 = jax.random.normal(ks[2], (hid,)) * 0.1
    w2 = jax.random.normal(ks[3], (hid, k)) * 0.1
    b2 = jax.random.normal(ks[4], (k,)) * 0.1
    qp = jax.nn.softmax(jax.random.normal(ks[5], (B, k)), -1)
    T = jnp.asarray(transition_matrix(ProbeConfig(num_bins=k, max_len=512)),
                    jnp.float32)
    q, p = probe_update(tap, w1, b1, w2, b2, qp, T, block_b=4, interpret=True)
    qr, pr = ref.probe_update_ref(tap, w1, b1, w2, b2, qp, T)
    assert float(jnp.max(jnp.abs(q - qr))) < 1e-5
    assert float(jnp.max(jnp.abs(p - pr))) < 1e-5
    # posteriors remain distributions
    assert float(jnp.max(jnp.abs(jnp.sum(q, -1) - 1.0))) < 1e-5
