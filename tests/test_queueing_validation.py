"""M/G/1 cross-validation: Lemma 1 closed form vs discrete-event sim.

The paper's Appendix-C/D theory (SPRPT with limited preemption, SOAP
decomposition) checked against `core.simulation.simulate` at a grid of
(lam, C) operating points, with multi-seed averaging so the tolerance
can be tight without flaking. Complements `test_queueing.py`'s
single-seed spot checks with:

* a >= 3-point (lam, C) validation grid per prediction model,
* a seed-averaged agreement bound (sim noise ~ 1/sqrt(n_seeds * n)),
* shape checks: theory and simulation must agree on *how* mean
  response moves as C and lam move, not just on point values.
"""

import pytest

from repro.core.queueing import MG1Config, mean_response
from repro.core.simulation import simulate

#: The validation grid: light / moderate / heavy load x loose / tight C.
GRID = [(0.3, 0.8), (0.5, 0.5), (0.7, 0.9)]

N_JOBS = 40000
SEEDS = (11, 12, 13)


def _sim_mean(lam: float, C: float, prediction: str) -> float:
    """Seed-averaged simulated mean response at one operating point."""
    vals = [simulate("sprpt-lp", lam, C=C, n_jobs=N_JOBS,
                     prediction=prediction, seed=s).mean_response
            for s in SEEDS]
    return sum(vals) / len(vals)


@pytest.mark.parametrize("lam,C", GRID)
def test_lemma1_vs_sim_perfect(lam, C):
    """Perfect predictions: closed form within 15% of the sim mean
    (the SOAP form's residence term mildly underestimates finite-run
    sims at moderate load; 15% matches `test_queueing.py`'s bound)."""
    th = mean_response(MG1Config(lam=lam, C=C, prediction="perfect"))
    assert _sim_mean(lam, C, "perfect") == pytest.approx(th, rel=0.15)


@pytest.mark.parametrize("lam,C", GRID)
def test_lemma1_vs_sim_exponential(lam, C):
    """Exponential prediction noise: closed form within 12% of sim."""
    th = mean_response(MG1Config(lam=lam, C=C, prediction="exponential"))
    assert _sim_mean(lam, C, "exponential") == pytest.approx(th, rel=0.12)


def test_theory_and_sim_agree_on_prediction_direction():
    """Noisy (exponential) predictions cost mean response vs perfect
    ones at every grid point — same sign in closed form and sim."""
    for lam, C in GRID:
        th_p = mean_response(MG1Config(lam=lam, C=C, prediction="perfect"))
        th_e = mean_response(MG1Config(lam=lam, C=C,
                                       prediction="exponential"))
        assert th_p < th_e
        assert _sim_mean(lam, C, "perfect") < _sim_mean(lam, C,
                                                        "exponential")


def test_theory_and_sim_agree_on_load_direction():
    """Mean response grows with lam in both theory and simulation."""
    C = 0.8
    ths = [mean_response(MG1Config(lam=lam, C=C, prediction="perfect"))
           for lam in (0.3, 0.5, 0.7)]
    sims = [_sim_mean(lam, C, "perfect") for lam in (0.3, 0.5, 0.7)]
    assert ths == sorted(ths)
    assert sims == sorted(sims)


def test_sim_converges_toward_theory():
    """The sim-vs-theory gap shrinks as the run length grows (the
    residual at 4x jobs is no worse than the short run's residual)."""
    lam, C = 0.5, 0.8
    th = mean_response(MG1Config(lam=lam, C=C, prediction="perfect"))
    short = abs(simulate("sprpt-lp", lam, C=C, n_jobs=5000,
                         prediction="perfect", seed=7).mean_response - th)
    long = abs(simulate("sprpt-lp", lam, C=C, n_jobs=80000,
                        prediction="perfect", seed=7).mean_response - th)
    assert long <= short + 0.05 * th
