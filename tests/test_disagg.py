"""Prefill/decode disaggregation tests: engine export/import primitives,
router pool dispatch, page conservation under adversarial interleavings
(hypothesis), per-tenant arrival streams, and real-mode token parity
across a mid-decode migration."""

import copy

import pytest

from _hypothesis_fallback import given, settings, st
from repro.cluster import Router, RouterConfig, run_cluster
from repro.config import get_config, get_smoke_config
from repro.metrics import EventLog, check_invariants
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import Engine, EngineConfig
from repro.serving.workload import (TenantSpec, WorkloadConfig, generate,
                                    scenario_config)

CFG = get_config("granite-3-8b")
HW = HardwareSpec(name="compute-bound-2tf", peak_flops=2e12, hbm_bw=819e9,
                  overhead_s=2e-4)


def workload(n=30, rate=4.0, seed=0, scenario="poisson"):
    wc = scenario_config(scenario, n_requests=n, request_rate=rate,
                         seed=seed, vocab=CFG.vocab_size)
    return generate(wc)


def _paged_engine(seed=0, prefill_only=False, **kw):
    return Engine(CFG, EngineConfig(policy="trail", kv_layout="paged",
                                    hardware=HW, seed=seed,
                                    prefill_only=prefill_only, **kw))


def _check_partition(bm):
    """The refcount-partition invariant: every physical page is exactly
    one of free-listed, reusable, or owned with refcount == #owners."""
    counts = {}
    for ps in bm.pages.values():
        for p in ps:
            counts[p] = counts.get(p, 0) + 1
    for p, c in counts.items():
        assert bm.refcount[p] == c, f"page {p}: refcount != owners"
    free, reusable, used = set(bm.free), set(bm._reusable), set(counts)
    assert len(bm.free) == len(free)
    assert not (free & reusable) and not (free & used)
    assert not (reusable & used)
    assert len(free) + len(reusable) + len(used) == bm.num_pages


# ---------------------------------------------------------------------------
# engine primitives: export / import / parking
# ---------------------------------------------------------------------------

def test_prefill_only_requires_page_retention():
    with pytest.raises(ValueError):
        Engine(CFG, EngineConffig := EngineConfig(kv_layout="contig",
                                                  prefill_only=True))
    del EngineConffig


def test_prefill_only_parks_and_exports():
    """A prefill-only engine finishes prefills, parks them (no decode
    tokens), and export hands back a KVHandoff that empties the source."""
    eng = _paged_engine(prefill_only=True)
    reqs = workload(n=4, rate=100.0)
    for r in copy.deepcopy(reqs):
        eng.submit(r)
    for _ in range(200):
        if len(eng.handoff_ready()) == len(reqs):
            break
        eng.step()
    ready = eng.handoff_ready()
    assert len(ready) == len(reqs)
    # parked in arrival order, no generated tokens, prefill complete
    assert ready == sorted(ready)
    for rid in list(ready):
        h = eng.export_request(rid)
        assert h.req.rid == rid
        assert h.kv_tokens > 0 and h.n_pages > 0 and h.nbytes > 0
        assert not h.req.generated
    assert eng.blocks.used_pages() == 0      # zero-leak on the source
    assert not eng.has_work()


def test_prefill_only_run_is_refused():
    eng = _paged_engine(prefill_only=True)
    with pytest.raises(ValueError):
        eng.run([])


def test_export_import_roundtrip_preserves_progress():
    """Import resumes from the shipped KV: arrival and prefill progress
    survive, and the destination serves the request to completion
    without re-prefilling the shipped tokens."""
    src = _paged_engine(seed=0, prefill_only=True)
    dst = _paged_engine(seed=1)
    reqs = workload(n=3, rate=50.0)
    for r in copy.deepcopy(reqs):
        src.submit(r)
    while len(src.handoff_ready()) < len(reqs):
        src.step()
    prefilled_src = src.stats.prefilled_tokens
    assert prefilled_src > 0
    for rid in list(src.handoff_ready()):
        h = src.export_request(rid)
        got = dst.import_request(h, t=src.now)
        assert got == h.kv_tokens
    done = []
    while dst.has_work():
        done.extend(dst.step().completed)
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    # the destination decoded from the shipped KV: its own prefill work
    # is at most the final prompt token per request, not the prompts
    assert dst.stats.prefilled_tokens <= len(reqs)
    assert src.blocks.used_pages() == 0
    assert dst.blocks.used_pages() == 0


def test_import_rejects_duplicate_rid():
    src = _paged_engine(prefill_only=True)
    dst = _paged_engine(seed=1)
    reqs = workload(n=2, rate=50.0)
    for r in copy.deepcopy(reqs):
        src.submit(r)
    while not src.handoff_ready():
        src.step()
    rid = src.handoff_ready()[0]
    h = src.export_request(rid)
    dst.import_request(h)
    with pytest.raises(ValueError):
        dst.import_request(h)


def test_export_mid_decode_from_regular_engine():
    """export_request doubles as suspended-request migration: a regular
    (non-prefill-only) engine can export a request mid-decode."""
    src = _paged_engine(seed=0)
    dst = _paged_engine(seed=1)
    reqs = workload(n=3, rate=100.0)
    for r in copy.deepcopy(reqs):
        src.submit(r)
    # step until someone has decoded a few tokens but nobody finished
    target = None
    for _ in range(500):
        src.step()
        live = [r for r in src._pool_reqs.values()
                if not r.done and r.generated]
        if live:
            target = max(live, key=lambda r: len(r.generated))
            break
    assert target is not None
    h = src.export_request(target.rid)
    assert h.kv_tokens > 0
    dst.import_request(h, t=src.now)
    while src.has_work():
        src.step()
    done = []
    while dst.has_work():
        done.extend(dst.step().completed)
    assert [r.rid for r in done] == [target.rid]
    assert src.blocks.used_pages() == 0 and dst.blocks.used_pages() == 0


# ---------------------------------------------------------------------------
# router: disagg topology and dispatch
# ---------------------------------------------------------------------------

def _replicas(p, n):
    out = []
    for i in range(n):
        out.append(_paged_engine(seed=i, prefill_only=i < p))
    return out


def test_router_validates_disagg_topology():
    with pytest.raises(ValueError):        # P >= n_replicas
        Router(_replicas(2, 2), RouterConfig(n_replicas=2, policy="jspw",
                                             prefill_replicas=2))
    with pytest.raises(ValueError):        # pool/flag mismatch
        Router(_replicas(0, 2), RouterConfig(n_replicas=2, policy="jspw",
                                             prefill_replicas=1))


def test_disagg_cluster_end_to_end():
    """Every request prefills on the P-pool, migrates exactly once, and
    finishes on the D-pool; both pools drain to zero pages and the merged
    event log keeps its lifecycle invariants."""
    reqs = workload(n=30, rate=4.0, scenario="bursty")
    stats = run_cluster(CFG, reqs, router_policy="jspw", n_replicas=3,
                        policy="trail", kv_layout="paged", hardware=HW,
                        seed=0, prefill_replicas=1, record_events=True)
    assert len(stats.latencies) == len(reqs)
    assert stats.n_handoffs == len(reqs)
    assert stats.handoff_pages > 0
    assert stats.leaked_pages == [0, 0, 0]
    check_invariants(stats.event_log)
    # prefill replicas never emit tokens; decode replicas never prefill
    # more than the per-request final prompt token
    per = stats.replica_summaries
    assert per[0]["prefilled_tokens"] > 0
    kinds = {}
    for e in stats.event_log.events:
        kinds.setdefault(e.kind, 0)
        kinds[e.kind] += 1
    assert kinds.get("handoff", 0) == len(reqs)
    assert kinds["finish"] == len(reqs)


def test_disagg_zero_prefill_replicas_is_colocated():
    """prefill_replicas=0 must be the exact colocated code path."""
    reqs = workload(n=20, rate=4.0)
    a = run_cluster(CFG, reqs, router_policy="jspw", n_replicas=2,
                    policy="trail", kv_layout="paged", hardware=HW, seed=0)
    b = run_cluster(CFG, reqs, router_policy="jspw", n_replicas=2,
                    policy="trail", kv_layout="paged", hardware=HW, seed=0,
                    prefill_replicas=0)
    assert a.latencies == b.latencies and a.ttfts == b.ttfts
    assert b.n_handoffs == 0


def test_disagg_ttft_counts_prefill_replica_first_token():
    """TTFT must be measured at the *decode* replica's first emitted
    token, after the transfer delay — never reset by migration. The
    merged log orders arrival <= handoff <= first_token per request."""
    reqs = workload(n=10, rate=2.0)
    stats = run_cluster(CFG, reqs, router_policy="jspw", n_replicas=2,
                        policy="trail", kv_layout="paged", hardware=HW,
                        seed=0, prefill_replicas=1, record_events=True)
    for rid, evs in stats.event_log.per_request().items():
        first = {}
        for e in evs:
            first.setdefault(e.kind, e.t)
        assert first["arrival"] <= first["handoff"] <= first["first_token"]


# ---------------------------------------------------------------------------
# page conservation under adversarial interleavings (hypothesis)
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 9)),
                min_size=1, max_size=60))
@settings(max_examples=25, deadline=None)
def test_handoff_any_interleaving_conserves_pages(ops):
    """Any interleaving of source steps, destination steps, exports,
    imports, cancels (on either side), and a source crash keeps the
    refcount partition intact on both engines, and draining both ends
    with zero resident pages everywhere."""
    reqs = workload(n=8, rate=50.0, seed=3)
    src = _paged_engine(seed=0, prefill_only=True, max_batch=4)
    dst = _paged_engine(seed=1, max_batch=4)
    for r in copy.deepcopy(reqs):
        src.submit(r)
    rids = [r.rid for r in reqs]
    for op, k in ops:
        if op == 0:
            src.step()
        elif op == 1:
            dst.step()
        elif op == 2:                       # export->import next ready
            ready = src.handoff_ready()
            if ready:
                h = src.export_request(ready[0])
                dst.import_request(h, t=max(src.now, dst.now))
        elif op == 3:                       # cancel wherever it lives
            rid = rids[k % len(rids)]
            src.cancel(rid) or dst.cancel(rid)
        else:                               # crash the source mid-flight
            src.crash()
        _check_partition(src.blocks)
        _check_partition(dst.blocks)
    # drain: migrate everything still parked, finish the decode side
    while src.has_work():
        src.step()
        for rid in list(src.handoff_ready()):
            h = src.export_request(rid)
            dst.import_request(h, t=max(src.now, dst.now))
    while dst.has_work():
        dst.step()
    _check_partition(src.blocks)
    _check_partition(dst.blocks)
    assert src.blocks.used_pages() == 0
    assert dst.blocks.used_pages() == 0


# ---------------------------------------------------------------------------
# per-tenant arrival processes (workload synthesis)
# ---------------------------------------------------------------------------

def test_tenant_arrivals_scenario_superposes():
    wc = scenario_config("tenant-arrivals", n_requests=150,
                         request_rate=10.0, seed=2, vocab=500)
    assert sum(s.rate for s in wc.tenants) == pytest.approx(10.0)
    reqs = generate(wc)
    assert len(reqs) == 150
    names = {r.tenant for r in reqs}
    assert names == {"chat", "code", "summarize"}
    assert all(a.arrival <= b.arrival for a, b in zip(reqs, reqs[1:]))
    assert [r.rid for r in reqs] == list(range(150))


def test_per_tenant_streams_are_independent():
    """Changing one tenant's rate must not reshuffle another tenant's
    length/content draws — the per-tenant split-stream invariance."""
    base = scenario_config("tenant-arrivals", n_requests=120,
                           request_rate=8.0, seed=5, vocab=500)
    from dataclasses import replace
    bumped = replace(base, tenants=tuple(
        replace(s, rate=s.rate * 4) if s.name == "chat" else s
        for s in base.tenants))

    def sig(reqs, name):
        return [(len(r.prompt), r.true_out_len, tuple(r.prompt[:4]))
                for r in reqs if r.tenant == name]

    a, b = generate(base), generate(bumped)
    for name in ("code", "summarize"):
        sa, sb = sig(a, name), sig(b, name)
        n = min(len(sa), len(sb))
        assert sa[:n] == sb[:n]


def test_per_tenant_burst_and_validation():
    tenants = (TenantSpec("a", 1.0, rate=5.0, arrival="burst"),
               TenantSpec("b", 1.0, rate=5.0))
    wc = WorkloadConfig(n_requests=40, request_rate=10.0, seed=1,
                        split_streams=True, tenants=tenants, vocab=300)
    reqs = generate(wc)
    assert len(reqs) == 40
    # the burst tenant fills the head of the merged stream at t=0
    assert all(r.tenant == "a" and r.arrival == 0.0 for r in reqs[:5])
    # mixed rate-driven and weight-driven tenants is an error
    bad = (TenantSpec("a", 1.0, rate=5.0), TenantSpec("b", 1.0))
    with pytest.raises(ValueError, match="positive rate"):
        generate(WorkloadConfig(n_requests=10, split_streams=True,
                                tenants=bad, vocab=300))
    # unknown per-tenant process is an error
    ugly = (TenantSpec("a", 1.0, rate=5.0, arrival="nope"),)
    with pytest.raises(ValueError, match="unknown arrival"):
        generate(WorkloadConfig(n_requests=10, split_streams=True,
                                tenants=ugly, vocab=300))


# ---------------------------------------------------------------------------
# real mode: migrated pages reproduce the unmigrated token stream
# ---------------------------------------------------------------------------

@pytest.mark.real
def test_real_mode_migration_token_parity():
    """Greedy decode resumed from shipped KV pages must emit exactly the
    tokens the unmigrated run emits — the device-level proof that
    export/import moves byte-equivalent KV."""
    import jax

    from repro.models.model import Model
    from repro.serving.predictors import ProbePredictor

    cfg = get_smoke_config("trail-llama")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    wc = WorkloadConfig(n_requests=4, request_rate=50.0, seed=2,
                        vocab=cfg.vocab_size, prompt_mean=6.0,
                        out_median=8.0, max_out=12, split_streams=True)
    reqs = generate(wc)

    def make(seed):
        pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                              embed_table=params["embed"])
        ecfg = EngineConfig(policy="trail", max_batch=3, mode="real",
                            kv_layout="paged", page_size=8, max_len=64,
                            seed=seed)
        return Engine(cfg, ecfg, predictor=pred, model=m, params=params)

    # baseline: no migration
    base = make(0)
    for r in sorted(copy.deepcopy(reqs), key=lambda r: r.arrival):
        base.submit(r)
    done = []
    while base.has_work():
        done.extend(base.step().completed)
    want = {r.rid: list(r.generated) for r in done}

    # migrated: decode a few tokens on A, ship mid-decode to B
    a, b = make(0), make(1)
    for r in sorted(copy.deepcopy(reqs), key=lambda r: r.arrival):
        a.submit(r)
    target = None
    for _ in range(200):
        a.step()
        live = [r for r in a._pool_reqs.values()
                if not r.done and r.generated]
        if live:
            target = max(live, key=lambda r: len(r.generated))
            break
    assert target is not None and not target.done
    pre = len(target.generated)
    h = a.export_request(target.rid)
    assert h.payload is not None            # real mode ships page data
    b.import_request(h, t=a.now)
    got = dict()
    while a.has_work():
        for r in a.step().completed:
            got[r.rid] = list(r.generated)
    while b.has_work():
        for r in b.step().completed:
            got[r.rid] = list(r.generated)
    assert set(got) == set(want)
    assert got[target.rid] == want[target.rid]
    assert len(want[target.rid]) > pre      # genuinely resumed mid-stream
    assert a.blocks.used_pages() == 0 and b.blocks.used_pages() == 0
