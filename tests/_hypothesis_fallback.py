"""Import-or-stub shim for hypothesis.

The tier-1 suite must *collect* (and its non-property tests must run) on
machines without ``hypothesis`` installed. Test modules import property
-testing names from here instead of from hypothesis directly:

    from _hypothesis_fallback import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is available this re-exports the real thing. When it is
not, ``given`` becomes a decorator that marks the test skipped, and
``st``/``hnp`` become chainable stand-ins so module-level strategy
expressions (``st.integers(0, 9).flatmap(...)``, ``@st.composite``)
still evaluate during collection.
"""

try:
    from hypothesis import assume, given, settings, strategies as st

    try:
        from hypothesis.extra import numpy as hnp
    except ImportError:          # hypothesis without the numpy extra
        hnp = None
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any attribute access / call, returning itself, so
        strategy-construction expressions evaluate at collection time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()
    hnp = _AnyStrategy()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (property test)")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def assume(_condition):
        return True
