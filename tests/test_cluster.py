"""Cluster-router tests: incremental engine API equivalence, router
invariants (batch bounds, determinism), and prediction-driven dispatch."""

import copy

import pytest

from repro.cluster import ROUTER_POLICIES, Router, RouterConfig, run_cluster
from repro.config import get_config
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import Engine, EngineConfig, run_policy
from repro.serving.predictors import OraclePredictor
from repro.serving.workload import generate, scenario_config

CFG = get_config("granite-3-8b")
HW = HardwareSpec(name="compute-bound-2tf", peak_flops=2e12, hbm_bw=819e9,
                  overhead_s=2e-4)


def workload(n=60, rate=2.0, seed=0, scenario="bursty"):
    wc = scenario_config(scenario, n_requests=n, request_rate=rate,
                         seed=seed, vocab=CFG.vocab_size)
    return generate(wc)


# ---------------------------------------------------------------------------
# incremental engine API (the tentpole refactor)
# ---------------------------------------------------------------------------

def test_run_equals_submit_step_loop():
    """run() is a thin wrapper: manual submit()+step() must reproduce it."""
    reqs = workload(n=40)
    batch = run_policy(CFG, "trail", reqs, mode="sim", seed=1)

    eng = Engine(CFG, EngineConfig(policy="trail", seed=1))
    for r in sorted(copy.deepcopy(reqs), key=lambda r: r.arrival):
        eng.submit(r)
    completed = []
    while eng.has_work():
        res = eng.step()
        completed.extend(res.completed)
    assert len(completed) == len(reqs)
    assert eng.stats.latencies == batch.latencies
    assert eng.stats.ttfts == batch.ttfts
    assert eng.stats.iterations == batch.iterations
    assert eng.now == batch.sim_time


def test_step_result_fields():
    eng = Engine(CFG, EngineConfig(policy="trail", seed=2))
    assert not eng.has_work() and eng.backlog() == 0.0
    res = eng.step()                        # drained engine: idle no-op
    assert not res.ran and res.now == 0.0
    for r in workload(n=4, rate=100.0, seed=3):
        eng.submit(r)
    assert eng.queue_len() == 4 and eng.backlog() > 0.0
    ran_any = False
    while eng.has_work():
        res = eng.step()
        ran_any = ran_any or res.ran
        assert res.now == eng.now
    assert ran_any and eng.backlog() == 0.0


def test_single_replica_cluster_equals_run_policy():
    """A 1-replica cluster is exactly the single-engine simulation."""
    reqs = workload(n=50, seed=4)
    single = run_policy(CFG, "trail", reqs, mode="sim", seed=5,
                        hardware=HW).summary()
    clus = run_cluster(CFG, reqs, router_policy="round-robin", n_replicas=1,
                       policy="trail", seed=5, hardware=HW).summary()
    assert clus["mean_latency"] == pytest.approx(single["mean_latency"])
    assert clus["finished"] == len(reqs)


# ---------------------------------------------------------------------------
# router invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ROUTER_POLICIES)
def test_all_requests_finish_every_router_policy(policy):
    reqs = workload(n=60, seed=6)
    s = run_cluster(CFG, reqs, router_policy=policy, n_replicas=3,
                    policy="trail", seed=7, hardware=HW)
    d = s.summary()
    assert d["finished"] == len(reqs)
    assert sum(d["dispatch_counts"]) == len(reqs)
    assert all(v > 0 for v in s.latencies)


@pytest.mark.parametrize("policy", ROUTER_POLICIES)
def test_no_replica_exceeds_max_batch(policy):
    mb = 4
    reqs = workload(n=60, rate=8.0, seed=8)
    s = run_cluster(CFG, reqs, router_policy=policy, n_replicas=2,
                    policy="trail", seed=9, max_batch=mb, hardware=HW)
    for summ in s.replica_summaries:
        assert 0 < summ["peak_batch"] <= mb


@pytest.mark.parametrize("policy", ROUTER_POLICIES)
def test_dispatch_deterministic_per_seed(policy):
    reqs = workload(n=50, seed=10)

    def once():
        replicas = [Engine(CFG, EngineConfig(policy="trail", seed=11 + i,
                                             hardware=HW))
                    for i in range(2)]
        router = Router(replicas, RouterConfig(n_replicas=2, policy=policy,
                                               seed=13),
                        size_predictor=OraclePredictor(CFG.probe, seed=99))
        stats = router.run(copy.deepcopy(reqs))
        return router.dispatch_log, stats.summary()["mean_latency"]

    log1, lat1 = once()
    log2, lat2 = once()
    assert log1 == log2
    assert lat1 == lat2


def test_round_robin_is_cyclic():
    reqs = workload(n=30, seed=12)
    replicas = [Engine(CFG, EngineConfig(policy="trail", seed=i,
                                         hardware=HW)) for i in range(3)]
    router = Router(replicas, RouterConfig(n_replicas=3,
                                           policy="round-robin", seed=0))
    router.run(copy.deepcopy(reqs))
    assert [i for _, i in router.dispatch_log] == \
        [k % 3 for k in range(len(reqs))]


def test_router_validation():
    replicas = [Engine(CFG, EngineConfig(seed=0))]
    with pytest.raises(ValueError):
        Router(replicas, RouterConfig(n_replicas=1, policy="magic"))
    with pytest.raises(ValueError):
        Router(replicas, RouterConfig(n_replicas=2, policy="jsq"))


# ---------------------------------------------------------------------------
# jspw uses live predictions
# ---------------------------------------------------------------------------

def _engine_with_jobs(out_lens, seed):
    """An engine holding admitted jobs with ~oracle-accurate predictions,
    stepped past prefill so backlog is dominated by pred_remaining."""
    eng = Engine(CFG, EngineConfig(policy="trail", seed=seed, hardware=HW),
                 predictor=OraclePredictor(CFG.probe, seed=seed,
                                           bert_sigma=1e-6, flip_prob=0.0,
                                           temp=1e-3))
    reqs = workload(n=len(out_lens), rate=1e9, seed=seed)
    for r, olen in zip(reqs, out_lens):
        r.true_out_len = olen
        r.prompt = r.prompt[:8]
        eng.submit(r)
    eng.step()        # admit + prefill
    eng.step()        # first decode: on_prefill predictions live
    return eng


def test_jspw_routes_by_live_predictions():
    """Untruncated jspw joins the replica with the smaller predicted
    backlog, regardless of queue counts."""
    e_long = _engine_with_jobs([400], seed=1)       # 1 job, huge backlog
    e_short = _engine_with_jobs([30, 30, 30], seed=2)   # 3 jobs, small
    assert e_long.backlog() > e_short.backlog()
    assert e_long.queue_len() < e_short.queue_len()
    router = Router([e_long, e_short],
                    RouterConfig(n_replicas=2, policy="jspw", seed=0))
    req = workload(n=1, rate=1e9, seed=3)[0]
    assert router._pick(req) == 1                   # smaller backlog wins
    # jsq would have picked the other replica
    router_q = Router([e_long, e_short],
                      RouterConfig(n_replicas=2, policy="jsq", seed=0))
    assert router_q._pick(req) == 0


def test_jspw_truncation_ignores_longer_jobs():
    """With a size predictor, predicted work longer than the arrival is
    discounted (SRPT-interfering work): one 400-token job interferes less
    with a 10-token arrival than three 30-token jobs."""
    e_long = _engine_with_jobs([400], seed=1)
    e_short = _engine_with_jobs([30, 30, 30], seed=2)
    size_pred = OraclePredictor(CFG.probe, seed=5, bert_sigma=1e-6,
                                flip_prob=0.0)
    router = Router([e_long, e_short],
                    RouterConfig(n_replicas=2, policy="jspw", seed=0),
                    size_predictor=size_pred)
    req = workload(n=1, rate=1e9, seed=3)[0]
    req.true_out_len = 10
    assert router._pick(req) == 0                   # long job yields anyway


def test_jspw_beats_round_robin_on_bursty():
    """The BENCH_cluster.json headline, at reduced scale: predicted-work
    routing beats state-blind round-robin at the matched aggregate rate."""
    means = {}
    for pol in ("round-robin", "jspw"):
        vals = []
        for seed in (3, 11, 23):
            reqs = workload(n=150, rate=0.9, seed=seed)
            s = run_cluster(CFG, reqs, router_policy=pol, n_replicas=2,
                            policy="trail", seed=5, hardware=HW)
            vals.append(s.summary()["mean_latency"])
        means[pol] = sum(vals) / len(vals)
    assert means["jspw"] < means["round-robin"]


def test_two_replicas_beat_one_at_matched_rate():
    reqs = workload(n=120, rate=0.9, seed=3)
    r1 = run_cluster(CFG, reqs, router_policy="round-robin", n_replicas=1,
                     policy="trail", seed=5, hardware=HW).summary()
    r2 = run_cluster(CFG, reqs, router_policy="round-robin", n_replicas=2,
                     policy="trail", seed=5, hardware=HW).summary()
    assert r2["mean_latency"] < r1["mean_latency"]
