"""End-to-end behaviour tests for the paper's system (TRAIL pipeline):
train a tiny LM -> harvest embeddings -> train probe -> serve with the real
probe under SPRPT-LP, validating the paper's *relative* claims at CPU scale.
Also: the dry-run entry point lowers+compiles on the production mesh
(subprocess so the 512-device XLA flag never leaks into this process)."""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.config import INPUT_SHAPES, get_config, get_smoke_config, shape_applies
from repro.models.model import Model
from repro.serving.engine import run_policy
from repro.serving.predictors import OraclePredictor, ProbePredictor
from repro.serving.workload import WorkloadConfig, generate
from repro.training import optimizer as opt_mod
from repro.training.data import DataConfig, batches, harvest_probe_data
from repro.training.train import (ProbeTrainConfig, probe_mae, train_lm,
                                  train_probe)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def trained_system():
    cfg = get_smoke_config("trail-llama")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab_size, seq_len=96, batch=8,
                    prompt_mean=10, max_out=60, seed=0)
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=80)
    params, _, _ = train_lm(model, params, batches(dc, 80), ocfg, 80)
    taps, rem = harvest_probe_data(
        model, params, DataConfig(vocab=cfg.vocab_size, seq_len=96, batch=8,
                                  prompt_mean=10, max_out=60, seed=9), 8)
    probe_params, _ = train_probe(taps, rem, cfg.probe, cfg.d_model,
                                  ProbeTrainConfig(epochs=6))
    params = dict(params)
    params["probe"] = probe_params
    return cfg, model, params, (taps, rem)


def test_probe_beats_prompt_only_mae(trained_system):
    """Paper Figure 3's relative claim: tap-embedding probe beats a
    prompt-only (BERT-regime) predictor on remaining-length MAE."""
    cfg, model, params, (taps, rem) = trained_system
    mae_probe = probe_mae(params["probe"], taps, rem, cfg.probe)
    # prompt-only baseline: same head trained on the *embedding-table mean*
    # (no forward pass, no per-iteration refresh) — the S^3/BERT regime
    emb = np.asarray(params["embed"], np.float32)
    rng = np.random.default_rng(0)
    # crude prompt-only features: mean embedding of random prompt tokens
    feats = emb[rng.integers(16, cfg.vocab_size, size=(len(rem), 8))].mean(1)
    bert_params, _ = train_probe(feats, rem, cfg.probe, cfg.d_model,
                                 ProbeTrainConfig(epochs=6))
    mae_bert = probe_mae(bert_params, feats, rem, cfg.probe)
    assert mae_probe < mae_bert


def test_full_pipeline_trail_beats_fcfs(trained_system):
    cfg, model, params, _ = trained_system
    wc = WorkloadConfig(n_requests=10, request_rate=60.0, seed=4,
                        vocab=cfg.vocab_size, prompt_mean=8.0,
                        out_median=8.0, max_out=24)
    reqs = generate(wc)
    results = {}
    for pol in ("fcfs", "trail"):
        pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                              embed_table=params["embed"])
        s = run_policy(cfg, pol, reqs, max_batch=3, mode="real",
                       model=model, params=params, predictor=pred)
        results[pol] = s.summary()
        assert len(s.latencies) == len(reqs)
    assert results["trail"]["mean_ttft"] <= results["fcfs"]["mean_ttft"] * 1.1


def test_long_500k_skip_rules():
    shape = INPUT_SHAPES["long_500k"]
    runs = {a: shape_applies(get_config(a), shape)
            for a in ("mamba2-370m", "hymba-1.5b", "gemma3-1b", "gemma2-9b",
                      "granite-3-8b", "qwen1.5-32b", "arctic-480b",
                      "olmoe-1b-7b", "whisper-tiny", "paligemma-3b")}
    assert runs["mamba2-370m"] and runs["hymba-1.5b"]
    assert runs["gemma3-1b"] and runs["gemma2-9b"]
    assert not any(runs[a] for a in ("granite-3-8b", "qwen1.5-32b",
                                     "arctic-480b", "olmoe-1b-7b",
                                     "whisper-tiny", "paligemma-3b"))


@pytest.mark.slow
def test_dryrun_lowers_on_production_mesh():
    """Subprocess: the smallest (arch, shape) pair must lower+compile on the
    256-chip mesh via the real entry point."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-370m",
         "--shape", "long_500k", "--out", "/tmp/dryrun_test"],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=520)
    assert out.returncode == 0, out.stdout + out.stderr
    with open("/tmp/dryrun_test/mamba2-370m_long_500k_16x16.json") as f:
        rep = json.load(f)
    assert rep["roofline"]["n_chips"] == 256
    assert rep["memory"]["peak_per_device_gb"] < 16.0


def test_serve_cli_invalid_flags_exit_2():
    """The serve CLI's contract for bad input: exit code 2 (argparse's
    convention) with a one-line error on stderr — never a traceback,
    never status 1."""
    cases = [
        ["--rate-scale", "2.0"],                     # needs --trace
        ["--trace", "/nonexistent/t.jsonl"],         # unreadable path
        ["--chaos", "crash:1@5"],                    # needs --replicas >= 2
        ["--chaos", "meteor:0@5", "--replicas", "2"],  # bad fault kind
        ["--policy", "nope"],                        # argparse choice error
        ["--admission-control"],                     # needs watermark
        ["--age-boost", "-1"],                       # negative knob
        ["--deadline-slack", "5"],                   # needs --deadline
        ["--port", "8100"],                          # needs --serve
        ["--serve", "--port", "99999"],              # port out of range
        ["--time-scale", "20"],                      # needs --serve
        ["--serve", "--time-scale", "0"],            # must be positive
        ["--clients", "0"],                          # must be positive
        ["--think-time", "1.0"],                     # needs --clients
        ["--clients", "4", "--think-time", "-1"],    # negative think time
        ["--requests-per-client", "2"],              # needs --clients
        ["--serve", "--trace", "sample"],            # serve is closed-loop
        ["--clients", "4", "--disagg", "1:3"],       # no disagg front door
        ["--serve", "--replicas", "2"],              # single engine only
        ["--serve", "--metrics-out", "m.json"],      # GET /metrics instead
    ]
    for argv in cases:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", *argv],
            env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
            capture_output=True, text=True, timeout=120)
        assert out.returncode == 2, (argv, out.returncode, out.stderr)
        assert "error:" in out.stderr, (argv, out.stderr)
        assert "Traceback" not in out.stderr, (argv, out.stderr)


def test_oracle_predictor_statistics():
    """Sharper probe temp -> lower serving latency (prediction quality
    matters, the paper's TRAIL vs TRAIL-BERT axis)."""
    cfg = get_config("granite-3-8b")
    wc = WorkloadConfig(n_requests=150, request_rate=14.0, seed=5,
                        vocab=cfg.vocab_size)
    reqs = generate(wc)
    good = run_policy(cfg, "trail", reqs, mode="sim", seed=6,
                      predictor=OraclePredictor(cfg.probe, temp=0.3,
                                                flip_prob=0.0, seed=6))
    bad = run_policy(cfg, "trail", reqs, mode="sim", seed=6,
                     predictor=OraclePredictor(cfg.probe, temp=5.0,
                                               flip_prob=0.5, bert_sigma=2.0,
                                               seed=6))
    assert good.summary()["mean_latency"] <= bad.summary()["mean_latency"] * 1.05
