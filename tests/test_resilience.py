"""Failure & overload resilience: first-class cancellation, deadlines,
predicted-work load shedding, and deterministic fault injection with
router failover. Also pins the off-by-default guarantee: every knob at
its default is byte-identical to the pre-resilience code paths."""

import copy
import json

import pytest

from repro.cluster import Router, RouterConfig, run_cluster
from repro.cluster.faults import (NEVER, FaultSchedule, FlakySubmit,
                                  ReplicaCrash, SlowdownWindow, parse_chaos)
from repro.config import get_config
from repro.core.scheduler import ReqState
from repro.metrics.events import EventLog, check_invariants
from repro.metrics.rollup import rollup
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import Engine, EngineConfig, run_policy
from repro.serving.workload import WorkloadConfig, generate, scenario_config

CFG = get_config("granite-3-8b")
HW = HardwareSpec(name="compute-bound-2tf", peak_flops=2e12, hbm_bw=819e9,
                  overhead_s=2e-4)


def workload(n=40, rate=4.0, seed=0, scenario="bursty"):
    wc = scenario_config(scenario, n_requests=n, request_rate=rate,
                         seed=seed, vocab=CFG.vocab_size)
    return generate(wc)


def drain(eng):
    while eng.has_work():
        eng.step()


# ---------------------------------------------------------------------------
# Engine.cancel: every request state, both KV layouts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["contig", "paged"])
def test_cancel_running_request_releases_kv(layout):
    eng = Engine(CFG, EngineConfig(policy="trail", seed=0,
                                   kv_layout=layout),
                 event_log=EventLog())
    reqs = workload(n=6, rate=100.0)
    for r in copy.deepcopy(reqs):
        eng.submit(r)
    running = []
    for _ in range(20):                     # admit + start some work
        eng.step()
        running = [rid for rid, r in eng._pool_reqs.items()
                   if r.entry.state is ReqState.RUNNING]
        if running:
            break
    assert running, "no request reached RUNNING"
    rid = running[0]
    assert eng.cancel(rid) is True
    assert eng._pool_reqs.get(rid) is None
    assert rid not in eng._entries
    if layout == "paged":
        assert rid not in eng.blocks.pages
    drain(eng)
    assert eng.stats.n_cancelled == 1
    assert len(eng.stats.latencies) == len(reqs) - 1
    check_invariants(eng.events)
    kinds = {e.kind for e in eng.events.events if e.rid == rid}
    assert "cancel" in kinds and "finish" not in kinds
    if layout == "paged":
        assert eng.blocks.used_pages() == 0


def test_cancel_pending_request_before_admission():
    """A submitted-but-unadmitted arrival cancels cleanly — it never
    touched the pool, yet goodput still counts it (arrival is emitted
    alongside the cancel)."""
    eng = Engine(CFG, EngineConfig(policy="trail", seed=0),
                 event_log=EventLog())
    reqs = workload(n=5, rate=0.5)          # spaced arrivals
    for r in copy.deepcopy(reqs):
        eng.submit(r)
    eng.step()                              # only early arrivals admitted
    late = reqs[-1].rid
    assert late not in eng._pool_reqs       # still behind the frontier
    assert eng.cancel(late) is True
    drain(eng)
    assert len(eng.stats.latencies) == len(reqs) - 1
    check_invariants(eng.events)
    per = eng.events.per_request()[late]
    assert [e.kind for e in per] == ["arrival", "cancel"]
    rep = rollup(eng.events)
    assert rep["requests"]["arrived"] == len(reqs)
    assert rep["requests"]["cancelled"] == 1
    assert rep["requests"]["goodput"] == pytest.approx(4 / 5)


def test_cancel_suspended_request_reclaims_host_pages():
    """Cancelling a preempted, host-swapped request reclaims its host
    copy through free_request — no stranded pages on either side."""
    eng = Engine(CFG, EngineConfig(policy="trail", seed=0,
                                   kv_layout="paged", max_batch=4,
                                   mem_budget=1 << 26))
    for r in copy.deepcopy(workload(n=12, rate=100.0)):
        eng.submit(r)
    suspended = None
    for _ in range(400):
        eng.step()
        cand = [rid for rid, r in eng._pool_reqs.items()
                if r.entry.state is ReqState.PREEMPTED and not r.done]
        if cand:
            suspended = cand[0]
            break
    assert suspended is not None, "no request was ever preempted"
    assert eng.cancel(suspended) is True
    assert suspended not in eng.blocks.pages
    assert suspended not in eng.blocks.host_pages
    drain(eng)
    assert eng.blocks.used_pages() == 0


def test_cancel_is_idempotent_and_validates_reason():
    eng = Engine(CFG, EngineConfig(policy="trail", seed=0))
    reqs = workload(n=2, rate=100.0)
    for r in copy.deepcopy(reqs):
        eng.submit(r)
    eng.step()
    rid = reqs[0].rid
    assert eng.cancel(rid) is True
    assert eng.cancel(rid) is False         # already cancelled
    assert eng.cancel(99999) is False       # unknown rid
    drain(eng)
    assert eng.cancel(reqs[1].rid) is False  # finished
    with pytest.raises(ValueError):
        eng.cancel(0, reason="vibes")


def test_cancelled_entries_never_reschedule():
    """A cancelled entry leaves scheduler state entirely: the engine
    finishes the rest of the stream without ever re-admitting it."""
    eng = Engine(CFG, EngineConfig(policy="trail", seed=3))
    reqs = workload(n=8, rate=50.0)
    for r in copy.deepcopy(reqs):
        eng.submit(r)
    victims = []
    for _ in range(20):
        eng.step()
        victims = [rid for rid, r in eng._pool_reqs.items()
                   if not r.done][:3]
        if len(victims) == 3:
            break
    assert victims
    for rid in victims:
        assert eng.cancel(rid) is True
    drain(eng)
    assert len(eng.stats.latencies) == len(reqs) - len(victims)
    assert eng.stats.n_cancelled == len(victims)


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_completion_deadline_times_out_under_overload():
    stats = run_policy(CFG, "trail", workload(n=40, rate=40.0),
                       hardware=HW, seed=0, deadline_s=1.0)
    s = stats.summary()
    assert s["timeouts"] > 0
    assert s["cancelled"] == s["timeouts"]
    assert len(stats.latencies) + s["cancelled"] == 40
    # every served completion respected the budget (enforcement lags at
    # most one megastep boundary; latencies past it were cancelled)
    assert all(lat <= 1.0 + 0.5 for lat in stats.latencies)


def test_ttft_deadline_cancels_only_unstarted_requests():
    log = EventLog()
    run_policy(CFG, "trail", workload(n=40, rate=40.0), hardware=HW,
               seed=0, ttft_deadline_s=0.3, event_log=log)
    check_invariants(log)
    timed_out = {e.rid for e in log.events if e.kind == "timeout"}
    assert timed_out
    started = {e.rid for e in log.events if e.kind == "first_token"}
    assert not (timed_out & started)


def test_request_level_deadline_overrides_engine_default():
    eng = Engine(CFG, EngineConfig(policy="trail", seed=0,
                                   deadline_s=1e9))
    reqs = copy.deepcopy(workload(n=6, rate=40.0))
    reqs[0].deadline_s = 1e-6               # expires at the first boundary
    for r in reqs:
        eng.submit(r)
    drain(eng)
    assert eng.stats.n_timeouts == 1
    assert len(eng.stats.latencies) == len(reqs) - 1


def test_no_deadline_is_zero_overhead_path():
    """deadline_s=0 must not even arm the deadline scan."""
    eng = Engine(CFG, EngineConfig(policy="trail", seed=0))
    assert eng._deadlines is False
    eng.submit(copy.deepcopy(workload(n=1))[0])
    assert eng._deadlines is False


# ---------------------------------------------------------------------------
# load shedding + admission control
# ---------------------------------------------------------------------------

def test_shedding_keeps_backlog_at_watermark():
    log = EventLog()
    stats = run_policy(CFG, "trail", workload(n=60, rate=60.0),
                       hardware=HW, seed=0, shed_watermark=3000.0,
                       event_log=log)
    s = stats.summary()
    assert s["shed"] > 0 and s["cancelled"] == s["shed"]
    check_invariants(log)
    # shed victims never started: no first_token for any shed rid
    shed = {e.rid for e in log.events if e.kind == "shed"}
    started = {e.rid for e in log.events if e.kind == "first_token"}
    assert not (shed & started)
    assert len(stats.latencies) + s["shed"] == 60


def test_shed_victims_are_worst_ranked():
    """With the oracle predictor, shedding drops the longest predicted
    jobs first — the served set's mean true output length is shorter
    than the shed set's."""
    reqs = workload(n=60, rate=60.0)
    log = EventLog()
    run_policy(CFG, "trail", reqs, hardware=HW, seed=0,
               shed_watermark=3000.0, event_log=log)
    shed = {e.rid for e in log.events if e.kind == "shed"}
    assert shed
    out = {r.rid: r.true_out_len for r in reqs}
    shed_mean = sum(out[r] for r in shed) / len(shed)
    kept = [out[r] for r in out if r not in shed]
    assert shed_mean > sum(kept) / len(kept)


def test_admission_control_refuses_at_arrival():
    log = EventLog()
    stats = run_policy(CFG, "trail", workload(n=60, rate=60.0),
                       hardware=HW, seed=0, shed_watermark=3000.0,
                       admission_control=True, event_log=log)
    assert stats.summary()["shed"] > 0
    check_invariants(log)
    shed = {e.rid for e in log.events if e.kind == "shed"}
    assert shed
    # refused arrivals never reached the pool: arrival + shed only
    per = log.per_request()
    for rid in shed:
        assert [e.kind for e in per[rid]] == ["arrival", "shed"]


def test_shedding_improves_served_tail_latency_at_overload():
    """The benchmark's headline claim in miniature: at overload, the
    requests actually served complete faster with shedding than the
    same stream without it."""
    reqs = workload(n=60, rate=60.0)
    base = run_policy(CFG, "trail", reqs, hardware=HW, seed=0)
    shedded = run_policy(CFG, "trail", reqs, hardware=HW, seed=0,
                         shed_watermark=3000.0)
    assert shedded.summary()["p99_latency"] < base.summary()["p99_latency"]


# ---------------------------------------------------------------------------
# fault schedule parsing + validation
# ---------------------------------------------------------------------------

def test_parse_chaos_full_grammar():
    fs = parse_chaos("crash:1@30, crash:0@5-12.5, slow:1@10-20*4, "
                     "flaky:0@0-10%0.25", seed=9)
    assert fs.seed == 9
    assert fs.crash_for(1) == ReplicaCrash(1, 30.0)
    assert fs.crash_for(0) == ReplicaCrash(0, 5.0, 12.5)
    assert fs.crash_for(2) is None
    assert fs.slow_factor(1, 15.0) == 4.0
    assert fs.slow_factor(1, 25.0) == 1.0
    assert fs.degraded(1, 10.0) and not fs.degraded(1, 20.0)
    assert fs.flaky_rate(0, 5.0) == pytest.approx(0.25)
    assert fs.flaky_rate(0, 10.0) == 0.0


@pytest.mark.parametrize("bad", [
    "crash:@5", "crash:1", "slow:0@5-1*2", "slow:0@1-5*-1",
    "flaky:0@0-10%1.5", "meteor:0@5", "crash:0@5-2",
])
def test_parse_chaos_rejects_malformed_entries(bad):
    with pytest.raises(ValueError):
        parse_chaos(bad)


def test_fault_schedule_validation():
    with pytest.raises(ValueError):
        FaultSchedule(crashes=(ReplicaCrash(0, 1.0), ReplicaCrash(0, 2.0)))
    with pytest.raises(ValueError):
        SlowdownWindow(0, 5.0, 5.0)
    with pytest.raises(ValueError):
        FlakySubmit(0, 0.0, 1.0, fail_rate=2.0)
    assert ReplicaCrash(0, 1.0).recover_at == NEVER


def test_router_rejects_out_of_range_fault_replica():
    replicas = [Engine(CFG, EngineConfig(seed=i)) for i in range(2)]
    with pytest.raises(ValueError):
        Router(replicas, RouterConfig(n_replicas=2),
               faults=parse_chaos("crash:5@1"))


# ---------------------------------------------------------------------------
# crash + failover end to end
# ---------------------------------------------------------------------------

def _chaos_cluster(spec, reqs, policy="jspw", n=2, seed=0, **kw):
    return run_cluster(CFG, reqs, router_policy=policy, n_replicas=n,
                       seed=seed, hardware=HW, record_events=True,
                       kv_layout="paged",
                       faults=parse_chaos(spec, seed=seed), **kw)


def test_crash_failover_serves_everything():
    reqs = workload(n=50, rate=4.0)
    stats = _chaos_cluster("crash:1@5", reqs)
    s = stats.summary()
    assert s["replica_crashes"] == 1
    assert s["retries"] > 0
    assert s["lost"] == 0
    assert s["finished"] == 50 and s["goodput"] == 1.0
    check_invariants(stats.event_log)
    rep = rollup(stats.event_log)
    assert rep["counters"]["replica_downs"] == 1
    assert rep["counters"]["retries"] == s["retries"]
    assert rep["requests"]["finished"] == 50


def test_crash_recovery_reuses_the_replica():
    reqs = workload(n=60, rate=4.0)
    stats = _chaos_cluster("crash:1@3-10", reqs)
    s = stats.summary()
    assert s["finished"] == 60 and s["lost"] == 0
    kinds = [e.kind for e in stats.event_log.events]
    assert "replica_down" in kinds and "replica_up" in kinds
    # events after recovery include dispatches back onto replica 1:
    # its post-recovery summary shows served work
    check_invariants(stats.event_log)


def test_crash_leaves_zero_pages_on_every_replica():
    reqs = workload(n=40, rate=6.0)
    for spec in ["crash:1@4", "crash:0@2-8", "crash:0@3,slow:1@1-5*3"]:
        replicas = [Engine(CFG, EngineConfig(seed=i, kv_layout="paged",
                                             policy="trail", hardware=HW),
                           event_log=EventLog()) for i in range(2)]
        router = Router(replicas, RouterConfig(n_replicas=2, policy="jsq"),
                        faults=parse_chaos(spec), event_log=EventLog())
        router.run(copy.deepcopy(reqs))
        for eng in replicas:
            assert eng.blocks.used_pages() == 0, spec


def test_straggler_excluded_from_dispatch_while_degraded():
    reqs = workload(n=30, rate=2.0)
    stats = run_cluster(CFG, reqs, router_policy="jsq", n_replicas=2,
                        seed=0, hardware=HW,
                        faults=parse_chaos("slow:1@0-100000*8"))
    # replica 1 is degraded for the whole run: nothing lands on it
    assert stats.dispatch_counts[1] == 0
    assert stats.summary()["finished"] == 30


def test_flaky_submit_fails_over_same_instant():
    reqs = workload(n=30, rate=2.0)
    stats = run_cluster(CFG, reqs, router_policy="jsq", n_replicas=2,
                        seed=0, hardware=HW, record_events=True,
                        faults=parse_chaos("flaky:0@0-100000%1.0"))
    s = stats.summary()
    assert stats.dispatch_counts[0] == 0    # every pick of 0 bounced
    assert s["finished"] == 30 and s["lost"] == 0
    assert s["retries"] > 0
    check_invariants(stats.event_log)


def test_retry_budget_exhaustion_loses_requests():
    reqs = workload(n=10, rate=2.0)
    stats = run_cluster(
        CFG, reqs, router_policy="jsq", n_replicas=2, seed=0, hardware=HW,
        record_events=True, max_retries=1,
        faults=parse_chaos("flaky:0@0-1e9%1.0,flaky:1@0-1e9%1.0"))
    s = stats.summary()
    assert s["lost"] == 10 and s["finished"] == 0
    assert s["goodput"] == 0.0
    check_invariants(stats.event_log)
    rep = rollup(stats.event_log)
    assert rep["requests"]["arrived"] == 10
    assert rep["requests"]["finished"] == 0
    assert rep["requests"]["cancelled"] == 10


def test_retried_requests_keep_user_perceived_latency():
    """Failover preserves the original arrival: completion latency spans
    the crash + backoff, it is not reset on the new replica."""
    reqs = workload(n=40, rate=4.0)
    stats = _chaos_cluster("crash:1@5", reqs)
    retried = {e.rid for e in stats.event_log.events if e.kind == "retry"}
    assert retried
    per = stats.event_log.per_request()
    for rid in retried:
        evs = per[rid]
        arrivals = {e.t for e in evs if e.kind == "arrival"}
        assert len(arrivals) == 1           # duplicates carry the same t
        finish = [e.t for e in evs if e.kind == "finish"]
        retry_t = [e.t for e in evs if e.kind == "retry"]
        if finish:
            assert finish[0] >= max(retry_t)


def test_chaos_runs_are_deterministic():
    reqs = workload(n=40, rate=4.0)
    a = _chaos_cluster("crash:1@5-20,flaky:0@0-3%0.5", reqs)
    b = _chaos_cluster("crash:1@5-20,flaky:0@0-3%0.5", reqs)
    assert json.dumps(a.summary(), sort_keys=True) == \
        json.dumps(b.summary(), sort_keys=True)
    assert [e.as_dict() for e in a.event_log.events] == \
        [e.as_dict() for e in b.event_log.events]


# ---------------------------------------------------------------------------
# off-by-default byte-identity
# ---------------------------------------------------------------------------

def test_resilience_knobs_off_are_byte_identical_single_engine():
    reqs = workload(n=40, rate=4.0)
    base = run_policy(CFG, "trail", reqs, hardware=HW, seed=0)
    gated = run_policy(CFG, "trail", reqs, hardware=HW, seed=0,
                       deadline_s=0.0, ttft_deadline_s=0.0,
                       shed_watermark=0.0, admission_control=False)
    assert json.dumps(base.summary(), sort_keys=True) == \
        json.dumps(gated.summary(), sort_keys=True)
    assert base.latencies == gated.latencies


@pytest.mark.parametrize("policy", ["round-robin", "pow2", "jspw"])
def test_no_faults_cluster_is_byte_identical(policy):
    reqs = workload(n=40, rate=4.0)
    base = run_cluster(CFG, reqs, router_policy=policy, n_replicas=2,
                       seed=0, hardware=HW)
    gated = run_cluster(CFG, reqs, router_policy=policy, n_replicas=2,
                        seed=0, hardware=HW, faults=None, max_retries=5)
    assert json.dumps(base.summary(), sort_keys=True) == \
        json.dumps(gated.summary(), sort_keys=True)
    assert base.dispatch_counts == gated.dispatch_counts
