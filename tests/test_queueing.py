"""Theory (Lemma 1) vs discrete-event simulation cross-validation."""

import pytest

from repro.core.queueing import Lemma1, MG1Config, mean_response
from repro.core.simulation import simulate


def test_fcfs_mm1_sanity():
    # M/M/1 FCFS: E[T] = 1 / (1 - rho)
    for lam in (0.3, 0.6):
        r = simulate("fcfs", lam, n_jobs=40000, seed=1)
        assert r.mean_response == pytest.approx(1 / (1 - lam), rel=0.08)


@pytest.mark.parametrize("lam,C", [(0.4, 0.5), (0.7, 1.0)])
def test_lemma1_matches_sim_perfect(lam, C):
    th = mean_response(MG1Config(lam=lam, C=C, prediction="perfect"))
    sim = simulate("sprpt-lp", lam, C=C, n_jobs=60000,
                   prediction="perfect", seed=5).mean_response
    assert sim == pytest.approx(th, rel=0.15)


@pytest.mark.parametrize("lam,C", [(0.4, 0.8), (0.7, 0.5)])
def test_lemma1_matches_sim_exponential(lam, C):
    th = mean_response(MG1Config(lam=lam, C=C, prediction="exponential"))
    sim = simulate("sprpt-lp", lam, C=C, n_jobs=60000,
                   prediction="exponential", seed=5).mean_response
    assert sim == pytest.approx(th, rel=0.15)


def test_c1_equals_srpt():
    """C=1 'becomes the same as SPRPT' (paper Section 3.3)."""
    a = simulate("sprpt-lp", 0.8, C=1.0, n_jobs=30000, seed=3)
    b = simulate("srpt", 0.8, C=1.0, n_jobs=30000, seed=3)
    assert a.mean_response == pytest.approx(b.mean_response, rel=1e-9)
    assert a.preemptions == b.preemptions


def test_policy_ordering():
    """SRPT-family < SJF < FCFS in mean response under load."""
    lam = 0.8
    rs = {p: simulate(p, lam, C=0.8, n_jobs=40000,
                      prediction="perfect", seed=2).mean_response
          for p in ("srpt", "sprpt-lp", "sjf", "fcfs")}
    assert rs["srpt"] <= rs["sprpt-lp"] * 1.05
    assert rs["sprpt-lp"] < rs["sjf"]
    assert rs["sjf"] < rs["fcfs"]


def test_limited_preemption_reduces_memory():
    """Appendix D: smaller C -> fewer preemptions and lower mean memory."""
    lam = 0.85
    big = simulate("sprpt-lp", lam, C=1.0, n_jobs=40000, seed=3)
    small = simulate("sprpt-lp", lam, C=0.2, n_jobs=40000, seed=3)
    assert small.preemptions < big.preemptions
    assert small.mean_memory < big.mean_memory
    # and the response-time cost of limiting is modest at this load
    assert small.mean_response < big.mean_response * 1.2


def test_response_xr_monotone_in_x():
    l1 = Lemma1(MG1Config(lam=0.5, C=0.8, prediction="exponential"))
    xs = [0.5, 1.0, 2.0, 4.0]
    vals = [l1.response_xr(x, 1.0) for x in xs]
    assert all(b > a for a, b in zip(vals, vals[1:]))
