"""Cross-request prefix cache: BlockManager refcount/index/COW invariants,
cached-aware engine behaviour, shared-prefix workloads, prefix-affinity
routing, and real-mode token parity."""

import copy

import pytest

from _hypothesis_fallback import given, settings, st
from repro.cluster import Router, RouterConfig, run_cluster
from repro.config import get_config, get_smoke_config
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import Engine, EngineConfig, run_policy
from repro.serving.kv_cache import BlockManager
from repro.serving.workload import (TenantSpec, WorkloadConfig, generate,
                                    scenario_config)

CFG = get_config("granite-3-8b")
HW = HardwareSpec(name="compute-bound-2tf", peak_flops=2e12, hbm_bw=819e9,
                  overhead_s=2e-4)


def shared_prompt_workload(n=40, rate=20.0, seed=4, prefix_len=64):
    """Single-tenant stream where every prompt carries one shared prefix."""
    wc = WorkloadConfig(n_requests=n, request_rate=rate, seed=seed,
                        vocab=CFG.vocab_size, split_streams=True,
                        prefix_len=prefix_len)
    return generate(wc)


# ---------------------------------------------------------------------------
# BlockManager: match / link / register
# ---------------------------------------------------------------------------

def test_match_link_register_roundtrip():
    bm = BlockManager(num_pages=16, page_size=4, prefix_cache=True)
    toks = list(range(100, 116))            # 4 full pages
    assert bm.ensure(1, 16)
    bm.note_cached(1, 16)
    assert bm.register_prefix(1, toks, 16) == 4
    # a second request links the whole chain without allocating
    free_before = bm.free_pages()
    hit = bm.link_prefix(2, toks)
    assert hit == 16
    assert bm.block_table(2) == bm.block_table(1)
    assert bm.free_pages() == free_before
    for pid in bm.block_table(1):
        assert bm.refcount[pid] == 2


def test_match_is_chained_not_per_block():
    """An identical second block under a different first block must not
    match: the key chains through the parent's physical id."""
    bm = BlockManager(num_pages=16, page_size=4, prefix_cache=True)
    a = [1, 2, 3, 4, 9, 9, 9, 9]
    b = [5, 6, 7, 8, 9, 9, 9, 9]            # same 2nd block, different 1st
    bm.ensure(1, 8)
    bm.register_prefix(1, a, 8)
    pids, hit = bm.match_prefix(b)
    assert hit == 0 and pids == []
    pids, hit = bm.match_prefix(a)
    assert hit == 8


def test_partial_tail_page_never_indexed():
    bm = BlockManager(num_pages=8, page_size=4, prefix_cache=True)
    toks = list(range(10))                  # 2 full pages + 2 tokens
    bm.ensure(1, 10)
    assert bm.register_prefix(1, toks, 10) == 2
    _, hit = bm.match_prefix(toks)
    assert hit == 8                         # the partial page cannot match


def test_finished_request_pages_stay_warm_and_lru_reclaims():
    bm = BlockManager(num_pages=4, page_size=4, prefix_cache=True)
    toks = list(range(50, 58))
    bm.ensure(1, 8)
    bm.register_prefix(1, toks, 8)
    pages = bm.block_table(1)
    freed = bm.free_request(1)
    assert freed == pages                   # left the used set...
    assert all(p in bm._reusable for p in pages)    # ...parked warm
    assert all(p not in bm.free for p in pages)     # ...not reset-freed
    assert bm.free_pages() == 4             # but counted as capacity
    assert bm.used_pages() == 0
    # still hittable
    assert bm.link_prefix(2, toks) == 8
    bm.free_request(2)
    # demanding the full pool reclaims the warm pages LRU-first and
    # deregisters them
    assert bm.ensure(3, 16)
    assert bm.match_prefix(toks)[1] == 0


def test_reclaim_cascades_to_descendants():
    """Reclaiming an indexed page must deregister its chained children:
    their keys name its physical id, which may be reused for different
    content."""
    bm = BlockManager(num_pages=2, page_size=4, prefix_cache=True)
    toks = list(range(70, 78))
    bm.ensure(1, 8)
    bm.register_prefix(1, toks, 8)
    bm.free_request(1)
    # take one page: reclaims the LRU (root) page and must cascade
    assert bm.ensure(2, 4)
    assert bm.match_prefix(toks)[1] == 0
    assert not bm._index and not bm._key_of


def test_cow_gives_private_copy_and_preserves_shared_page():
    bm = BlockManager(num_pages=8, page_size=4, prefix_cache=True)
    toks = list(range(30, 38))
    bm.ensure(1, 8)
    bm.register_prefix(1, toks, 8)
    bm.link_prefix(2, toks)
    shared = list(bm.block_table(2))
    ops = bm.make_writable(2, 4)            # page 1 must be copied
    assert len(ops) == 1 and ops[0][0] == shared[1]
    assert bm.block_table(1) == shared      # owner's table untouched
    assert bm.block_table(2)[0] == shared[0]
    assert bm.block_table(2)[1] != shared[1]
    assert bm.refcount[shared[1]] == 1      # back to sole ownership
    assert bm.refcount[bm.block_table(2)[1]] == 1


def test_eviction_stops_at_shared_pages():
    bm = BlockManager(num_pages=8, page_size=4, prefix_cache=True)
    toks = list(range(40, 48))
    bm.ensure(1, 12)                        # 2 shared-able + 1 private page
    bm.note_cached(1, 12)
    bm.register_prefix(1, toks, 8)
    bm.link_prefix(2, toks)
    freed = bm.evict_tail(1, 3)
    assert len(freed) == 1                  # only the unshared tail page
    assert bm.resident_pages(1) == 2
    assert bm.unshared_tail_pages(1) == 0
    assert bm.evict_tail(1, 1) == []        # shared tail: nothing to take


def test_swap_in_is_atomic_on_exhausted_pool():
    bm = BlockManager(num_pages=4, page_size=4)
    bm.ensure(1, 16)
    bm.note_cached(1, 16)
    bm.swap_out_tail(1, 2)
    assert bm.host_pages[1] == 2
    bm.ensure(2, 8)                         # eat the freed capacity
    pages_before = list(bm.pages[1])
    assert bm.swap_in(1) == 0               # cannot fit: must be a no-op
    assert bm.pages[1] == pages_before
    assert bm.host_pages[1] == 2
    bm.free_request(2)
    assert bm.swap_in(1) == 2
    assert bm.resident_tokens(1) == 16


# ---------------------------------------------------------------------------
# refcount invariants under random interleavings (hypothesis)
# ---------------------------------------------------------------------------

def _check_invariants(bm: BlockManager, n_pages: int):
    owned = [p for ps in bm.pages.values() for p in ps]
    # no page appears in two block-table positions
    assert len(set(owned)) == len(owned) or bm.prefix_cache
    # refcount of every owned page equals its number of owners
    counts = {}
    for ps in bm.pages.values():
        for p in ps:
            counts[p] = counts.get(p, 0) + 1
    for p, c in counts.items():
        assert bm.refcount[p] == c, f"page {p}: refcount != owners"
    # every physical page is exactly one of: free-listed, reusable, owned
    free, reusable = set(bm.free), set(bm._reusable)
    used = set(counts)
    assert len(bm.free) == len(free)                # free-listed once
    assert not (free & reusable) and not (free & used)
    assert not (reusable & used)
    assert len(free) + len(reusable) + len(used) == n_pages
    # reusable pages hold refcount 0; owned pages >= 1
    for p in reusable:
        assert bm.refcount[p] == 0
    # indexed pages resolve back to themselves
    for pid, key in bm._key_of.items():
        assert bm._index[key] == pid


@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 5),
                          st.integers(1, 40)),
                min_size=1, max_size=80))
@settings(max_examples=60, deadline=None)
def test_refcount_invariants_any_interleaving(ops):
    """Any interleaving of admit/link/register/evict/swap/free keeps every
    physical page either free-listed exactly once or referenced with
    refcount >= 1 (or parked reusable at refcount 0), and COW never
    mutates a shared page in place."""
    n_pages = 12
    bm = BlockManager(num_pages=n_pages, page_size=4, prefix_cache=True)
    toks = [100 + i for i in range(48)]     # one shared prompt universe
    frozen = {}                             # pid -> key when registered
    for op, rid, amount in ops:
        if op == 0:                         # admit/grow
            if not bm.pages.get(rid):
                bm.link_prefix(rid, toks[:amount])
            bm.ensure(rid, amount)
            bm.note_cached(rid, amount)
        elif op == 1:                       # publish prompt pages
            bm.register_prefix(rid, toks, min(amount,
                                              bm.resident_tokens(rid)))
        elif op == 2:
            bm.evict_tail(rid, amount % 5)
        elif op == 3:
            try:
                bm.make_writable(rid, amount % 8)
            except RuntimeError:
                pass                        # tiny pool exhausted mid-COW:
                                            # partial COW must stay valid
            bm.swap_out_tail(rid, amount % 3)
            bm.swap_in(rid)
        elif op == 4:
            bm.free_request(rid)
        else:
            bm.free_request(rid)
            bm.link_prefix(rid, toks[:amount])
        # a page's registered identity never changes while indexed: COW
        # and reuse must replace pages, not rewrite them
        for pid, key in bm._key_of.items():
            assert frozen.setdefault(pid, key) == key
        for pid in list(frozen):
            if pid not in bm._key_of:
                del frozen[pid]             # deregistered: id reusable
        _check_invariants(bm, n_pages)


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 9)),
                min_size=1, max_size=60))
@settings(max_examples=25, deadline=None)
def test_cancel_any_interleaving_releases_all_pages(ops):
    """Interleaving step() with cancel() — hitting requests in every state
    (pending, waiting, running, swapped-out) — keeps the refcount
    partition intact, and cancelling everything leaves zero resident
    pages: the leak invariant the chaos benchmarks enforce. Shared
    prompt prefixes make the release path go through deregistration,
    never a blind free of pages other requests still reference."""
    reqs = shared_prompt_workload(n=10, rate=50.0, seed=7)
    eng = Engine(CFG, EngineConfig(kv_layout="paged", prefix_cache=True,
                                   policy="trail", seed=1, max_batch=4,
                                   mem_budget=1 << 26))
    for r in copy.deepcopy(reqs):
        eng.submit(r)
    rids = [r.rid for r in reqs]
    for op, k in ops:
        if op == 0:
            eng.step()
        else:
            eng.cancel(rids[k % len(rids)],
                       reason="cancel" if op == 1 else "shed")
        _check_invariants(eng.blocks, eng.blocks.num_pages)
    for rid in rids:
        eng.cancel(rid)             # False for already finished/cancelled
    _check_invariants(eng.blocks, eng.blocks.num_pages)
    assert eng.blocks.used_pages() == 0
    assert not eng.has_work()


# ---------------------------------------------------------------------------
# engine: cached-aware serving (sim mode)
# ---------------------------------------------------------------------------

def test_prefix_cache_requires_paged_pure_attention():
    with pytest.raises(ValueError):
        Engine(CFG, EngineConfig(kv_layout="contig", prefix_cache=True))
    with pytest.raises(ValueError):
        Engine(get_config("mamba2-370m"),
               EngineConfig(kv_layout="paged", prefix_cache=True))


def test_prefix_hits_cut_prefill_and_latency():
    reqs = shared_prompt_workload(n=60, rate=0.9, prefix_len=256)
    base = run_policy(CFG, "trail", copy.deepcopy(reqs), mode="sim", seed=5,
                      kv_layout="paged", hardware=HW)
    cached = run_policy(CFG, "trail", copy.deepcopy(reqs), mode="sim",
                        seed=5, kv_layout="paged", hardware=HW,
                        prefix_cache=True)
    assert base.prefix_hit_tokens == 0
    assert cached.prefix_hit_tokens > 0
    assert cached.prefilled_tokens < base.prefilled_tokens
    assert len(cached.latencies) == len(reqs)
    mean = lambda v: sum(v) / len(v)
    assert mean(cached.latencies) < mean(base.latencies)


def test_zero_hit_dial_yields_no_sharing():
    wc = WorkloadConfig(n_requests=30, request_rate=5.0, seed=7,
                        vocab=CFG.vocab_size, split_streams=True,
                        prefix_len=64, prefix_hit=0.0)
    reqs = generate(wc)
    s = run_policy(CFG, "trail", reqs, mode="sim", seed=5,
                   kv_layout="paged", prefix_cache=True)
    assert s.prefix_hit_tokens == 0


def test_disabled_flag_matches_default_paged_run():
    reqs = shared_prompt_workload(n=40)
    a = run_policy(CFG, "trail", copy.deepcopy(reqs), mode="sim", seed=5,
                   kv_layout="paged")
    b = run_policy(CFG, "trail", copy.deepcopy(reqs), mode="sim", seed=5,
                   kv_layout="paged", prefix_cache=False)
    assert a.latencies == b.latencies
    assert a.prefilled_tokens == b.prefilled_tokens


# ---------------------------------------------------------------------------
# workload: shared-prefix generation
# ---------------------------------------------------------------------------

def test_tenant_prefixes_shared_within_not_across():
    wc = scenario_config("shared-prefix", n_requests=80, request_rate=5.0,
                         seed=3, vocab=CFG.vocab_size)
    reqs = generate(wc)
    by_tenant = {}
    for r in reqs:
        by_tenant.setdefault(r.tenant, []).append(r)
    lens = {"chat": 192, "code": 384, "summarize": 96}
    heads = {}
    for tenant, rs in by_tenant.items():
        pl = lens[tenant]
        head = rs[0].prompt[:pl]
        heads[tenant] = tuple(head)
        for r in rs:
            assert r.prompt[:pl] == head
    assert len(set(heads.values())) == len(heads)   # distinct across tenants


def test_prefix_requires_split_streams():
    with pytest.raises(ValueError):
        generate(WorkloadConfig(n_requests=4, prefix_len=16))
    with pytest.raises(ValueError):
        generate(WorkloadConfig(n_requests=4, tenants=(
            TenantSpec("t", 1.0, prefix_len=16),)))


def test_hit_dial_preserves_arrivals_and_lengths():
    kw = dict(n_requests=40, request_rate=5.0, seed=9,
              vocab=CFG.vocab_size, split_streams=True, prefix_len=32)
    full = generate(WorkloadConfig(prefix_hit=1.0, **kw))
    none = generate(WorkloadConfig(prefix_hit=0.0, **kw))
    assert [r.arrival for r in full] == [r.arrival for r in none]
    assert [len(r.prompt) for r in full] == [len(r.prompt) for r in none]
    assert [r.true_out_len for r in full] == [r.true_out_len for r in none]


# ---------------------------------------------------------------------------
# router: kv headroom + prefix affinity
# ---------------------------------------------------------------------------

def _paged_engine(seed=0, **kw):
    return Engine(CFG, EngineConfig(policy="trail", kv_layout="paged",
                                    prefix_cache=True, seed=seed,
                                    hardware=HW, **kw))


def test_step_result_reports_headroom():
    eng = Engine(CFG, EngineConfig(policy="trail", seed=1,
                                   mem_budget=2 * (1 << 30)))
    for r in shared_prompt_workload(n=4, rate=100.0):
        eng.submit(r)
    while eng.has_work():
        res = eng.step()
        assert 0.0 <= res.kv_headroom <= 1.0
        assert res.kv_headroom == eng.kv_headroom()


def test_jspw_ties_break_on_headroom():
    e_full = Engine(CFG, EngineConfig(policy="trail", seed=0))
    e_free = Engine(CFG, EngineConfig(policy="trail", seed=1))
    e_full._last_mem, e_full.ecfg.mem_budget = 900, 1000
    e_free._last_mem, e_free.ecfg.mem_budget = 100, 1000
    assert e_full.backlog() == e_free.backlog() == 0.0
    router = Router([e_full, e_free],
                    RouterConfig(n_replicas=2, policy="jspw", seed=0))
    req = shared_prompt_workload(n=1)[0]
    assert router._pick(req) == 1           # more headroom wins the tie


def test_prefix_affinity_joins_warm_replica():
    reqs = shared_prompt_workload(n=6, rate=1e9, prefix_len=64)
    warm, cold = _paged_engine(seed=0), _paged_engine(seed=1)
    for r in reqs[:3]:
        warm.submit(r)
    while warm.has_work():
        warm.step()
    probe = reqs[3]
    assert warm.cached_prefix_tokens(probe.prompt) >= 64 - 16
    assert cold.cached_prefix_tokens(probe.prompt) == 0
    router = Router([cold, warm], RouterConfig(n_replicas=2,
                                               policy="prefix-affinity",
                                               seed=0))
    assert router._pick(probe) == 1         # despite equal queues
    # ties (no hit anywhere) fall back to jspw: a fresh unmatched prompt
    # goes wherever plain jspw would send it
    fresh = copy.deepcopy(probe)
    fresh.prompt = [1] * 80
    jspw = Router([cold, warm], RouterConfig(n_replicas=2, policy="jspw",
                                             seed=0))
    assert router._pick(fresh) == jspw._pick(fresh)


def test_prefix_affinity_cluster_end_to_end():
    wc = scenario_config("shared-prefix", n_requests=80, request_rate=0.9,
                         seed=3, vocab=CFG.vocab_size)
    reqs = generate(wc)
    s = run_cluster(CFG, reqs, router_policy="prefix-affinity",
                    n_replicas=2, policy="trail", seed=5, hardware=HW,
                    kv_layout="paged", prefix_cache=True)
    d = s.summary()
    assert d["finished"] == len(reqs)
    assert d["prefix_hit_tokens"] > 0
    base = run_cluster(CFG, reqs, router_policy="round-robin",
                       n_replicas=2, policy="trail", seed=5, hardware=HW,
                       kv_layout="paged", prefix_cache=False)
    assert d["mean_latency"] < base.summary()["mean_latency"]
    assert d["prefilled_tokens"] < base.summary()["prefilled_tokens"]


# ---------------------------------------------------------------------------
# real mode: linked pages reproduce the uncached token streams
# ---------------------------------------------------------------------------

@pytest.mark.real
def test_real_mode_prefix_cache_token_parity():
    """Greedy decode over linked shared pages must emit exactly the same
    tokens as the uncached run — the device-level proof that linked pages
    hold the right KV and COW/reset bookkeeping never corrupts them."""
    import jax

    from repro.models.model import Model
    from repro.serving.predictors import ProbePredictor

    cfg = get_smoke_config("trail-llama")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    wc = WorkloadConfig(n_requests=6, request_rate=30.0, seed=1,
                        vocab=cfg.vocab_size, prompt_mean=6.0,
                        out_median=6.0, max_out=12, split_streams=True,
                        prefix_len=16, prefix_hit=1.0)
    reqs = generate(wc)

    def run(flag):
        pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                              embed_table=params["embed"])
        ecfg = EngineConfig(policy="trail", max_batch=3, mode="real",
                            kv_layout="paged", page_size=8, max_len=64,
                            prefix_cache=flag)
        eng = Engine(cfg, ecfg, predictor=pred, model=m, params=params)
        for r in sorted(copy.deepcopy(reqs), key=lambda r: r.arrival):
            eng.submit(r)
        done = []
        while eng.has_work():
            done.extend(eng.step().completed)
        return eng.stats, {r.rid: list(r.generated) for r in done}

    base, base_toks = run(False)
    cached, cached_toks = run(True)
    assert cached.prefix_hit_tokens > 0
    assert cached.prefilled_tokens < base.prefilled_tokens
    assert len(cached.latencies) == len(reqs)
    assert cached_toks == base_toks
