"""Scheduler unit + hypothesis property tests (SPRPT-LP invariants)."""

import math

from _hypothesis_fallback import given, settings, st

from repro.core.scheduler import (Decision, ReqState, SchedEntry, select_batch)


def mk(rid, arrival=0.0, r0=10.0, age=0, state=ReqState.WAITING, c=0.8,
       pred=None, prompt=16):
    e = SchedEntry(rid=rid, arrival=arrival, prompt_len=prompt, r0=r0,
                   pred_remaining=pred if pred is not None else r0,
                   age=age, c_limit=c, state=state)
    return e


def bytes_fn(e):
    return 100 * (e.prompt_len + e.age)


def test_rank_function_matches_paper():
    # rank = r - a while a < floor(C*r), else -inf (only when running)
    e = mk(0, r0=10.0, age=3, state=ReqState.RUNNING, c=0.8, pred=7.0)
    assert e.a0 == 8
    assert e.preemptable
    assert e.rank("trail") == 7.0
    assert e.rank("trail-bert") == 7.0
    e.age = 8
    e.pred_remaining = 2.0
    assert not e.preemptable
    assert e.rank("trail") == float("-inf")
    # srpt (C=1 in paper notation) never pins
    assert e.rank("srpt") == 2.0


def test_c_zero_means_no_preemption_after_start():
    e = mk(0, r0=10.0, age=0, state=ReqState.RUNNING, c=0.0)
    assert e.a0 == 0 and not e.preemptable
    assert e.rank("trail") == float("-inf")


def test_pinned_jobs_always_scheduled():
    entries = {
        0: mk(0, arrival=0, r0=10, age=9, state=ReqState.RUNNING, pred=1.0),
        1: mk(1, arrival=1, r0=2, state=ReqState.WAITING, pred=2.0),
    }
    entries[0].pred_remaining = 50.0     # terrible rank, but pinned (age>=a0)
    d = select_batch(entries, policy="trail", max_batch=1,
                     mem_budget=1 << 60, bytes_fn=bytes_fn)
    assert 0 in d.scheduled
    assert d.preempted == []


def test_fcfs_never_preempts():
    entries = {
        0: mk(0, arrival=0.0, state=ReqState.RUNNING, r0=100),
        1: mk(1, arrival=1.0, state=ReqState.WAITING, r0=1),
    }
    d = select_batch(entries, policy="fcfs", max_batch=1,
                     mem_budget=1 << 60, bytes_fn=bytes_fn)
    assert d.scheduled == [0] and d.preempted == []


def test_trail_preempts_preemptable_running():
    entries = {
        0: mk(0, arrival=0.0, state=ReqState.RUNNING, r0=100, age=1,
              pred=99.0),
        1: mk(1, arrival=1.0, state=ReqState.WAITING, r0=2, pred=2.0),
    }
    d = select_batch(entries, policy="trail", max_batch=1,
                     mem_budget=1 << 60, bytes_fn=bytes_fn)
    assert d.scheduled == [1]
    assert d.preempted == [0]
    assert d.admitted == [1]


def test_megastep_lookahead_pins_finishing_jobs():
    """k-token lookahead (engine decode megasteps): a RUNNING job whose
    predicted remaining length fits inside the upcoming megastep is never
    preempted — it would have finished within k tokens. lookahead=1 (the
    per-token loop) keeps the old decision exactly."""
    def fresh():
        return {
            0: mk(0, arrival=0.0, state=ReqState.RUNNING, r0=100, age=1,
                  pred=3.0),     # would finish within a k=4 megastep
            1: mk(1, arrival=1.0, state=ReqState.WAITING, r0=2, pred=2.0),
        }
    d = select_batch(fresh(), policy="trail", max_batch=1,
                     mem_budget=1 << 60, bytes_fn=bytes_fn)
    assert d.preempted == [0]           # per-token: rank 2.0 < 3.0 wins
    d = select_batch(fresh(), policy="trail", max_batch=1,
                     mem_budget=1 << 60, bytes_fn=bytes_fn, lookahead=4)
    assert 0 in d.scheduled and d.preempted == []
    # the pin claims its slot FIRST: the better-ranked waiting job must
    # not be admitted alongside it past max_batch (slot pool would burst)
    assert d.scheduled == [0] and d.admitted == []
    # a job that cannot finish within the megastep is still preemptable
    entries = fresh()
    entries[0].pred_remaining = 9.0
    d = select_batch(entries, policy="trail", max_batch=1,
                     mem_budget=1 << 60, bytes_fn=bytes_fn, lookahead=4)
    assert d.preempted == [0]


states = st.sampled_from([ReqState.WAITING, ReqState.RUNNING,
                          ReqState.PREEMPTED])


@st.composite
def entry_strategy(draw, rid):
    r0 = draw(st.floats(0.5, 512.0))
    return mk(rid,
              arrival=draw(st.floats(0.0, 100.0)),
              r0=r0,
              age=draw(st.integers(0, 600)),
              state=draw(states),
              c=draw(st.sampled_from([0.0, 0.2, 0.5, 0.8, 1.0])),
              pred=draw(st.floats(0.0, 512.0)),
              prompt=draw(st.integers(1, 128)))


@given(st.integers(1, 14).flatmap(
    lambda n: st.tuples(*[entry_strategy(i) for i in range(n)])),
    st.integers(1, 8), st.sampled_from([10_000, 200_000, 1 << 60]),
    st.sampled_from(["fcfs", "sjf", "srpt", "trail", "trail-bert"]))
@settings(max_examples=200, deadline=None)
def test_select_batch_invariants(entries_tuple, max_batch, mem_budget, policy):
    entries = {e.rid: e for e in entries_tuple}
    d = select_batch(entries, policy=policy, max_batch=max_batch,
                     mem_budget=mem_budget, bytes_fn=bytes_fn)
    sched = set(d.scheduled)
    assert len(sched) == len(d.scheduled), "duplicates"

    pinned = {e.rid for e in entries.values()
              if e.state is ReqState.RUNNING
              and (policy in ("fcfs", "sjf") or
                   (policy != "srpt" and not e.preemptable))}
    # 1. pinned running jobs always stay
    assert pinned <= sched
    # 2. budget respected by non-pinned selections
    extra = [entries[r] for r in sched - pinned]
    assert len(sched) <= max(max_batch, len(pinned))
    used_pinned = sum(bytes_fn(entries[r]) for r in pinned)
    used = used_pinned + sum(bytes_fn(e) for e in extra)
    if extra:
        assert used <= max(mem_budget, used_pinned)
    # 3. preempted = running not scheduled; admitted = non-running scheduled
    for e in entries.values():
        if e.state is ReqState.RUNNING and e.rid not in sched:
            assert e.rid in d.preempted
        if e.state is not ReqState.RUNNING and e.rid in sched:
            assert e.rid in d.admitted
    # 4. fcfs/sjf never preempt
    if policy in ("fcfs", "sjf"):
        assert not d.preempted
    # 5. a0 is the paper's floor(C * r0)
    for e in entries.values():
        assert e.a0 == math.floor(e.c_limit * max(e.r0, 0.0))
