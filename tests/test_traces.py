"""Trace subsystem tests: loader normalization, synthesis correlation,
the bundled-fixture byte pin, replay determinism, and the open-loop
driver's equivalence with the engine's batch API."""

import json
import math
import os

import numpy as np
import pytest

from repro.config import get_config
from repro.metrics import EventLog, report_json, rollup
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import Engine, EngineConfig
from repro.traces import (ReplayConfig, SAMPLE_CONFIG, SynthesisConfig,
                          TenantTraceSpec, load_csv, load_jsonl, load_trace,
                          normalize, replay, requests_from_trace,
                          sample_trace, sample_trace_path, save_jsonl,
                          synthesize)
from repro.traces.schema import TraceRecord

CFG = get_config("granite-3-8b")
HW = HardwareSpec(name="compute-bound-2tf", peak_flops=2e12, hbm_bw=819e9,
                  overhead_s=2e-4)


# ---------------------------------------------------------------------------
# loaders + schema
# ---------------------------------------------------------------------------

def test_jsonl_loader_flexible_keys(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text(
        '{"ts": 5.0, "context_tokens": 10, "generated_tokens": 4}\n'
        '\n'    # blank lines tolerated
        '{"TIMESTAMP": 2.5, "ContextTokens": 7, "GeneratedTokens": 3,'
        ' "tenant": "chat"}\n')
    tr = load_jsonl(str(p))
    # sorted by arrival and rebased to zero
    assert [r.arrival for r in tr.records] == [0.0, 2.5]
    assert tr.records[0].tenant == "chat"
    assert tr.records[1].prompt_tokens == 10


def test_csv_loader_azure_columns_and_iso_timestamps(tmp_path):
    p = tmp_path / "t.csv"
    p.write_text("TIMESTAMP,ContextTokens,GeneratedTokens\n"
                 "2023-11-16T18:00:01,100,20\n"
                 "2023-11-16T18:00:00,50,0\n")     # zero output clamped
    tr = load_csv(str(p))
    assert [r.arrival for r in tr.records] == [0.0, 1.0]
    assert tr.records[0].prompt_tokens == 50
    assert tr.records[0].output_tokens == 1        # clamped, not dropped
    assert tr.mean_rate == pytest.approx(1.0)


def test_load_trace_dispatch_and_unknown_ext(tmp_path):
    with pytest.raises(ValueError, match="unknown trace format"):
        load_trace(str(tmp_path / "t.parquet"))
    missing = tmp_path / "t.jsonl"
    with pytest.raises(FileNotFoundError):
        load_trace(str(missing))


def test_loader_missing_column_raises(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text('{"ts": 1.0, "generated_tokens": 4}\n')
    with pytest.raises(ValueError, match="prompt-length"):
        load_jsonl(str(p))


def test_non_strict_jsonl_skips_bad_rows_with_counted_warning(tmp_path):
    """strict=False drops malformed rows (bad JSON, missing columns, bad
    timestamps) instead of raising, warns once with the count, and
    records it in trace.meta; the good rows load unchanged."""
    p = tmp_path / "dirty.jsonl"
    p.write_text(
        '{"ts": 1.0, "context_tokens": 10, "generated_tokens": 4}\n'
        'this is not json\n'
        '{"ts": 2.0, "generated_tokens": 4}\n'          # missing prompt col
        '{"ts": "NOT-A-TIME", "context_tokens": 1, "generated_tokens": 1}\n'
        '{"ts": 3.0, "context_tokens": 5, "generated_tokens": 2}\n')
    with pytest.raises(ValueError):
        load_jsonl(str(p))                              # strict default
    with pytest.warns(UserWarning, match=r"skipped 3 malformed"):
        tr = load_jsonl(str(p), strict=False)
    assert len(tr.records) == 2
    assert [r.prompt_tokens for r in tr.records] == [10, 5]
    assert tr.meta["skipped_rows"] == 3


def test_non_strict_csv_skips_bad_rows(tmp_path):
    p = tmp_path / "dirty.csv"
    p.write_text("TIMESTAMP,ContextTokens,GeneratedTokens\n"
                 "1.0,100,20\n"
                 "oops,not,numbers\n"
                 "2.0,50,10\n")
    with pytest.raises(ValueError):
        load_csv(str(p))
    with pytest.warns(UserWarning, match=r"skipped 1 malformed"):
        tr = load_trace(str(p), strict=False)
    assert len(tr.records) == 2
    assert tr.meta["skipped_rows"] == 1


def test_strict_and_clean_loads_have_no_skip_meta(tmp_path):
    """A clean file loads identically in both modes — no warning, no
    skipped_rows key (non-strict must not perturb clean pipelines)."""
    p = tmp_path / "clean.jsonl"
    p.write_text('{"ts": 1.0, "context_tokens": 2, "generated_tokens": 1}\n')
    a, b = load_jsonl(str(p)), load_jsonl(str(p), strict=False)
    assert "skipped_rows" not in a.meta and "skipped_rows" not in b.meta
    assert [r.as_dict() for r in a.records] == \
        [r.as_dict() for r in b.records]


def test_non_strict_still_rejects_json_array(tmp_path):
    """A whole-file JSON array is a format error, not a row error."""
    p = tmp_path / "array.jsonl"
    p.write_text('[{"ts": 1.0, "context_tokens": 2, '
                 '"generated_tokens": 1}]\n')
    with pytest.raises(ValueError, match="JSON array"):
        load_jsonl(str(p), strict=False)


def test_loader_limit_keeps_earliest_not_file_order(tmp_path):
    """`limit` must slice after the sort: an unsorted export's cap keeps
    the earliest arrivals and rebases t=0 on the true earliest record."""
    p = tmp_path / "unsorted.jsonl"
    p.write_text(
        '{"ts": 30.0, "context_tokens": 3, "generated_tokens": 1}\n'
        '{"ts": 10.0, "context_tokens": 1, "generated_tokens": 1}\n'
        '{"ts": 20.0, "context_tokens": 2, "generated_tokens": 1}\n')
    tr = load_jsonl(str(p), limit=2)
    assert [r.arrival for r in tr.records] == [0.0, 10.0]
    assert [r.prompt_tokens for r in tr.records] == [1, 2]


def test_normalize_clamps_and_sorts():
    tr = normalize([TraceRecord(3.0, 0, -2), TraceRecord(1.0, 5, 5)])
    assert [r.arrival for r in tr.records] == [0.0, 2.0]
    assert tr.records[1].prompt_tokens == 1
    assert tr.records[1].output_tokens == 1


# ---------------------------------------------------------------------------
# bundled fixture + synthesis
# ---------------------------------------------------------------------------

def test_sample_fixture_matches_synthesis_bytes(tmp_path):
    """The checked-in JSONL is exactly `sample_trace()` re-serialized —
    the fixture stays auditable/regenerable from code."""
    regen = tmp_path / "regen.jsonl"
    save_jsonl(sample_trace(), str(regen))
    assert regen.read_bytes() == open(sample_trace_path(), "rb").read()


def test_sample_fixture_shape():
    tr = load_trace("sample")
    st = tr.stats()
    assert st["n"] == SAMPLE_CONFIG.n_requests
    assert st["tenants"] == ["chat", "code", "rag"]
    assert st["mean_rate"] == pytest.approx(SAMPLE_CONFIG.mean_rate,
                                            rel=0.15)


def _log_corr(records):
    p = np.log([r.prompt_tokens for r in records])
    o = np.log([r.output_tokens for r in records])
    return float(np.corrcoef(p, o)[0, 1])


@pytest.mark.parametrize("method", ["copula", "rank-shuffle"])
def test_synthesis_correlation_sign_and_strength(method):
    sc = SynthesisConfig(
        n_requests=1200, mean_rate=1.0, method=method, seed=7,
        tenants=(TenantTraceSpec("pos", 0.5, rho=0.7),
                 TenantTraceSpec("neg", 0.5, prompt_median=200.0,
                                 out_median=24.0, rho=-0.6)))
    tr = synthesize(sc)
    pos = [r for r in tr.records if r.tenant == "pos"]
    neg = [r for r in tr.records if r.tenant == "neg"]
    assert _log_corr(pos) > 0.5
    assert _log_corr(neg) < -0.4


def test_rank_shuffle_preserves_marginals():
    """Rank shuffle must reorder, not redraw: the output-length multiset
    equals an independent (rho=0) draw's multiset under the same seed."""
    base = SynthesisConfig(n_requests=400, method="rank-shuffle", seed=3,
                           tenants=(TenantTraceSpec("t", rho=0.0),))
    coupled = SynthesisConfig(n_requests=400, method="rank-shuffle", seed=3,
                              tenants=(TenantTraceSpec("t", rho=0.9),))
    outs_a = sorted(r.output_tokens for r in synthesize(base).records)
    outs_b = sorted(r.output_tokens for r in synthesize(coupled).records)
    assert outs_a == outs_b


def test_synthesis_deterministic_in_seed():
    sc = SynthesisConfig(n_requests=50, seed=9)
    a = [r.as_dict() for r in synthesize(sc).records]
    b = [r.as_dict() for r in synthesize(sc).records]
    assert a == b


# ---------------------------------------------------------------------------
# replay materialization
# ---------------------------------------------------------------------------

def test_requests_from_trace_deterministic_and_clipped():
    tr = load_trace("sample")
    rcfg = ReplayConfig(seed=5, vocab=700, limit=40, max_prompt=64,
                        max_output=32)
    a = requests_from_trace(tr, rcfg)
    b = requests_from_trace(tr, rcfg)
    assert len(a) == 40
    assert [(r.arrival, tuple(r.prompt), r.true_out_len) for r in a] == \
           [(r.arrival, tuple(r.prompt), r.true_out_len) for r in b]
    assert max(len(r.prompt) for r in a) <= 64
    assert max(r.true_out_len for r in a) <= 32
    assert all(1 <= t < 700 for r in a for t in r.prompt)
    assert [r.tenant for r in a] == [rec.tenant for rec in tr.records[:40]]


def test_rate_scale_and_time_warp_compress_arrivals():
    tr = load_trace("sample")
    base = requests_from_trace(tr, ReplayConfig(limit=50))
    fast = requests_from_trace(tr, ReplayConfig(limit=50, rate_scale=2.0))
    warp = requests_from_trace(tr, ReplayConfig(limit=50, rate_scale=2.0,
                                                time_warp=2.0))
    for b, f, w in zip(base, fast, warp):
        assert f.arrival == pytest.approx(b.arrival / 2.0)
        assert w.arrival == pytest.approx(b.arrival / 4.0)
    # lengths and content are untouched by time rescaling
    assert [r.prompt for r in base] == [r.prompt for r in fast]
    with pytest.raises(ValueError):
        requests_from_trace(tr, ReplayConfig(rate_scale=0.0))


# ---------------------------------------------------------------------------
# open-loop driver + determinism acceptance pin
# ---------------------------------------------------------------------------

def _engine(policy="trail", event_log=None):
    return Engine(CFG, EngineConfig(policy=policy, hardware=HW, seed=0),
                  event_log=event_log)


def _replayed_requests(limit=40, scale=2.0):
    return requests_from_trace(
        load_trace("sample"),
        ReplayConfig(rate_scale=scale, seed=0, vocab=CFG.vocab_size,
                     limit=limit))


def test_replay_driver_matches_batch_run():
    """The open-loop driver and `Engine.run` are the same state machine:
    results must be byte-identical."""
    import copy
    reqs = _replayed_requests()
    s_replay = replay(_engine(), copy.deepcopy(reqs))
    s_batch = _engine().run(copy.deepcopy(reqs))
    assert s_replay.latencies == s_batch.latencies
    assert s_replay.ttfts == s_batch.ttfts
    assert s_replay.n_preemptions == s_batch.n_preemptions


def test_replay_metrics_bit_identical_across_runs():
    """ISSUE acceptance: same trace + seed -> byte-identical metrics
    JSON across two independent replays."""
    outs = []
    for _ in range(2):
        log = EventLog()
        replay(_engine(event_log=log), _replayed_requests())
        outs.append(report_json(rollup(log)))
    assert outs[0] == outs[1]
    rep = json.loads(outs[0])
    assert rep["requests"]["finished"] == 40
    for metric in ("ttft", "tbt", "completion"):
        assert rep[metric]["p99"] >= rep[metric]["p50"] >= 0.0


def test_replay_drives_router():
    from repro.cluster.router import Router, RouterConfig
    engines = [_engine(), _engine()]
    router = Router(engines, RouterConfig(n_replicas=2, policy="jsq"))
    stats = replay(router, _replayed_requests(limit=30))
    assert len(stats.latencies) == 30
    assert sum(stats.dispatch_counts) == 30


# ---------------------------------------------------------------------------
# workload integration (scenario_config trace sources)
# ---------------------------------------------------------------------------

def test_scenario_config_trace_source():
    from repro.serving.workload import generate, scenario_config
    wc = scenario_config("trace:sample", n_requests=50, request_rate=0.0,
                         seed=1, vocab=900)
    reqs = generate(wc)
    assert len(reqs) == 50
    tr = load_trace("sample", limit=50)
    assert [r.arrival for r in reqs] == \
           [rec.arrival for rec in tr.records]       # native rate
    assert [len(r.prompt) for r in reqs] == \
           [min(rec.prompt_tokens, 2048) for rec in tr.records]


def test_scenario_config_trace_rate_targeting():
    """request_rate > 0 converts to a rate-scale hitting that mean rate."""
    from repro.serving.workload import generate, scenario_config
    wc = scenario_config("trace:sample", n_requests=300, request_rate=2.0,
                         seed=1, vocab=900)
    reqs = generate(wc)
    emp = (len(reqs) - 1) / (reqs[-1].arrival - reqs[0].arrival)
    assert emp == pytest.approx(2.0, rel=1e-6)
    # explicit trace_rate_scale override wins
    wc2 = scenario_config("trace:sample", n_requests=300, request_rate=2.0,
                          seed=1, vocab=900, trace_rate_scale=1.0)
    assert wc2.trace_rate_scale == 1.0


def test_scenario_config_unknown_still_raises():
    from repro.serving.workload import scenario_config
    with pytest.raises(ValueError, match="unknown scenario"):
        scenario_config("nope", n_requests=4, request_rate=1.0)


def test_trace_path_roundtrip_through_workload(tmp_path):
    """A user-supplied trace file flows through WorkloadConfig.trace."""
    from repro.serving.workload import WorkloadConfig, generate
    p = tmp_path / "mini.jsonl"
    save_jsonl(normalize([TraceRecord(0.0, 8, 3),
                          TraceRecord(1.0, 6, 2, tenant="x")]), str(p))
    reqs = generate(WorkloadConfig(n_requests=0, seed=2, vocab=100,
                                   trace=str(p)))
    assert [(len(r.prompt), r.true_out_len, r.tenant) for r in reqs] == \
           [(8, 3, ""), (6, 2, "x")]
    assert os.path.exists(sample_trace_path())


def test_trace_replay_benchmark_smoke_cells():
    """One tiny benchmark cell end to end (the CI smoke path's core)."""
    from benchmarks.trace_replay import _run_cell
    tr = load_trace("sample")
    rep, js = _run_cell(CFG, tr, "trail", 16.0, limit=20)
    assert rep["requests"]["finished"] == 20
    assert not math.isnan(rep["completion"]["p99"])
    _, js2 = _run_cell(CFG, tr, "trail", 16.0, limit=20)
    assert js == js2
