"""Paged KV subsystem: BlockManager invariants, block-table correctness
across preempt->resume (including a slot move), paged engine behaviour in
sim and real modes, and page-granular memory accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.config import get_config, get_smoke_config
from repro.core.scheduler import ReqState, SchedEntry, select_batch
from repro.serving.engine import run_policy
from repro.serving.kv_cache import (BlockManager, PagedSlotPool,
                                    bytes_for_context, page_bytes,
                                    paged_bytes_for_context,
                                    supports_page_retention)
from repro.serving.workload import WorkloadConfig, generate

CFG = get_config("granite-3-8b")


# ---------------------------------------------------------------------------
# BlockManager
# ---------------------------------------------------------------------------

def test_block_manager_alloc_and_exhaustion():
    bm = BlockManager(num_pages=4, page_size=8)
    assert bm.ensure(1, 16)                 # 2 pages
    assert bm.ensure(2, 16)                 # 2 pages
    assert bm.free_pages() == 0
    assert not bm.ensure(3, 8)              # exhausted: allocates nothing
    assert bm.resident_pages(3) == 0
    bm.free_request(1)
    assert bm.free_pages() == 2
    assert bm.ensure(3, 8)
    # distinct physical ids across requests, all within the id range
    ids = bm.block_table(2) + bm.block_table(3)
    assert len(set(ids)) == len(ids)
    assert all(1 <= i <= 4 for i in ids)


def test_block_manager_partial_growth_is_atomic():
    bm = BlockManager(num_pages=3, page_size=8)
    assert bm.ensure(1, 16)
    assert not bm.ensure(2, 24)             # needs 3, only 1 free
    assert bm.resident_pages(2) == 0        # nothing allocated on failure
    assert bm.ensure(2, 8)


def test_block_manager_tail_eviction_clamps_cached_tokens():
    bm = BlockManager(num_pages=8, page_size=8)
    bm.ensure(1, 30)                        # 4 pages
    bm.note_cached(1, 30)
    assert bm.resident_tokens(1) == 30
    bm.evict_tail(1, 1)
    assert bm.resident_pages(1) == 3
    assert bm.resident_tokens(1) == 24      # clamped to surviving pages
    assert bm.resume(1) == 24               # resume sees the clean prefix
    bm.evict_tail(1, 10)                    # over-eviction is safe
    assert bm.resident_tokens(1) == 0


def test_block_manager_swap_roundtrip_preserves_tokens():
    bm = BlockManager(num_pages=4, page_size=8)
    bm.ensure(1, 32)
    bm.note_cached(1, 30)
    freed = bm.swap_out_tail(1, 2)
    assert len(freed) == 2
    assert bm.free_pages() == 2
    assert bm.resident_tokens(1) == 16      # resident prefix only
    assert bm.cached_tokens[1] == 30        # host still holds the tail
    assert bm.swap_in(1) == 2
    assert bm.resident_tokens(1) == 30


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 64)),
                min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_block_manager_never_double_books_pages(ops):
    """Random ensure/free interleavings: every resident physical page is
    owned by exactly one request and the free list never overlaps."""
    bm = BlockManager(num_pages=10, page_size=8)
    for rid, tokens in ops:
        if bm.resident_pages(rid) and tokens % 3 == 0:
            bm.free_request(rid)
        else:
            bm.ensure(rid, tokens)
        owned = [p for ps in bm.pages.values() for p in ps]
        assert len(set(owned)) == len(owned)
        assert not (set(owned) & set(bm.free))
        assert len(owned) + len(bm.free) == 10


# ---------------------------------------------------------------------------
# page-granular accounting
# ---------------------------------------------------------------------------

def test_paged_bytes_rounds_up_to_pages():
    ps = 16
    assert paged_bytes_for_context(CFG, 1, ps) == \
        paged_bytes_for_context(CFG, ps, ps)
    assert paged_bytes_for_context(CFG, ps + 1, ps) == \
        paged_bytes_for_context(CFG, 2 * ps, ps)
    # page-aligned contexts cost the same as exact accounting (dense arch)
    assert paged_bytes_for_context(CFG, 256, ps) == \
        bytes_for_context(CFG, 256)
    assert paged_bytes_for_context(CFG, 250, ps) > \
        bytes_for_context(CFG, 250)
    assert page_bytes(CFG, ps) * (256 // ps) == \
        paged_bytes_for_context(CFG, 256, ps)


def test_page_retention_gating():
    assert supports_page_retention(get_config("granite-3-8b"))
    assert supports_page_retention(get_config("trail-llama"))
    assert not supports_page_retention(get_config("mamba2-370m"))
    assert not supports_page_retention(get_config("gemma3-1b"))
    assert not supports_page_retention(get_config("whisper-tiny"))


@given(st.lists(st.tuples(st.integers(1, 128), st.integers(0, 400),
                          st.floats(1.0, 400.0)),
                min_size=1, max_size=24),
       st.integers(1, 64))
@settings(max_examples=80, deadline=None)
def test_scheduled_paged_bytes_never_exceed_budget(jobs, n_pages_budget):
    """Hypothesis invariant: under paged accounting (with its round-up
    fragmentation) the scheduler's admitted set stays within mem_budget.
    srpt pins nothing, so the bound is strict."""
    ps = 16
    budget = n_pages_budget * page_bytes(CFG, ps)
    entries = {}
    for i, (prompt, age, pred) in enumerate(jobs):
        e = SchedEntry(rid=i, arrival=float(i), prompt_len=prompt,
                       r0=pred, pred_remaining=pred, age=age)
        entries[i] = e
    bytes_fn = lambda e: paged_bytes_for_context(
        CFG, e.prompt_len + e.age + 1, ps)
    d = select_batch(entries, policy="srpt", max_batch=8,
                     mem_budget=budget, bytes_fn=bytes_fn)
    used = sum(bytes_fn(entries[rid]) for rid in d.scheduled)
    assert used <= budget
    assert len(d.scheduled) <= 8


# ---------------------------------------------------------------------------
# paged engine: sim mode
# ---------------------------------------------------------------------------

def small_workload(n=100, rate=20.0, seed=4):
    wc = WorkloadConfig(n_requests=n, request_rate=rate, seed=seed,
                        vocab=CFG.vocab_size)
    return generate(wc)


def test_paged_engine_completes_and_skips_recompute():
    """With memory slack, paged preemption retains every page: same
    workload finishes with zero recomputed tokens, vs >0 for contig."""
    reqs = small_workload()
    contig = run_policy(CFG, "trail", reqs, mode="sim", seed=5,
                        kv_layout="contig")
    paged = run_policy(CFG, "trail", reqs, mode="sim", seed=5,
                       kv_layout="paged", page_size=16)
    assert len(paged.latencies) == len(reqs)
    assert contig.n_preemptions > 0 and paged.n_preemptions > 0
    assert contig.recomputed_tokens > 0
    assert paged.recomputed_tokens == 0
    assert paged.recomputed_tokens < contig.recomputed_tokens


def test_paged_engine_tight_budget_evicts_not_discards():
    """Under real memory pressure pages are evicted tail-first, so paged
    recompute stays strictly below contiguous discard-and-recompute."""
    reqs = small_workload(n=120, rate=30.0)
    budget = 6 * bytes_for_context(CFG, 256)
    contig = run_policy(CFG, "trail", reqs, mode="sim", seed=5,
                        mem_budget=budget, max_batch=64, kv_layout="contig")
    paged = run_policy(CFG, "trail", reqs, mode="sim", seed=5,
                       mem_budget=budget, max_batch=64, kv_layout="paged",
                       page_size=16)
    assert len(paged.latencies) == len(reqs)
    assert paged.recomputed_tokens < contig.recomputed_tokens
    # suspended + scheduled pages respect the budget (small slack for the
    # pinned-growth exemption select_batch documents)
    assert paged.peak_mem_bytes <= budget * 1.25


def test_paged_swap_moves_pages_not_sequences():
    """oom_mode="swap" + paged: only the pages squeezed out by pressure
    cross the DMA, so swap traffic drops vs whole-sequence swapping."""
    reqs = small_workload(n=120, rate=30.0)
    budget = 6 * bytes_for_context(CFG, 256)
    contig = run_policy(CFG, "trail", reqs, mode="sim", seed=5,
                        mem_budget=budget, max_batch=64, oom_mode="swap",
                        kv_layout="contig")
    paged = run_policy(CFG, "trail", reqs, mode="sim", seed=5,
                       mem_budget=budget, max_batch=64, oom_mode="swap",
                       kv_layout="paged", page_size=16)
    assert contig.swapped_bytes > 0
    assert paged.swapped_bytes > 0
    assert paged.swapped_bytes < contig.swapped_bytes
    assert paged.recomputed_tokens == 0
    assert len(paged.latencies) == len(reqs)


# ---------------------------------------------------------------------------
# paged pool: real mode block-table correctness
# ---------------------------------------------------------------------------

@pytest.mark.real
def test_paged_pool_retention_survives_preempt_and_slot_move():
    """Preempt a request, hand its slot to another rid, resume it in a
    different slot: the re-linked block table must reproduce the exact
    logits of an uninterrupted run."""
    cfg = get_smoke_config("trail-llama")
    from repro.models.model import Model
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    decode = jax.jit(m.decode_step)
    prefill = jax.jit(m.prefill_chunk)
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 4,
                                 cfg.vocab_size)

    def uninterrupted():
        cache = m.init_cache(2, 32)
        logits, cache, *_ = prefill(params, cache, prompts)
        out, tok = [], jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(6):
            logits, cache, *_ = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(np.asarray(logits))
        return out

    def preempt_resume():
        pool = PagedSlotPool(m, slots=2, max_len=32, page_size=8)
        assert pool.retain
        rid = 7
        slot = pool.assign(rid)
        pool.ensure_pages(rid, 8)
        pool.flush_resets()
        toks = np.zeros((2, 8), np.int32)
        valid = np.zeros((2, 8), bool)
        toks[slot] = np.asarray(prompts)[0]
        valid[slot] = True
        logits, pool.cache, *_ = prefill(params, pool.cache,
                                         jnp.asarray(toks),
                                         valid=jnp.asarray(valid))
        out = []
        tok = np.zeros((2, 1), np.int32)
        active = np.zeros((2,), bool)
        tok[slot, 0] = int(jnp.argmax(logits[slot]))
        active[slot] = True
        for step in range(3):
            pool.ensure_pages(rid, 9 + step)
            pool.flush_resets()
            logits, pool.cache, *_ = decode(params, pool.cache,
                                            jnp.asarray(tok),
                                            active=jnp.asarray(active))
            out.append(np.asarray(logits[slot]))
            tok[slot, 0] = int(jnp.argmax(logits[slot]))
        saved = tok[slot, 0]
        pool.blocks.note_cached(rid, 11)     # 8 prompt + 3 decoded written
        pool.release(rid, retain=True)
        other = pool.assign(99)              # old slot goes to someone else
        slot2 = pool.assign(rid)             # resume in the remaining slot
        assert slot2 != other
        assert int(pool.cache["lengths"][slot2]) == 11
        tok = np.zeros((2, 1), np.int32)
        active = np.zeros((2,), bool)
        tok[slot2, 0] = saved
        active[slot2] = True
        for step in range(3):
            pool.ensure_pages(rid, 12 + step)
            pool.flush_resets()
            logits, pool.cache, *_ = decode(params, pool.cache,
                                            jnp.asarray(tok),
                                            active=jnp.asarray(active))
            out.append(np.asarray(logits[slot2]))
            tok[slot2, 0] = int(jnp.argmax(logits[slot2]))
        return out

    ref = uninterrupted()
    got = preempt_resume()
    for i, (r, g) in enumerate(zip(ref, got)):
        assert float(np.max(np.abs(r[0] - g))) == 0.0, f"step {i} diverged"


@pytest.mark.real
def test_paged_real_mode_end_to_end():
    cfg = get_smoke_config("trail-llama")
    from repro.models.model import Model
    from repro.serving.predictors import ProbePredictor
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    wc = WorkloadConfig(n_requests=6, request_rate=100.0, seed=1,
                        vocab=cfg.vocab_size, prompt_mean=8.0,
                        out_median=6.0, max_out=16)
    reqs = generate(wc)
    pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                          embed_table=params["embed"])
    s = run_policy(cfg, "trail", reqs, max_batch=3, mode="real", model=m,
                   params=params, predictor=pred, kv_layout="paged",
                   page_size=8, max_len=64)
    assert len(s.latencies) == len(reqs)
    assert s.iterations > 0
