"""Closed-loop client-pool tests: determinism, think/session semantics,
retry exhaustion, and a live-socket smoke run against the front door."""

import asyncio
import json
from collections import defaultdict

from repro.clients import ClientPoolConfig, run_closed_loop, run_live_pool
from repro.config import get_config
from repro.metrics import EventLog, check_invariants
from repro.server import EngineServer, ServerConfig
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import Engine, EngineConfig

CFG = get_config("granite-3-8b")


def _engine(**kw):
    return Engine(CFG, EngineConfig(policy="trail", hardware=HardwareSpec(),
                                    seed=0, **kw), event_log=EventLog())


def _by_client(stats):
    turns = defaultdict(list)
    for r in stats.records:
        turns[r.client].append(r)
    for recs in turns.values():
        recs.sort(key=lambda r: r.turn)
    return turns


def test_closed_loop_determinism_under_fixed_seed():
    """Two runs with the same seed are byte-identical: same summaries,
    same per-record times (the virtual-time loop has no wall clock)."""
    cfg = ClientPoolConfig(n_clients=6, requests_per_client=3,
                           think_time_s=1.0, seed=42)
    outs = []
    for _ in range(2):
        eng = _engine()
        stats = run_closed_loop(eng, cfg)
        check_invariants(eng.events)
        outs.append((json.dumps(stats.summary(), sort_keys=True),
                     [(r.client, r.turn, r.t_issue, r.t_done, r.tokens)
                      for r in stats.records]))
    assert outs[0] == outs[1]
    summary = json.loads(outs[0][0])
    assert summary["issued"] == 18
    assert summary["finished"] == 18 and summary["lost"] == 0


def test_closed_loop_think_time_semantics():
    """A user never overlaps their own requests: each turn is issued at
    (previous finish + think draw), immediately when think time is 0."""
    for think in (0.0, 5.0):
        stats = run_closed_loop(
            _engine(), ClientPoolConfig(n_clients=3, requests_per_client=3,
                                        think_time_s=think, seed=1))
        for recs in _by_client(stats).values():
            for prev, cur in zip(recs, recs[1:]):
                assert prev.outcome == "finish"
                if think == 0.0:
                    assert cur.t_first_issue == prev.t_done
                else:
                    assert cur.t_first_issue > prev.t_done


def test_session_boundaries_use_the_session_gap():
    """With session_len=2 and a much larger session gap, the think gaps
    at session boundaries dominate the within-session gaps."""
    stats = run_closed_loop(
        _engine(), ClientPoolConfig(n_clients=4, requests_per_client=6,
                                    think_time_s=0.05, session_len=2,
                                    session_gap_s=60.0, seed=7))
    boundary, within = [], []
    for recs in _by_client(stats).values():
        for prev, cur in zip(recs, recs[1:]):
            gap = cur.t_first_issue - prev.t_done
            (boundary if cur.turn % 2 == 0 else within).append(gap)
    assert boundary and within
    assert min(boundary) > max(within)


def test_retry_budget_exhaustion_counted_as_lost():
    """Against an overloaded admission-controlled engine, shed requests
    burn their retries and are recorded as lost with the fail kind."""
    cfg = ClientPoolConfig(n_clients=6, requests_per_client=2,
                           think_time_s=0.0, max_retries=1,
                           retry_backoff_s=0.5, seed=3)
    eng = _engine(shed_watermark=600.0, admission_control=True)
    stats = run_closed_loop(eng, cfg)
    check_invariants(eng.events)
    summary = stats.summary()
    assert summary["issued"] == 12
    assert summary["finished"] + summary["lost"] == summary["issued"]
    lost = [r for r in stats.records if r.outcome == "lost"]
    assert lost and summary["failures"].get("shed", 0) > 0
    for r in lost:
        assert r.fail_kind == "shed"
        assert r.retries == cfg.max_retries
    # every shed event was either retried into a finish or counted lost
    assert all(r.outcome in ("finish", "lost") for r in stats.records)


def test_live_socket_smoke():
    """8 socket users against a real server on localhost: every stream
    terminates, every logical request ends finish-or-lost."""
    async def main():
        eng = _engine()
        server = EngineServer(eng, ServerConfig(port=0, time_scale=50.0))
        await server.start()
        try:
            cfg = ClientPoolConfig(n_clients=8, requests_per_client=2,
                                   think_time_s=1.0, seed=0)
            return await run_live_pool("127.0.0.1", server.port, cfg,
                                       time_scale=50.0), eng
        finally:
            await server.close()

    stats, eng = asyncio.run(main())
    summary = stats.summary()
    assert summary["issued"] == 16
    assert all(r.outcome in ("finish", "lost") for r in stats.records)
    assert summary["finished"] == 16 and summary["lost"] == 0
    assert not eng.has_work()
    check_invariants(eng.events)
