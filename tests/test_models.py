"""Model-layer unit/property tests: attention paths, SSD, MoE, RoPE, CE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.config import get_smoke_config
from repro.models import attention as A
from repro.models import moe as moe_mod
from repro.models.layers import apply_rope, softcap
from repro.models.model import Model, _chunked_ce
from repro.models.ssm import ssd_chunked
from repro.kernels.ref import ssd_scan_ref

CFG = get_smoke_config("granite-3-8b")
KEY = jax.random.key(0)


@given(st.integers(1, 3), st.integers(8, 64), st.integers(0, 40))
@settings(max_examples=25, deadline=None)
def test_blocked_equals_dense_attention(B, S, win):
    H, KH, hd = 4, 2, 16
    M = S + 16
    ks = jax.random.split(jax.random.fold_in(KEY, S * 7 + win), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, M, KH, hd))
    v = jax.random.normal(ks[2], (B, M, KH, hd))
    qp = jnp.broadcast_to(jnp.arange(S), (B, S))
    kp = jnp.broadcast_to(jnp.arange(M), (B, M))
    kp = jnp.where(kp < M - 5, kp, -1)            # some invalid slots
    d = A._attend_dense(CFG, q, k, v, qp, kp, window=win, causal=True)
    b = A._attend_blocked(CFG, q, k, v, qp, kp, window=win, causal=True,
                          block=16)
    assert float(jnp.max(jnp.abs(d - b))) < 1e-5


def test_rope_relative_property():
    """RoPE: <rot(q,n), rot(k,m)> depends only on n - m."""
    hd = 32
    q = jax.random.normal(KEY, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, hd))
    def dot(n, m):
        qr = apply_rope(q, jnp.asarray([[n]]), 10000.0)
        kr = apply_rope(k, jnp.asarray([[m]]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert dot(3, 1) == pytest.approx(dot(10, 8), rel=1e-4)
    assert dot(5, 5) == pytest.approx(dot(0, 0), rel=1e-4)


def test_softcap():
    x = jnp.asarray([-1e5, 0.0, 1e5])
    y = softcap(x, 30.0)
    assert float(y[0]) == pytest.approx(-30.0, rel=1e-3)
    assert float(y[1]) == 0.0
    assert float(y[2]) == pytest.approx(30.0, rel=1e-3)
    assert softcap(x, 0.0) is x


def test_cache_write_drop_semantics():
    cache = A.init_kv_cache(CFG, batch=2, max_len=8, n_layers=1)
    layer = jax.tree.map(lambda x: x[0], cache)
    k_new = jnp.ones((2, 3, CFG.num_kv_heads, CFG.head_dim))
    pos = jnp.asarray([[0, 1, 2], [-1, 5, 99]])   # -1 and overflow dropped
    out = A.cache_write(layer, k_new, k_new, pos, window=0)
    assert bool(jnp.all(out["kpos"][0, :3] == jnp.asarray([0, 1, 2])))
    assert int(out["kpos"][1, 5]) == 5
    assert int(out["kpos"][1, 0]) == -1           # -1 write dropped
    assert bool(jnp.all(out["k"][1, 0] == 0))


def test_ring_buffer_wraparound():
    win = 4
    cache = A.init_kv_cache(CFG, batch=1, max_len=16, n_layers=1, window=win)
    layer = jax.tree.map(lambda x: x[0], cache)
    k_new = jnp.arange(6, dtype=jnp.float32)[None, :, None, None] * jnp.ones(
        (1, 6, CFG.num_kv_heads, CFG.head_dim))
    pos = jnp.arange(6)[None]
    out = A.cache_write(layer, k_new, k_new, pos, window=win)
    # slots hold positions 4,5,2,3 (ring of width 4)
    assert sorted(np.asarray(out["kpos"][0]).tolist()) == [2, 3, 4, 5]


def test_ssd_chunk_invariance():
    """Chunk size must not change the SSD result."""
    B, L, nh, hp, N = 1, 96, 2, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, L, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh)))
    Aa = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    y_ref, s_ref = ssd_scan_ref(x, dt, Aa, Bm, Cm)
    for chunk in (8, 16, 32, 96):
        y, s = ssd_chunked(x, dt, Aa, Bm, Cm, chunk)
        assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-3, chunk
        assert float(jnp.max(jnp.abs(s - s_ref))) < 1e-3, chunk


def test_moe_sort_matches_dense_dropless():
    cfg = dataclasses.replace(get_smoke_config("olmoe-1b-7b"),
                              capacity_factor=2.0)
    p = moe_mod.init_moe(KEY, cfg)
    h = jax.random.normal(jax.random.fold_in(KEY, 2),
                          (2, 16, cfg.d_model)).astype(jnp.bfloat16)
    y1, a1 = moe_mod.moe_mlp(cfg, p, h)
    y2, a2 = moe_mod.moe_mlp_dense(cfg, p, h)
    err = float(jnp.max(jnp.abs(y1.astype(jnp.float32)
                                - y2.astype(jnp.float32))))
    assert err < 3e-2
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)
    assert float(a1) >= 1.0 - 1e-3     # Switch aux lower bound is 1 at balance


def test_moe_capacity_drops_are_identity():
    """Tokens dropped by capacity contribute zero delta (residual intact)."""
    cfg = dataclasses.replace(get_smoke_config("olmoe-1b-7b"),
                              capacity_factor=0.01)   # drop almost everything
    p = moe_mod.init_moe(KEY, cfg)
    h = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 8, cfg.d_model))
    y, _ = moe_mod.moe_mlp(cfg, p, h)
    # capacity floor is 4 slots/expert; most tokens dropped -> tiny norm
    assert float(jnp.mean(jnp.abs(y))) < float(jnp.mean(jnp.abs(h)))


def test_int8_kv_cache_close_to_fp():
    """kv_quant=True: decode logits within quantization tolerance of fp."""
    cfg = get_smoke_config("granite-3-8b")
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    m_fp = Model(cfg)
    m_q = Model(qcfg)
    params = m_fp.init(KEY)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.fold_in(KEY, 9), (B, S), 0,
                                cfg.vocab_size)
    nt = jax.random.randint(jax.random.fold_in(KEY, 10), (B, 1), 0,
                            cfg.vocab_size)
    outs = {}
    for name, m in (("fp", m_fp), ("q", m_q)):
        cache = m.init_cache(B, 64)
        _, cache, *_ = m.prefill_chunk(params, cache, tokens)
        ld, cache, *_ = m.decode_step(params, cache, nt)
        outs[name] = ld
        if name == "q":
            run0 = cache["run_0"][0]
            assert run0["k"].dtype == jnp.int8
            assert "k_scale" in run0
    err = float(jnp.max(jnp.abs(outs["fp"] - outs["q"])))
    scale = float(jnp.max(jnp.abs(outs["fp"])))
    assert err < 0.05 * scale + 0.3, (err, scale)   # int8: small perturbation
    # top-1 prediction must agree
    assert bool(jnp.all(jnp.argmax(outs["fp"], -1)
                        == jnp.argmax(outs["q"], -1)))
    # and the accounting reflects the ~2x saving
    from repro.serving.kv_cache import bytes_for_context
    assert bytes_for_context(qcfg, 1024) < 0.6 * bytes_for_context(cfg, 1024)


def test_chunked_ce_matches_dense():
    cfg = get_smoke_config("trail-llama")
    m = Model(cfg)
    params = m.init(KEY)
    B, S = 2, 32
    h = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, cfg.d_model))
    labels = jax.random.randint(jax.random.fold_in(KEY, 5), (B, S), -1,
                                cfg.vocab_size)
    loss8, n8 = _chunked_ce(cfg, params, h, labels, chunk=8)
    loss32, n32 = _chunked_ce(cfg, params, h, labels, chunk=32)
    assert float(jnp.abs(loss8 - loss32)) < 1e-4
    assert float(n8) == float(n32)
