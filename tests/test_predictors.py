"""Predictor strategy layer tests: spec parsing, cost accounting,
determinism, legacy byte-identity, and rank/magnitude agreement."""

import math

import pytest

from _hypothesis_fallback import given, settings, st

from repro.config import get_config
from repro.core.scheduler import ReqState, SchedEntry, select_batch
from repro.serving.engine import run_policy
from repro.serving.predictors import (STRATEGIES, ExactOraclePredictor,
                                      NoisyOraclePredictor, OraclePredictor,
                                      PromptOnlyPredictor, make_predictor,
                                      parse_spec)
from repro.serving.workload import WorkloadConfig, generate

CFG = get_config("granite-3-8b")

#: One representative spec per strategy, paired with a compatible policy.
STRATEGY_SPECS = (
    ("trail-probe", "trail"),
    ("oracle", "trail"),
    ("noisy-oracle:sigma=0.5", "trail"),
    ("bucketed:bins=4", "trail"),
    ("prompt-only", "trail-bert"),
    ("rank-only", "rank"),
    ("iterative:period=4", "trail"),
)


def _workload(n=40, rate=20.0, seed=3):
    return generate(WorkloadConfig(n_requests=n, request_rate=rate,
                                   seed=seed, vocab=CFG.vocab_size))


# ---------------------------------------------------------------------------
# spec parsing / factory
# ---------------------------------------------------------------------------

def test_parse_spec_forms():
    assert parse_spec("oracle") == ("oracle", {})
    assert parse_spec("noisy-oracle:sigma=0.5") == ("noisy-oracle",
                                                    {"sigma": 0.5})
    assert parse_spec("bucketed:bins=4") == ("bucketed", {"bins": 4})
    name, kw = parse_spec("iterative:period=8,sigma=0.3")
    assert name == "iterative" and kw == {"period": 8, "sigma": 0.3}
    with pytest.raises(ValueError):
        parse_spec("noisy-oracle:sigma")          # not key=value


def test_make_predictor_every_strategy():
    for name in STRATEGIES:
        p = make_predictor(name, CFG.probe, seed=1)
        assert hasattr(p, "initial") and hasattr(p, "on_token")
    with pytest.raises(ValueError):
        make_predictor("no-such-strategy", CFG.probe)
    with pytest.raises(TypeError):
        make_predictor("oracle:sigma=1.0", CFG.probe)   # keyword-strict


def test_trail_probe_spec_is_the_legacy_class():
    p = make_predictor("trail-probe", CFG.probe, seed=7)
    assert type(p) is OraclePredictor
    assert p.provides_magnitude and p.flops_initial == 0.0


# ---------------------------------------------------------------------------
# legacy byte-identity
# ---------------------------------------------------------------------------

def test_trail_probe_byte_identical_to_legacy_default():
    reqs = _workload()
    legacy = run_policy(CFG, "trail", reqs, seed=0)
    spec = run_policy(CFG, "trail", reqs, predictor="trail-probe", seed=0)
    assert legacy.latencies == spec.latencies
    assert legacy.summary() == spec.summary()


# ---------------------------------------------------------------------------
# determinism: same trace + seed -> byte-identical metrics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,policy", STRATEGY_SPECS,
                         ids=[s for s, _ in STRATEGY_SPECS])
def test_strategy_deterministic(spec, policy):
    reqs = _workload()
    a = run_policy(CFG, policy, reqs, predictor=spec, seed=0)
    b = run_policy(CFG, policy, reqs, predictor=spec, seed=0)
    assert a.latencies == b.latencies
    assert a.summary() == b.summary()


# ---------------------------------------------------------------------------
# cost accounting
# ---------------------------------------------------------------------------

def test_zero_cost_strategies_charge_nothing():
    reqs = _workload()
    for spec, policy in (("trail-probe", "trail"), ("oracle", "trail"),
                         ("noisy-oracle:sigma=0.5", "trail"),
                         ("bucketed:bins=4", "trail"),
                         ("rank-only", "rank")):
        s = run_policy(CFG, policy, reqs, predictor=spec, seed=0)
        d = s.summary()
        assert d["predictor_time_s"] == 0.0, spec
        assert d["predictor_calls"] == 0, spec


def test_prompt_only_charges_per_prompt_token():
    p = PromptOnlyPredictor(CFG.probe, seed=0)

    class _Req:
        rid, prompt, true_out_len = 0, list(range(17)), 30
    p.initial(_Req())
    assert p.cost_calls == 1
    assert p.cost_flops_pending == p.flops_per_prompt_token * 17
    assert p.take_cost_flops() == p.flops_per_prompt_token * 17
    assert p.take_cost_flops() == 0.0                 # drained


def test_costed_strategy_charges_engine_clock():
    reqs = _workload()
    free = run_policy(CFG, "trail-bert", reqs, predictor="oracle", seed=0)
    paid = run_policy(CFG, "trail-bert", reqs, predictor="prompt-only",
                      seed=0)
    assert free.summary()["predictor_time_s"] == 0.0
    d = paid.summary()
    assert d["predictor_calls"] == len(reqs)          # one charge per admit
    # total charged seconds = total prompt tokens x proxy flops / peak
    total_tokens = sum(len(r.prompt) for r in reqs)
    expect = (PromptOnlyPredictor.flops_per_prompt_token * total_tokens
              / free.hardware.peak_flops if hasattr(free, "hardware")
              else None)
    assert d["predictor_time_s"] > 0.0
    if expect is not None:
        assert d["predictor_time_s"] == pytest.approx(expect)


def test_iterative_period_controls_refresh_cost():
    reqs = _workload()
    fast = run_policy(CFG, "trail", reqs, predictor="iterative:period=1",
                      seed=0)
    slow = run_policy(CFG, "trail", reqs, predictor="iterative:period=64",
                      seed=0)
    assert fast.summary()["predictor_calls"] > slow.summary()[
        "predictor_calls"]
    assert fast.summary()["predictor_time_s"] > slow.summary()[
        "predictor_time_s"]


# ---------------------------------------------------------------------------
# magnitude contract
# ---------------------------------------------------------------------------

def test_rank_only_rejects_magnitude_policies():
    reqs = _workload(n=4)
    for policy in ("trail", "trail-bert", "srpt"):
        with pytest.raises(ValueError):
            run_policy(CFG, policy, reqs, predictor="rank-only", seed=0)
    # the rank policy (and non-preempting fcfs) are fine
    run_policy(CFG, "rank", reqs, predictor="rank-only", seed=0)
    run_policy(CFG, "fcfs", reqs, predictor="rank-only", seed=0)


def test_rank_only_matches_oracle_ordering_end_to_end():
    # noise-free ordinal scores are a monotone transform of the truth, so
    # the rank policy must reproduce the oracle's srpt-style schedule
    reqs = _workload()
    rank = run_policy(CFG, "rank", reqs, predictor="rank-only", seed=0)
    srpt = run_policy(CFG, "srpt", reqs, predictor="oracle", seed=0)
    assert rank.latencies == srpt.latencies


# ---------------------------------------------------------------------------
# select_batch: rank-policy agreement with magnitude-SRPT
# ---------------------------------------------------------------------------

def _entries(sizes, states):
    out = {}
    for i, (size, st_) in enumerate(zip(sizes, states)):
        out[i] = SchedEntry(rid=i, arrival=float(i), prompt_len=8,
                            r0=float(size), pred_remaining=float(size),
                            age=0, c_limit=0.8, state=st_)
    return out


@given(st.lists(st.tuples(st.integers(1, 512), st.booleans()),
                min_size=1, max_size=12),
       st.integers(1, 6))
@settings(max_examples=200, deadline=None)
def test_rank_policy_agrees_with_srpt_under_monotone_scores(jobs, max_batch):
    sizes = [s for s, _ in jobs]
    states = [ReqState.RUNNING if r else ReqState.WAITING for _, r in jobs]
    kw = dict(max_batch=max_batch, mem_budget=1 << 62,
              bytes_fn=lambda e: 1, lookahead=1)
    srpt = select_batch(_entries(sizes, states), policy="srpt", **kw)
    # ordinal scores: any strictly monotone transform of the sizes
    ents = _entries(sizes, states)
    for e in ents.values():
        e.pred_remaining = math.log1p(e.pred_remaining) / math.log1p(512.0)
    rank = select_batch(ents, policy="rank", **kw)
    assert set(rank.scheduled) == set(srpt.scheduled)
    assert set(rank.preempted) == set(srpt.preempted)


@given(st.lists(st.integers(1, 512), min_size=2, max_size=10, unique=True))
@settings(max_examples=200, deadline=None)
def test_noisy_oracle_sigma_zero_matches_oracle_ordering(lengths):
    pc = CFG.probe
    noisy = NoisyOraclePredictor(pc, sigma=0.0, seed=9)
    exact = ExactOraclePredictor(pc)

    class _Req:
        def __init__(self, n):
            self.rid, self.prompt, self.generated = n, [1], []
            self.true_out_len = n
    reqs = [_Req(n) for n in lengths]
    n_order = sorted(reqs, key=lambda r: noisy.initial(r))
    e_order = sorted(reqs, key=lambda r: exact.initial(r))
    assert [r.rid for r in n_order] == [r.rid for r in e_order]
