"""Decode-megastep parity and donation-safety tests.

``Model.decode_multi(k)`` must be bit-for-bit (tokens, cache lengths) and
fp-tolerance (probe posteriors, KV/SSM state) identical to ``k`` sequential
``decode_step`` calls on both cache layouts, including inactive rows,
per-row budget/EOS halting, and SSM ``_mask_recurrent`` state. Donation
(engine jits donate the cache pytree) must never resurrect stale buffers
across preemption resets.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_smoke_config
from repro.models.model import Model
from repro.serving.engine import run_policy
from repro.serving.kv_cache import PagedSlotPool, SlotPool, donating_jit
from repro.serving.predictors import ProbePredictor
from repro.serving.workload import WorkloadConfig, generate


def _build(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    return cfg, m, params


def _sequential(m, params, cache, tok, active, budget, k):
    """k decode_step calls with the same halting semantics as decode_multi."""
    dec = jax.jit(m.decode_step)
    emitted = jnp.zeros_like(budget)
    toks, probes = [], []
    for _ in range(k):
        act = active & (emitted < budget)
        logits, cache, _, pl = dec(params, cache, tok, active=act)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(np.where(np.asarray(act), np.asarray(nxt), -1))
        probes.append(np.asarray(jax.nn.softmax(pl.astype(jnp.float32), -1)))
        tok = jnp.where(act, nxt, tok[:, 0])[:, None]
        emitted = emitted + act.astype(jnp.int32)
    return cache, np.stack(toks, 1), np.stack(probes, 1), np.asarray(emitted)


def _assert_cache_close(got, ref, tol=1e-5):
    assert bool(jnp.all(got["lengths"] == ref["lengths"]))
    for key in ref:
        if not key.startswith("run_"):
            continue
        for s_got, s_ref in zip(got[key], ref[key]):
            for leaf in s_ref:
                err = float(jnp.max(jnp.abs(
                    jnp.asarray(s_got[leaf], jnp.float32)
                    - jnp.asarray(s_ref[leaf], jnp.float32))))
                assert err < tol, (key, leaf, err)


@pytest.mark.real
@pytest.mark.parametrize("arch", ["trail-llama", "mamba2-370m"])
def test_decode_multi_matches_sequential_contig(arch):
    """Contig layout, incl. an inactive row, a short budget, and (for
    mamba2) the SSM ``_mask_recurrent`` state of halted rows."""
    cfg, m, params = _build(arch)
    B, k = 3, 5
    cache = m.init_cache(B, 32)
    prompts = jax.random.randint(jax.random.key(1), (B, 8), 4, cfg.vocab_size)
    logits, cache, *_ = jax.jit(m.prefill_chunk)(params, cache, prompts)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    active = jnp.array([True, True, False])
    budget = jnp.array([k, 3, k], jnp.int32)

    c_ref, t_ref, p_ref, n_ref = _sequential(m, params, cache, tok,
                                             active, budget, k)
    toks, c_got, probs, n_got = jax.jit(
        m.decode_multi, static_argnames=("k", "eos_id"))(
            params, cache, tok, active, budget, k=k)

    assert np.array_equal(np.asarray(toks), t_ref)
    assert np.array_equal(np.asarray(n_got), n_ref)
    assert np.asarray(n_got).tolist() == [5, 3, 0]
    # rows halted by budget / inactivity emit -1 sentinels past their halt
    assert np.all(np.asarray(toks)[1, 3:] == -1)
    assert np.all(np.asarray(toks)[2] == -1)
    assert float(np.max(np.abs(np.asarray(probs) - p_ref))) < 1e-5
    _assert_cache_close(c_got, c_ref)


@pytest.mark.real
def test_decode_multi_matches_sequential_paged():
    """Paged layout: same block table for both paths, so the page pool
    (pk/pv/pkpos) must come out identical too."""
    cfg, m, params = _build("trail-llama")
    B, k, ps = 2, 4, 8
    cache = m.init_cache(B, 32, kv_layout="paged", page_size=ps)
    # rows 0/1 get disjoint scrambled pages covering 8 prompt + k new tokens
    table = np.zeros((B, 4), np.int32)
    table[0, :2] = [3, 5]
    table[1, :2] = [1, 7]
    cache["block_table"] = jnp.asarray(table)
    prompts = jax.random.randint(jax.random.key(2), (B, 8), 4, cfg.vocab_size)
    logits, cache, *_ = jax.jit(m.prefill_chunk)(params, cache, prompts)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    active = jnp.ones((B,), bool)
    budget = jnp.full((B,), k, jnp.int32)

    c_ref, t_ref, p_ref, n_ref = _sequential(m, params, cache, tok,
                                             active, budget, k)
    toks, c_got, probs, n_got = jax.jit(
        m.decode_multi, static_argnames=("k", "eos_id"))(
            params, cache, tok, active, budget, k=k)

    assert np.array_equal(np.asarray(toks), t_ref)
    assert np.array_equal(np.asarray(n_got), n_ref)
    assert float(np.max(np.abs(np.asarray(probs) - p_ref))) < 1e-5
    _assert_cache_close(c_got, c_ref)


@pytest.mark.real
def test_decode_multi_eos_halting():
    """A row that emits ``eos_id`` halts there: no further KV writes or
    length growth, later outputs are -1."""
    cfg, m, params = _build("trail-llama")
    B, k = 2, 5
    cache = m.init_cache(B, 32)
    prompts = jax.random.randint(jax.random.key(3), (B, 8), 4, cfg.vocab_size)
    logits, cache, *_ = jax.jit(m.prefill_chunk)(params, cache, prompts)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    dm = jax.jit(m.decode_multi, static_argnames=("k", "eos_id"))
    free_toks, _, _, _ = dm(params, cache, tok, k=k)
    eos = int(np.asarray(free_toks)[0, 2])        # row 0's 3rd token as EOS
    toks, c_got, _, n_got = dm(params, cache, tok, k=k, eos_id=eos)
    toks = np.asarray(toks)
    n = np.asarray(n_got)
    assert n[0] == 3                              # EOS is emitted, then halt
    assert toks[0, 2] == eos and np.all(toks[0, 3:] == -1)
    lengths = np.asarray(c_got["lengths"])
    assert lengths[0] == 8 + 3
    assert lengths[1] == 8 + int(n[1])


@pytest.mark.real
@pytest.mark.parametrize("paged", [False, True])
def test_donation_no_stale_buffer_after_preemption_reset(paged):
    """Engine-style donating jits + the reset queue: after a preempted
    request's slot is released, reset, and reassigned, the new occupant's
    generation must match a run on a fresh pool (no stale-KV reuse through
    the donated/aliased buffers)."""
    cfg, m, params = _build("trail-llama")
    prefill = donating_jit(m.prefill_chunk)
    decode = donating_jit(m.decode_multi, static_argnames=("k", "eos_id"))
    prompts = jax.random.randint(jax.random.key(4), (2, 8), 4, cfg.vocab_size)

    def make_pool():
        if paged:
            return PagedSlotPool(m, slots=2, max_len=32, page_size=8,
                                 retain=False)
        return SlotPool(m, slots=2, max_len=32)

    def run_request(pool, slot_tokens, slot):
        if paged:
            pool.ensure_pages(pool_rid[slot], 8 + 4)
        pool.flush_resets()
        toks = np.zeros((2, 8), np.int32)
        valid = np.zeros((2, 8), bool)
        toks[slot] = slot_tokens
        valid[slot] = True
        logits, pool.cache, *_ = prefill(params, pool.cache,
                                         jnp.asarray(toks),
                                         valid=jnp.asarray(valid))
        tok = np.zeros((2, 1), np.int32)
        active = np.zeros((2,), bool)
        tok[slot, 0] = int(jnp.argmax(logits[slot]))
        active[slot] = True
        out, pool.cache, _, _ = decode(params, pool.cache, jnp.asarray(tok),
                                       jnp.asarray(active), k=4)
        return np.asarray(out)[slot]

    pool_rid = {}
    # fresh pool: rid 9 alone
    pool = make_pool()
    pool_rid[pool.assign(9)] = 9
    ref = run_request(pool, np.asarray(prompts)[1], pool.slot_of[9])

    # dirty pool: rid 7 runs first, is preempted (discard), slot reused by 9
    pool = make_pool()
    pool_rid = {}
    s7 = pool.assign(7)
    pool_rid[s7] = 7
    _ = run_request(pool, np.asarray(prompts)[0], s7)
    pool.release(7)                     # queues the device reset
    s9 = pool.assign(9)
    pool_rid[s9] = 9
    assert s9 == s7                     # same physical slot
    got = run_request(pool, np.asarray(prompts)[1], s9)
    assert np.array_equal(ref, got)


@pytest.mark.real
@pytest.mark.parametrize("kv_layout", ["contig", "paged"])
def test_engine_real_megastep_end_to_end(kv_layout):
    """probe_interval=4 megasteps: every request still finishes, and the
    engine consults the scheduler ~4x less often than per-token."""
    cfg, m, params = _build("trail-llama")
    wc = WorkloadConfig(n_requests=6, request_rate=100.0, seed=1,
                        vocab=cfg.vocab_size, prompt_mean=8.0,
                        out_median=6.0, max_out=16)
    pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                          embed_table=params["embed"])
    per_tok = run_policy(cfg, "trail", generate(wc), max_batch=3,
                         mode="real", model=m, params=params, predictor=pred,
                         probe_interval=1, kv_layout=kv_layout,
                         page_size=8, max_len=64)
    mega = run_policy(cfg, "trail", generate(wc), max_batch=3,
                      mode="real", model=m, params=params, predictor=pred,
                      probe_interval=4, kv_layout=kv_layout,
                      page_size=8, max_len=64)
    assert len(per_tok.latencies) == 6
    assert len(mega.latencies) == 6
    assert mega.iterations < per_tok.iterations


@pytest.mark.real
def test_model_paged_kernels_parity():
    """use_kernels=True routes the paged path through the Pallas single-
    and multi-query flash-decode kernels (interpret mode on CPU); prefill
    + a decode megastep must match the gather+attend reference path."""
    cfg = get_smoke_config("trail-llama")
    m_ref = Model(cfg, use_kernels=False)
    m_ker = Model(cfg, use_kernels=True)
    params = m_ref.init(jax.random.key(0))
    B, ps, k = 2, 8, 2
    table = np.zeros((B, 4), np.int32)
    table[0, :2] = [2, 4]
    table[1, :2] = [6, 1]
    prompts = jax.random.randint(jax.random.key(5), (B, 8), 4, cfg.vocab_size)

    outs = []
    for m in (m_ref, m_ker):
        cache = m.init_cache(B, 32, kv_layout="paged", page_size=ps)
        cache["block_table"] = jnp.asarray(table)
        logits, cache, *_ = m.prefill_chunk(params, cache, prompts)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks, cache, probs, _ = m.decode_multi(params, cache, tok, k=k)
        outs.append((np.asarray(logits), np.asarray(toks), np.asarray(probs)))
    assert float(np.max(np.abs(outs[0][0] - outs[1][0]))) < 2e-4
    assert np.array_equal(outs[0][1], outs[1][1])
    assert float(np.max(np.abs(outs[0][2] - outs[1][2]))) < 2e-4
