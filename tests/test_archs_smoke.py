"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step on CPU with shape + finiteness
asserts, plus prefill->decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.config import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import Model

KEY = jax.random.key(0)


def make_batch(cfg, B=2, S=32):
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.key(3), (B, cfg.num_prefix_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_constraints(arch):
    smoke = get_smoke_config(arch)
    assert smoke.num_layers <= 2
    assert smoke.d_model <= 512
    assert smoke.num_experts <= 4
    full = get_config(arch)
    assert full.family == smoke.family


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(KEY)
    batch = make_batch(cfg)
    loss, aux = m.forward_train(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    S_eff = 32 + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    assert aux["tap"].shape == (2, S_eff, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(aux["tap"])))

    # one real optimizer step must reduce nothing NaN-wards
    from repro.training import optimizer as opt_mod
    from repro.training.train import make_train_step
    ocfg = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(m, ocfg))
    opt_state = opt_mod.init(ocfg, params)
    params2, _, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree.leaves(changed)), f"{arch}: no parameter moved"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.num_experts:       # dropless capacity for exact equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=float(
            cfg.num_experts // max(cfg.experts_per_token, 1)))
    m = Model(cfg)
    params = m.init(KEY)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    fr = {}
    if cfg.family == "audio":
        fr["enc_embeds"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        fr["prefix_embeds"] = jax.random.normal(
            jax.random.key(3), (B, cfg.num_prefix_tokens, cfg.d_model))

    cache = m.init_cache(B, 64)
    _, cache1, tap_sum, cnt = m.prefill_chunk(params, cache, tokens, **fr)
    S_eff = S + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    assert bool(jnp.all(cnt == S_eff))
    nt = jax.random.randint(jax.random.key(4), (B, 1), 0, cfg.vocab_size)
    ld, cache2, tap, probe_logits = m.decode_step(params, cache1, nt)
    assert probe_logits.shape == (B, cfg.probe.num_bins)
    assert bool(jnp.all(jnp.isfinite(ld)))

    cachef = m.init_cache(B, 64)
    lfull, *_ = m.prefill_chunk(params, cachef,
                                jnp.concatenate([tokens, nt], 1), **fr)
    err = float(jnp.max(jnp.abs(ld - lfull)))
    assert err < 3e-2, f"{arch}: decode/prefill mismatch {err}"
    assert bool(jnp.all(cache2["lengths"] == S_eff + 1))


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-370m",
                                  "gemma2-9b", "hymba-1.5b"])
def test_inactive_rows_do_not_mutate_state(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(KEY)
    B = 2
    tokens = jax.random.randint(jax.random.key(1), (B, 8), 0, cfg.vocab_size)
    cache = m.init_cache(B, 32)
    _, cache, *_ = m.prefill_chunk(params, cache, tokens)
    nt = jnp.ones((B, 1), jnp.int32)
    active = jnp.asarray([True, False])
    _, cache2, *_ = m.decode_step(params, cache, nt, active=active)
    assert int(cache2["lengths"][0]) == 9
    assert int(cache2["lengths"][1]) == 8
    # row 1's recurrent state must be untouched
    for key, run in cache.items():
        if not key.startswith("run_"):
            continue
        for j, sub in enumerate(run):
            for leaf in ("ssm_state", "conv_buf", "kpos"):
                if leaf in sub:
                    a = sub[leaf][:, 1]
                    b = cache2[key][j][leaf][:, 1]
                    assert bool(jnp.all(a == b)), (arch, key, j, leaf)
