"""Tail-aware scheduling: rank aging + deadline-slack non-preemption.

Pins the PR 8 semantics at every layer:

* rank algebra — the hinge aging law
  (``aged = rank - age_boost * max(waited - age_delay, 0)``), the
  grace window, which policies age, and the interaction with the
  C-limit / deadline-slack pins;
* select_batch — no-starvation (a long-waiting entry outranks any
  finite competitor) and in-slack RUNNING entries never preempted,
  both as deterministic cases and hypothesis properties;
* engine — bounded waiting under overload, the deadline-slack window
  honored on the event log, aged backlog caps, off-is-free identity;
* benchmarks — the BENCH_trace_replay headline cell is byte-identical
  with the new knobs at defaults, and the BENCH_tail headline cell
  reproduces the committed artifact (determinism pin).
"""

import copy
import json
import os

import pytest
from _hypothesis_fallback import given, settings, st

from repro.config import get_config
from repro.core.scheduler import (AGED_POLICIES, NEG_INF, POLICIES, ReqState,
                                  SchedEntry, select_batch)
from repro.metrics.events import EventLog
from repro.metrics.rollup import rollup
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import Engine, EngineConfig, run_policy
from repro.serving.workload import generate, scenario_config

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = get_config("granite-3-8b")
HW = HardwareSpec(name="compute-bound-2tf", peak_flops=2e12, hbm_bw=819e9,
                  overhead_s=2e-4)


def mk(rid, arrival=0.0, r0=10.0, age=0, state=ReqState.WAITING, c=0.8,
       pred=None, deadline_at=0.0):
    return SchedEntry(rid=rid, arrival=arrival, prompt_len=16, r0=r0,
                      pred_remaining=pred if pred is not None else r0,
                      age=age, c_limit=c, state=state,
                      deadline_at=deadline_at)


def bytes_fn(e):
    return 100 * (e.prompt_len + e.age)


def workload(n=40, rate=4.0, seed=0, scenario="bursty"):
    wc = scenario_config(scenario, n_requests=n, request_rate=rate,
                         seed=seed, vocab=CFG.vocab_size)
    return generate(wc)


# ---------------------------------------------------------------------------
# rank algebra: the hinge aging law
# ---------------------------------------------------------------------------

def test_zero_knobs_are_byte_identical_ranks():
    """Explicit zero knobs (at any clock value) return the exact same
    float as the legacy no-knob call, for every policy and state."""
    for policy in POLICIES:
        for state in (ReqState.WAITING, ReqState.RUNNING,
                      ReqState.PREEMPTED):
            e = mk(0, arrival=1.5, r0=20.0, age=3, state=state, pred=7.0,
                   deadline_at=100.0)
            legacy = e.rank(policy)
            assert e.rank(policy, now=1e9, age_boost=0.0, age_delay=0.0,
                          deadline_slack=0.0) == legacy


def test_hinge_is_pure_srpt_inside_grace_window():
    e = mk(0, arrival=0.0, pred=12.0)
    base = e.rank("trail")
    # anywhere inside the window, aging contributes exactly nothing
    for now in (0.0, 3.0, 5.0):
        assert e.rank("trail", now=now, age_boost=100.0,
                      age_delay=5.0) == base


def test_hinge_is_linear_past_grace_window():
    e = mk(0, arrival=2.0, pred=12.0)
    # waited 10s, window 4s -> 6s of boosted excess
    assert e.rank("trail", now=12.0, age_boost=3.0,
                  age_delay=4.0) == 12.0 - 3.0 * 6.0


def test_aging_applies_to_aged_policies_only():
    e = mk(0, arrival=1.0, r0=9.0, age=2, pred=7.0)
    for policy in POLICIES:
        base = e.rank(policy)
        aged = e.rank(policy, now=1e4, age_boost=50.0)
        if policy in AGED_POLICIES:
            assert aged < base
        else:                        # fcfs / sjf / mlfq: fixed baselines
            assert aged == base


def test_hinge_catch_up_algebra():
    """The hinge is what lets a starver catch up: while the fresh entry
    sits inside its grace window (not yet aging) the old entry's rank
    falls past it after exactly gap/boost seconds of boosted excess.
    (Past both windows relative order is fixed — both fall at the same
    rate — which is why a delay-free uniform boost can never reorder.)"""
    boost, gap, delay = 4.0, 100.0, 30.0
    short = mk(1, arrival=50.0, pred=10.0)          # fresh, great rank
    long = mk(0, arrival=0.0, pred=10.0 + gap)      # old, terrible rank
    # long ages from t=30; crossing at 30 + gap/boost = 55, while short
    # is still inside its own window (50..80)
    kw = dict(age_boost=boost, age_delay=delay)
    assert long.rank("trail", now=54.0, **kw) \
        > short.rank("trail", now=54.0, **kw)
    assert long.rank("trail", now=56.0, **kw) \
        < short.rank("trail", now=56.0, **kw)
    # both past their windows: the 2s gap in rank is frozen forever
    d1 = long.rank("trail", now=100.0, **kw) \
        - short.rank("trail", now=100.0, **kw)
    d2 = long.rank("trail", now=1000.0, **kw) \
        - short.rank("trail", now=1000.0, **kw)
    assert d1 == pytest.approx(d2)


def test_c_limit_pin_survives_aging():
    e = mk(0, r0=10.0, age=9, state=ReqState.RUNNING, c=0.8, pred=1.0)
    assert e.rank("trail", now=1e6, age_boost=1e6) == NEG_INF


# ---------------------------------------------------------------------------
# rank algebra: deadline-slack non-preemption
# ---------------------------------------------------------------------------

def test_deadline_slack_pins_in_slack_running_entries():
    for policy in ("trail", "srpt", "trail-bert", "rank", "mlfq"):
        e = mk(0, state=ReqState.RUNNING, pred=50.0, deadline_at=10.0)
        assert e.rank(policy, now=8.0, deadline_slack=3.0) == NEG_INF
        # outside the slack window: the normal finite rank
        assert e.rank(policy, now=2.0, deadline_slack=3.0) != NEG_INF


def test_deadline_slack_ignores_non_running_and_no_deadline():
    w = mk(0, state=ReqState.WAITING, pred=50.0, deadline_at=10.0)
    assert w.rank("trail", now=9.0, deadline_slack=3.0) != NEG_INF
    r = mk(1, state=ReqState.RUNNING, pred=50.0, deadline_at=0.0)
    assert r.rank("trail", now=9.0, deadline_slack=3.0) != NEG_INF
    # slack off: a RUNNING entry right at its deadline is still movable
    d = mk(2, state=ReqState.RUNNING, pred=50.0, deadline_at=9.0)
    assert d.rank("srpt", now=9.0, deadline_slack=0.0) != NEG_INF


def test_deadline_slack_does_not_touch_nonpreemptive_policies():
    e = mk(0, arrival=4.0, r0=6.0, state=ReqState.RUNNING, deadline_at=10.0)
    assert e.rank("fcfs", now=9.0, deadline_slack=5.0) == 4.0
    assert e.rank("sjf", now=9.0, deadline_slack=5.0) == 6.0


# ---------------------------------------------------------------------------
# select_batch: starvation freedom + slack protection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", AGED_POLICIES)
def test_starved_entry_wins_the_only_slot(policy):
    """A WAITING entry whose extra wait exceeds (its base-rank deficit /
    boost) + the grace window beats every fresh short competitor."""
    boost, delay = 2.0, 5.0
    entries = {0: mk(0, arrival=0.0, pred=500.0, r0=500.0)}  # the starver
    for rid in range(1, 5):
        entries[rid] = mk(rid, arrival=290.0 + rid, pred=1.0, r0=1.0)
    now = 300.0   # waited 300s >> 5 + (500-1)/2
    d = select_batch(entries, policy=policy, max_batch=1,
                     mem_budget=1 << 60, bytes_fn=bytes_fn, now=now,
                     age_boost=boost, age_delay=delay)
    assert d.scheduled == [0]
    # and with aging off the same starver keeps losing
    d0 = select_batch(entries, policy=policy, max_batch=1,
                      mem_budget=1 << 60, bytes_fn=bytes_fn, now=now)
    assert 0 not in d0.scheduled


def test_in_slack_running_entry_never_preempted():
    entries = {
        0: mk(0, arrival=0.0, pred=400.0, state=ReqState.RUNNING,
              deadline_at=21.0),                    # 1s of slack left
        1: mk(1, arrival=1.0, pred=2.0),            # much better rank
    }
    d = select_batch(entries, policy="srpt", max_batch=1,
                     mem_budget=1 << 60, bytes_fn=bytes_fn, now=20.0,
                     deadline_slack=3.0)
    assert 0 in d.scheduled and d.preempted == []
    # slack off: the short job takes the slot
    d0 = select_batch(entries, policy="srpt", max_batch=1,
                      mem_budget=1 << 60, bytes_fn=bytes_fn, now=20.0)
    assert d0.preempted == [0]


@given(st.integers(2, 12).flatmap(
    lambda n: st.tuples(
        st.lists(st.tuples(st.floats(0.0, 50.0),       # arrival
                           st.floats(0.5, 200.0),      # pred
                           st.sampled_from([ReqState.WAITING,
                                            ReqState.RUNNING,
                                            ReqState.PREEMPTED]),
                           st.floats(0.0, 120.0)),     # deadline_at
                 min_size=n, max_size=n),
        st.integers(1, 4),                             # max_batch
        st.floats(50.0, 100.0),                        # now
        st.floats(0.5, 20.0))),                        # slack
    st.sampled_from([p for p in POLICIES if p not in ("fcfs", "sjf")]))
@settings(max_examples=150, deadline=None)
def test_slack_property_no_in_slack_preemption(tup, policy):
    rows, max_batch, now, slack = tup
    entries = {}
    for rid, (arr, pred, state, dl) in enumerate(rows):
        entries[rid] = mk(rid, arrival=arr, pred=pred, r0=pred,
                          state=state, deadline_at=dl)
    d = select_batch(entries, policy=policy, max_batch=max_batch,
                     mem_budget=1 << 60, bytes_fn=bytes_fn, now=now,
                     deadline_slack=slack)
    for rid in d.preempted:
        e = entries[rid]
        assert not (e.deadline_at > 0.0
                    and e.deadline_at - now <= slack), \
            f"in-slack rid {rid} was preempted"


@given(st.integers(1, 10).flatmap(
    lambda n: st.lists(st.tuples(st.floats(0.0, 40.0),
                                 st.floats(0.5, 100.0),
                                 st.sampled_from([ReqState.WAITING,
                                                  ReqState.RUNNING,
                                                  ReqState.PREEMPTED])),
                       min_size=n, max_size=n)),
    st.floats(40.0, 1e6),
    st.sampled_from(list(POLICIES)))
@settings(max_examples=150, deadline=None)
def test_zero_boost_property_decisions_identical(rows, now, policy):
    """age_boost=0 at any clock value reproduces the legacy decision."""
    entries = {rid: mk(rid, arrival=a, pred=p, r0=p, state=s)
               for rid, (a, p, s) in enumerate(rows)}
    legacy = select_batch(copy.deepcopy(entries), policy=policy,
                          max_batch=2, mem_budget=1 << 60,
                          bytes_fn=bytes_fn)
    gated = select_batch(copy.deepcopy(entries), policy=policy,
                         max_batch=2, mem_budget=1 << 60,
                         bytes_fn=bytes_fn, now=now, age_boost=0.0,
                         age_delay=123.0, deadline_slack=0.0)
    assert (legacy.scheduled, legacy.preempted, legacy.admitted) \
        == (gated.scheduled, gated.preempted, gated.admitted)


# ---------------------------------------------------------------------------
# engine: bounded waiting, slack windows on the event log, aged backlog
# ---------------------------------------------------------------------------

def test_engine_aging_bounds_waiting_under_overload():
    """At overload, rank aging finishes every request with a strictly
    smaller worst-case first-token wait than pure TRAIL."""
    reqs = workload(n=60, rate=60.0, scenario="bursty")
    waits = {}
    for boost in (0.0, 256.0):
        log = EventLog()
        run_policy(CFG, "trail", reqs, hardware=HW, seed=0,
                   age_boost=boost, age_delay_s=5.0, event_log=log)
        rep = rollup(log)
        assert rep["requests"]["finished"] == 60
        waits[boost] = rep["counters"]["max_wait_s"]
    assert waits[256.0] < waits[0.0]


def test_engine_honors_deadline_slack_on_event_log():
    """With the slack knob on, no preempt event may land inside the
    victim's slack window (deadline_at - t <= slack)."""
    slack = 20.0
    log = EventLog()
    run_policy(CFG, "trail", workload(n=50, rate=50.0), hardware=HW,
               seed=0, deadline_s=60.0, deadline_slack_s=slack,
               event_log=log)
    arrivals = {}
    n_preempt = 0
    for e in log.events:
        if e.kind == "arrival":
            arrivals.setdefault(e.rid, e.t)
        elif e.kind == "preempt":
            n_preempt += 1
            deadline_at = arrivals[e.rid] + 60.0
            assert deadline_at - e.t > slack
    assert n_preempt > 0     # the scenario actually exercises preemption


def test_backlog_cap_ages_with_the_hinge():
    eng = Engine(CFG, EngineConfig(policy="trail", hardware=HW, seed=0,
                                   age_boost=10.0, age_delay_s=5.0))
    for r in copy.deepcopy(workload(n=4, rate=100.0)):
        eng.submit(r)
    eng.step()
    base_now = eng._now
    capped0 = eng.backlog(truncate=1.0, include_pending=False)
    # inside the grace window the cap (and thus the backlog) is frozen
    eng._now = base_now + 4.0
    assert eng.backlog(truncate=1.0, include_pending=False) == capped0
    # past it the per-job cap rises, so the truncated backlog can only grow
    eng._now = base_now + 500.0
    aged = eng.backlog(truncate=1.0, include_pending=False)
    assert aged >= capped0
    # and with a cap this old the hinge has unclipped every job: the
    # truncated backlog equals the untruncated one
    assert aged == pytest.approx(
        eng.backlog(truncate=None, include_pending=False))


def test_run_policy_tail_knobs_off_are_byte_identical():
    reqs = workload(n=40, rate=4.0)
    base = run_policy(CFG, "trail", reqs, hardware=HW, seed=0)
    gated = run_policy(CFG, "trail", reqs, hardware=HW, seed=0,
                       age_boost=0.0, age_delay_s=0.0,
                       deadline_slack_s=0.0)
    assert json.dumps(base.summary(), sort_keys=True) \
        == json.dumps(gated.summary(), sort_keys=True)
    assert base.latencies == gated.latencies
    assert base.n_preemptions == gated.n_preemptions


# ---------------------------------------------------------------------------
# benchmark identity: off-is-free + BENCH_tail determinism pin
# ---------------------------------------------------------------------------

def _bench_cell(policy, scale, **knobs):
    import sys
    sys.path.insert(0, ROOT)
    from benchmarks.tail_curves import _run_cell
    from benchmarks.trace_replay import _cell_summary, _make_cfg
    from repro.traces import load_trace
    report, _ = _run_cell(_make_cfg(), load_trace("sample"), policy, scale,
                          **knobs)
    return _cell_summary(report)


@pytest.mark.slow
def test_headline_cell_off_is_free_vs_committed_artifact():
    """BENCH_trace_replay's headline cell replayed with the tail knobs
    explicitly at their defaults: byte-identical to the committed grid."""
    with open(os.path.join(ROOT, "BENCH_trace_replay.json")) as f:
        committed = json.load(f)["grid"]["scale=24.0.trail"]
    cell = _bench_cell("trail", 24.0, age_boost=0.0, age_delay_s=0.0,
                       deadline_slack_s=0.0)
    assert json.dumps(cell, sort_keys=True) \
        == json.dumps(committed, sort_keys=True)


@pytest.mark.slow
def test_bench_tail_headline_cell_reproduces_committed_artifact():
    """Determinism pin on BENCH_tail.json: rerunning the tail recipe
    cell reproduces the committed completion summary exactly."""
    with open(os.path.join(ROOT, "BENCH_tail.json")) as f:
        payload = json.load(f)
    committed = payload["grid"]["scale=24.0.trail.tail"]
    cell = _bench_cell("trail", 24.0, **payload["config"]["tail_recipe"])
    assert json.dumps(cell["completion"], sort_keys=True) \
        == json.dumps(committed["completion"], sort_keys=True)
    assert payload["headline"]["gates_ok"] is True
    assert payload["headline"]["p99_uninverted"] is True
