"""Scenario-library tests: seed determinism, empirical arrival rates,
tenant mixes, RNG-stream decoupling, and legacy byte-compatibility."""

import math
import random

import pytest

from repro.serving.workload import (SCENARIOS, TenantSpec, WorkloadConfig,
                                    generate, scenario_config)


def _sig(reqs):
    return [(r.arrival, len(r.prompt), r.true_out_len, r.tenant,
             tuple(r.prompt[:4])) for r in reqs]


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_seed_determinism(name):
    wc = scenario_config(name, n_requests=64, request_rate=10.0, seed=9,
                         vocab=500)
    assert _sig(generate(wc)) == _sig(generate(wc))
    # a different seed must actually change the stream
    wc2 = scenario_config(name, n_requests=64, request_rate=10.0, seed=10,
                          vocab=500)
    assert _sig(generate(wc2)) != _sig(generate(wc))


@pytest.mark.parametrize("name,tol", [("poisson", 0.10), ("bursty", 0.25),
                                      ("diurnal", 0.25)])
def test_empirical_arrival_rate(name, tol):
    rate = 12.0
    wc = scenario_config(name, n_requests=3000, request_rate=rate, seed=2,
                         vocab=100)
    reqs = generate(wc)
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)
    emp = (len(arr) - 1) / (arr[-1] - arr[0])
    assert abs(emp - rate) / rate < tol, emp


def test_mmpp_is_burstier_than_poisson():
    """Squared coefficient of variation of inter-arrivals: MMPP > Poisson
    (which has CV^2 = 1)."""
    def cv2(name):
        reqs = generate(scenario_config(name, n_requests=4000,
                                        request_rate=10.0, seed=5,
                                        vocab=100))
        gaps = [b.arrival - a.arrival for a, b in zip(reqs, reqs[1:])]
        mu = sum(gaps) / len(gaps)
        var = sum((g - mu) ** 2 for g in gaps) / len(gaps)
        return var / (mu * mu)
    assert cv2("bursty") > 1.3 > cv2("poisson")


def test_tenant_mix_proportions():
    wc = scenario_config("multi-tenant", n_requests=3000, request_rate=10.0,
                         seed=1, vocab=100)
    reqs = generate(wc)
    weights = {s.name: s.weight for s in wc.tenants}
    total = sum(weights.values())
    for name, w in weights.items():
        frac = sum(1 for r in reqs if r.tenant == name) / len(reqs)
        assert abs(frac - w / total) < 0.05, (name, frac)
    # tenant length params actually apply: summarize prompts >> chat prompts
    mean_plen = lambda t: (sum(len(r.prompt) for r in reqs if r.tenant == t)
                           / max(sum(1 for r in reqs if r.tenant == t), 1))
    assert mean_plen("summarize") > 3 * mean_plen("chat")


def test_split_streams_decouple_rate_from_sizes():
    """The satellite fix: changing request_rate must not reshuffle length
    or content draws when streams are split."""
    a = generate(scenario_config("poisson", n_requests=80, request_rate=5.0,
                                 seed=7, vocab=300))
    b = generate(scenario_config("poisson", n_requests=80, request_rate=50.0,
                                 seed=7, vocab=300))
    assert [r.arrival for r in a] != [r.arrival for r in b]
    assert all(x.prompt == y.prompt and x.true_out_len == y.true_out_len
               for x, y in zip(a, b))


def test_legacy_rng_is_coupled_and_byte_stable():
    """The default (compat) path keeps the historical coupled stream: the
    same draws as random.Random(seed) interleaved arrival->lengths->
    content, so old experiment artifacts stay reproducible."""
    wc = WorkloadConfig(n_requests=3, request_rate=10.0, seed=0, vocab=50)
    reqs = generate(wc)
    rng = random.Random(0)
    t = 0.0
    for r in reqs:
        t += rng.expovariate(10.0)
        plen = max(4, min(int(rng.lognormvariate(math.log(44.0), 0.6)), 2048))
        olen = max(1, min(int(rng.lognormvariate(math.log(48.0), 1.0)), 512))
        prompt = [rng.randrange(1, 50) for _ in range(plen)]
        assert (r.arrival, len(r.prompt), r.true_out_len, r.prompt) == \
            (t, plen, olen, prompt)
    # and changing the arrival process DOES reshuffle sizes on the legacy
    # path (burst skips the expovariate draws, shifting every later draw)
    reqs2 = generate(WorkloadConfig(n_requests=3, request_rate=10.0, seed=0,
                                    vocab=50, burst=True))
    assert [r.true_out_len for r in reqs2] != [r.true_out_len for r in reqs]
    # ...while the split-stream path is invariant to it
    a = generate(WorkloadConfig(n_requests=3, seed=0, vocab=50,
                                split_streams=True))
    b = generate(WorkloadConfig(n_requests=3, seed=0, vocab=50, burst=True,
                                split_streams=True))
    assert [r.true_out_len for r in a] == [r.true_out_len for r in b]


def test_burst_scenario_arrives_at_zero():
    wc = scenario_config("burst", n_requests=16, request_rate=10.0, seed=3,
                         vocab=100)
    assert all(r.arrival == 0.0 for r in generate(wc))
    # legacy burst flag still works
    assert all(r.arrival == 0.0 for r in
               generate(WorkloadConfig(n_requests=16, burst=True)))


def test_validation_errors():
    with pytest.raises(ValueError):
        scenario_config("nope", n_requests=4, request_rate=1.0)
    with pytest.raises(ValueError):
        generate(WorkloadConfig(arrival="weibull", split_streams=True))
    with pytest.raises(ValueError):        # OFF rate would go negative
        generate(WorkloadConfig(arrival="mmpp", split_streams=True,
                                mmpp_duty=0.5, mmpp_burst_factor=3.0,
                                n_requests=4))
    with pytest.raises(ValueError):        # tenants need split streams
        generate(WorkloadConfig(tenants=(TenantSpec("a", 1.0),)))


def test_scenario_config_overrides():
    wc = scenario_config("bursty", n_requests=8, request_rate=2.0,
                         mmpp_cycle=99.0)
    assert wc.mmpp_cycle == 99.0 and wc.split_streams
