"""Bayesian refinement: transition-matrix structure + filter properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, hnp, settings, st

from repro.config import ProbeConfig
from repro.core.bins import bin_index, bin_means
from repro.core.smoothing import (bayes_update, expected_length,
                                  refine_sequence, transition_matrix)

PC = ProbeConfig()   # paper defaults: k=10 bins over [0, 512]


def test_transition_matrix_structure():
    T = transition_matrix(PC)
    k = PC.num_bins
    r = 1.0 / PC.bin_width
    assert T.shape == (k, k)
    # paper Appendix A: bidiagonal, columns stochastic
    np.testing.assert_allclose(T.sum(axis=0), np.ones(k), atol=1e-12)
    for i in range(1, k - 1):
        assert T[i, i] == pytest.approx(1 - r)
        assert T[i, i + 1] == pytest.approx(r)
    assert T[0, 0] == pytest.approx(1.0)


def test_bin_geometry_matches_paper():
    # b_i covers [512i/10, 512(i+1)/10); m_i = 128(2i+1)/5
    m = bin_means(PC)
    for i in range(PC.num_bins):
        assert m[i] == pytest.approx(128 * (2 * i + 1) / 5)
    assert int(bin_index(0, PC)) == 0
    assert int(bin_index(511, PC)) == 9
    assert int(bin_index(51.1, PC)) == 0
    assert int(bin_index(51.3, PC)) == 1


@given(hnp.arrays(np.float64, (10,), elements=st.floats(1e-3, 1.0)),
       hnp.arrays(np.float64, (10,), elements=st.floats(0.0, 1.0)))
@settings(max_examples=100, deadline=None)
def test_filter_keeps_simplex(q_raw, p_raw):
    q = jnp.asarray(q_raw / q_raw.sum())
    p = jnp.asarray(p_raw)
    T = transition_matrix(PC)
    q2 = bayes_update(q, p, T)
    assert bool(jnp.all(q2 >= -1e-9))
    assert float(jnp.abs(jnp.sum(q2) - 1.0)) < 1e-6
    el = expected_length(q2, PC)
    assert 0.0 <= float(el) <= PC.max_len


def test_filter_converges_on_consistent_evidence():
    """Repeated sharp evidence in bin b pulls the posterior to b."""
    T = transition_matrix(PC)
    q = jnp.ones((PC.num_bins,)) / PC.num_bins
    p = jnp.asarray(np.eye(PC.num_bins)[7] * 0.9 + 0.01)
    for _ in range(6):
        q = bayes_update(q, p, T)
    assert int(jnp.argmax(q)) == 7
    assert float(q[7]) > 0.9


def test_refine_reduces_noise_mae():
    """The paper's key claim at micro scale: the filtered estimate tracks a
    shrinking remaining-length better than raw noisy per-step predictions."""
    rng = np.random.default_rng(0)
    true_len = 300
    k = PC.num_bins
    raw_mae, ref_mae = [], []
    for trial in range(20):
        ps = []
        for t in range(true_len):
            rem = true_len - t
            b = min(int(rem / PC.bin_width), k - 1)
            # noisy probe: sometimes off by up to 3 bins
            off = rng.integers(-3, 4) if rng.random() < 0.5 else 0
            bb = int(np.clip(b + off, 0, k - 1))
            logits = -np.abs(np.arange(k) - bb) * 1.2
            p = np.exp(logits)
            ps.append(p / p.sum())
        ps = jnp.asarray(np.stack(ps))
        qs = refine_sequence(ps, PC)
        m = bin_means(PC)
        rems = np.array([true_len - t for t in range(true_len)])
        raw_mae.append(np.mean(np.abs(np.asarray(ps) @ m - rems)))
        ref_mae.append(np.mean(np.abs(np.asarray(qs) @ m - rems)))
    assert np.mean(ref_mae) < np.mean(raw_mae)
