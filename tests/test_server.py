"""Front-door tests: on_token hooks, HTTP parsing, and the live server."""

import asyncio
import json

import pytest

from repro.config import get_config
from repro.metrics import EventLog
from repro.server import EngineServer, ServerConfig
from repro.server import http as fdhttp
from repro.serving.costmodel import HardwareSpec
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request
from repro.serving.workload import WorkloadConfig, generate

CFG = get_config("granite-3-8b")


def _engine(**kw):
    return Engine(CFG, EngineConfig(policy="trail", hardware=HardwareSpec(),
                                    seed=0, **kw), event_log=EventLog())


# ---------------------------------------------------------------------------
# Engine.on_token: per-request ordering, terminals, auto-unsubscribe
# ---------------------------------------------------------------------------

def test_on_token_per_request_event_ordering():
    """Each subscribed rid sees first_token -> tokens* -> finish, in
    order, with token counts summing to the generated length."""
    eng = _engine()
    wc = WorkloadConfig(n_requests=6, request_rate=30.0, seed=3,
                        vocab=CFG.vocab_size)
    reqs = generate(wc)
    seen = {r.rid: [] for r in reqs}
    for r in reqs:
        eng.submit(r)
        eng.on_token(r.rid, lambda t, k, v, rid=r.rid:
                     seen[rid].append((t, k, v)))
    while eng.has_work():
        eng.step()
    for r in reqs:
        kinds = [k for _, k, _ in seen[r.rid]]
        assert kinds[0] == "first_token"
        assert kinds[-1] == "finish"
        assert set(kinds[1:-1]) == {"tokens"}
        assert sum(int(v) for _, k, v in seen[r.rid] if k == "tokens") \
            == len(r.generated)
        times = [t for t, _, _ in seen[r.rid]]
        assert times == sorted(times)
    # terminal events auto-unsubscribe: the registry drains itself
    assert eng._subs == {}


def test_on_token_matches_event_log_order():
    """The callback stream is exactly the event log's per-request slice
    (for the streamed kinds) — same kinds, same order, same times."""
    eng = _engine()
    wc = WorkloadConfig(n_requests=5, request_rate=20.0, seed=7,
                        vocab=CFG.vocab_size)
    reqs = generate(wc)
    seen = {r.rid: [] for r in reqs}
    for r in reqs:
        eng.submit(r)
        eng.on_token(r.rid, lambda t, k, v, rid=r.rid:
                     seen[rid].append((t, k)))
    while eng.has_work():
        eng.step()
    per_req = eng.events.per_request()
    streamed = ("first_token", "tokens", "finish", "cancel", "timeout",
                "shed")
    for r in reqs:
        logged = [(e.t, e.kind) for e in per_req[r.rid]
                  if e.kind in streamed]
        assert seen[r.rid] == logged


def test_on_token_terminal_cancel_and_timeout():
    """Cancel kinds are delivered as the terminal callback event, for
    both pool-resident and still-pending requests."""
    eng = _engine()
    events = []
    eng.submit(Request(0, 0.0, [1] * 16, true_out_len=400))
    eng.submit(Request(1, 500.0, [1] * 16, true_out_len=8))
    eng.on_token(0, lambda t, k, v: events.append((0, k)))
    eng.on_token(1, lambda t, k, v: events.append((1, k)))
    eng.step()
    assert eng.cancel(0, "timeout")          # admitted, running
    assert eng.cancel(1, "shed")             # still pending
    assert (0, "timeout") in events and (1, "shed") in events
    assert eng._subs == {}
    eng.off_token(0)                         # idempotent after terminal


def test_on_token_unused_is_invisible():
    """A run with no subscribers leaves the event stream byte-identical
    to one that never heard of on_token (the off-is-free property)."""
    wc = WorkloadConfig(n_requests=8, request_rate=25.0, seed=11,
                        vocab=CFG.vocab_size)
    logs = []
    for subscribe in (False, True):
        eng = _engine()
        for r in generate(wc):
            eng.submit(r)
            if subscribe:
                eng.on_token(r.rid, lambda t, k, v: None)
        while eng.has_work():
            eng.step()
        logs.append([(e.t, e.rid, e.kind, e.value)
                     for e in eng.events.events])
    assert logs[0] == logs[1]


# ---------------------------------------------------------------------------
# HTTP layer
# ---------------------------------------------------------------------------

def _feed(data: bytes):
    reader = asyncio.StreamReader()
    reader.feed_data(data)
    reader.feed_eof()
    return reader


def test_http_parses_request():
    async def main():
        reader = _feed(b"POST /v1/generate?x=1 HTTP/1.1\r\n"
                       b"Host: h\r\nContent-Length: 2\r\n\r\n{}")
        return await fdhttp.read_request(reader)

    method, path, headers, body = asyncio.run(main())
    assert (method, path, body) == ("POST", "/v1/generate", b"{}")
    assert headers["host"] == "h"


def test_http_clean_eof_is_none_and_garbage_is_400():
    async def parse(data):
        return await fdhttp.read_request(_feed(data))

    assert asyncio.run(parse(b"")) is None
    with pytest.raises(fdhttp.HttpError) as e:
        asyncio.run(parse(b"NOT-HTTP\r\n\r\n"))
    assert e.value.status == 400
    with pytest.raises(fdhttp.HttpError) as e:
        asyncio.run(parse(b"GET / HTTP/1.1\r\nContent-Length: no\r\n\r\n"))
    assert e.value.status == 400


def test_http_oversized_body_is_413():
    async def parse():
        big = fdhttp.MAX_BODY_BYTES + 1
        head = f"POST / HTTP/1.1\r\nContent-Length: {big}\r\n\r\n"
        return await fdhttp.read_request(_feed(head.encode()))

    with pytest.raises(fdhttp.HttpError) as e:
        asyncio.run(parse())
    assert e.value.status == 413


# ---------------------------------------------------------------------------
# Live server integration (in-process asyncio, OS-assigned port)
# ---------------------------------------------------------------------------

async def _request(port, method, path, body=b""):
    """One plain (non-streaming) request; returns (status, json dict,
    headers)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(body)}\r\n"
                  f"Connection: close\r\n\r\n").encode() + body)
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode().split("\r\n")
    status = int(lines[0].split(" ")[1])
    headers = {}
    for line in lines[1:]:
        name, sep, value = line.partition(":")
        if sep:
            headers[name.strip().lower()] = value.strip()
    payload = json.loads(await reader.read())
    writer.close()
    return status, payload, headers


async def _sse_events(reader):
    """Collect SSE events until the terminal one (or EOF)."""
    events = []
    while True:
        line = await reader.readline()
        if not line:
            return events
        line = line.strip()
        if not line.startswith(b"data: "):
            continue
        event = json.loads(line[6:])
        events.append(event)
        if event["event"] in ("finish", "cancel", "timeout", "shed"):
            return events


async def _generate_stream(port, payload):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode()
    writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    assert b"200" in head.split(b"\r\n")[0]
    events = await _sse_events(reader)
    writer.close()
    return events


def _serve(coro_fn, skw=None, **ekw):
    """Run one test body against a started server, then tear down."""
    async def main():
        eng = _engine(**ekw)
        server = EngineServer(
            eng, ServerConfig(**{"port": 0, "time_scale": 200.0,
                                 **(skw or {})}))
        await server.start()
        try:
            return await coro_fn(server, eng)
        finally:
            await server.close()

    return asyncio.run(main())


def test_server_healthz_404_and_bad_json():
    async def body(server, eng):
        status, payload, _ = await _request(server.port, "GET", "/healthz")
        assert status == 200 and payload["accepted"] == 0
        status, payload, _ = await _request(server.port, "GET", "/nope")
        assert status == 404
        status, payload, _ = await _request(server.port, "POST",
                                            "/v1/generate", b"{not json")
        assert status == 400 and "error" in payload

    _serve(body)


def test_server_streams_tokens_to_finish():
    async def body(server, eng):
        events = await _generate_stream(
            server.port, {"prompt_tokens": 16, "out_tokens": 6})
        kinds = [e["event"] for e in events]
        assert kinds[0] == "accepted"
        assert kinds[1] == "first_token"
        assert kinds[-1] == "finish"
        assert sum(e.get("n", 0) for e in events
                   if e["event"] == "tokens") == 6
        status, payload, _ = await _request(server.port, "GET", "/metrics")
        assert status == 200 and payload["requests"]["finished"] == 1

    _serve(body)


def test_server_deadline_maps_to_timeout_event():
    async def body(server, eng):
        events = await _generate_stream(
            server.port,
            {"prompt_tokens": 16, "out_tokens": 500, "timeout_s": 2.0})
        assert events[-1]["event"] == "timeout"
        assert eng.stats.n_timeouts == 1

    _serve(body)


def test_server_backpressure_429_with_retry_after():
    async def body(server, eng):
        # park one long request: its predicted backlog (~450 tokens)
        # sits above the door's admit watermark for the whole decode,
        # so the next knock is rejected while the stream keeps running
        reader, writer = await asyncio.open_connection(
            "127.0.0.1", server.port)
        body1 = json.dumps({"prompt_tokens": 64, "out_tokens": 500}).encode()
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                      f"Content-Length: {len(body1)}\r\n\r\n").encode()
                     + body1)
        await writer.drain()
        await reader.readuntil(b"\r\n\r\n")     # wait until accepted
        await reader.readline()
        status, payload, headers = await _request(
            server.port, "POST", "/v1/generate",
            json.dumps({"prompt_tokens": 8}).encode())
        assert status == 429
        assert "retry-after" in headers
        assert int(headers["retry-after"]) >= 1
        assert payload["error"] == "overloaded"
        assert server.n_rejected == 1
        writer.close()

    _serve(body, skw={"admit_watermark": 250.0, "time_scale": 20.0})


def test_server_client_disconnect_cancels_request():
    async def body(server, eng):
        reader, writer = await asyncio.open_connection(
            "127.0.0.1", server.port)
        payload = json.dumps({"prompt_tokens": 16,
                              "out_tokens": 500}).encode()
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                      f"Content-Length: {len(payload)}\r\n\r\n").encode()
                     + payload)
        await writer.drain()
        await reader.readuntil(b"\r\n\r\n")
        await reader.readline()                 # at least the accept event
        writer.close()                          # user walks away
        for _ in range(200):
            if eng.stats.n_cancelled:
                break
            await asyncio.sleep(0.02)
        assert eng.stats.n_cancelled == 1
        assert not eng.has_work()

    _serve(body)
