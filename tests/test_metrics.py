"""Metrics-layer tests: streaming-percentile exactness vs numpy, event-log
invariants under hypothesis, rollup determinism, and the no-perturbation
guarantee (enabling the metrics layer changes no scheduling result)."""

import json

import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.config import get_config
from repro.metrics import (EventLog, StreamingQuantiles, check_invariants,
                           report_json, report_markdown, rollup)
from repro.metrics.events import Event
from repro.serving.costmodel import CostModel, HardwareSpec
from repro.serving.engine import run_policy
from repro.serving.workload import WorkloadConfig, generate

CFG = get_config("granite-3-8b")
HW = HardwareSpec(name="compute-bound-2tf", peak_flops=2e12, hbm_bw=819e9,
                  overhead_s=2e-4)


def _small_workload(seed=3, n=24, rate=1.2):
    wc = WorkloadConfig(n_requests=n, request_rate=rate, seed=seed,
                        vocab=1000, split_streams=True, out_median=24.0,
                        max_out=96)
    return generate(wc)


# ---------------------------------------------------------------------------
# streaming percentiles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 17, 100, 999])
def test_streaming_percentiles_match_numpy(n):
    rng = np.random.default_rng(n)
    xs = rng.lognormal(1.0, 1.5, n)
    acc = StreamingQuantiles()
    for x in xs:
        acc.add(float(x))
    for q in (0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0):
        assert acc.percentile(q) == float(np.percentile(xs, q)), (n, q)
    s = acc.summary()
    assert s["n"] == n
    assert s["p99"] == float(np.percentile(xs, 99.0))
    assert s["mean"] == pytest.approx(float(np.mean(xs)))


def test_streaming_merge_and_order_invariance():
    rng = np.random.default_rng(0)
    xs = rng.normal(size=257)
    a = StreamingQuantiles(xs[:100])
    b = StreamingQuantiles(xs[100:])
    a.merge(b)
    whole = StreamingQuantiles(sorted(xs))     # different insertion order
    assert a.summary() == whole.summary()
    assert len(a) == 257


def test_streaming_attainment():
    acc = StreamingQuantiles([1.0, 2.0, 3.0, 4.0])
    assert acc.attainment(0.5) == 0.0
    assert acc.attainment(2.0) == 0.5          # <= is inclusive
    assert acc.attainment(100.0) == 1.0
    assert StreamingQuantiles().attainment(1.0) == 0.0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1e6,
                          allow_nan=False), min_size=1, max_size=200),
       st.floats(min_value=0.0, max_value=100.0))
def test_streaming_percentile_property(xs, q):
    acc = StreamingQuantiles(xs)
    assert acc.percentile(q) == float(np.percentile(np.asarray(xs), q))


# ---------------------------------------------------------------------------
# event log + rollup semantics
# ---------------------------------------------------------------------------

def _hand_log():
    log = EventLog()
    log.emit(0.0, 1, "arrival")
    log.emit(1.0, 1, "admit")
    log.emit(2.0, 1, "first_token")
    log.emit(2.0, 1, "tokens", 1)
    log.emit(3.0, 1, "tokens", 2)       # megastep: 2 tokens, 1s gap
    log.emit(3.0, 1, "finish")
    return log


def test_rollup_hand_computed():
    rep = rollup(_hand_log())
    assert rep["requests"] == {"arrived": 1, "finished": 1,
                               "cancelled": 0, "goodput": 1.0,
                               "output_tokens": 3.0}
    assert rep["ttft"]["mean"] == 2.0
    assert rep["completion"]["mean"] == 3.0
    # megastep gap of 1s over 2 tokens -> two 0.5s TBT samples
    assert rep["tbt"]["n"] == 2
    assert rep["tbt"]["mean"] == 0.5
    assert rep["latency_per_token"]["mean"] == 1.0
    check_invariants(_hand_log())


def test_rollup_slowdown_needs_service_times():
    rep = rollup(_hand_log())
    assert "slowdown" not in rep
    rep = rollup(_hand_log(), service_times={1: 1.5})
    assert rep["slowdown"]["mean"] == 2.0


def test_rollup_counts_ttft_of_inflight_requests():
    """A started-but-unfinished request contributes its TTFT (it is
    determined at the first token) — mid-run rollups must not drop the
    long-stuck tail."""
    log = EventLog()
    log.emit(0.0, 1, "arrival")
    log.emit(9.0, 1, "first_token")
    log.emit(9.0, 1, "tokens", 1)       # still decoding, no finish
    rep = rollup(log)
    assert rep["requests"] == {"arrived": 1, "finished": 0,
                               "cancelled": 0, "goodput": 0.0,
                               "output_tokens": 1.0}
    assert rep["ttft"]["n"] == 1
    assert rep["ttft"]["mean"] == 9.0
    assert rep["completion"]["n"] == 0


def test_check_invariants_catches_violations():
    log = EventLog()
    log.emit(5.0, 1, "arrival")
    log.emit(4.0, 1, "admit")               # admitted before arrival
    with pytest.raises(AssertionError):
        check_invariants(log)
    log2 = EventLog()
    log2.emit(0.0, 2, "arrival")
    log2.emit(1.0, 2, "finish")             # finish without any token
    with pytest.raises(AssertionError):
        check_invariants(log2)


def test_event_log_merge_orders_by_time():
    a, b = EventLog(), EventLog()
    a.emit(2.0, 1, "admit")
    b.emit(1.0, 2, "arrival")
    a.merge(b)
    assert [e.t for e in a.events] == [1.0, 2.0]
    assert isinstance(a.events[0], Event)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["trail", "fcfs", "srpt"])
def test_metrics_layer_does_not_perturb_results(policy):
    """Acceptance pin: enabling the event log leaves every scheduling
    result byte-identical — latencies, TTFTs, preemption counts."""
    reqs = _small_workload()
    log = EventLog()
    s_with = run_policy(CFG, policy, reqs, hardware=HW, event_log=log,
                        mem_budget=1 << 26)
    s_without = run_policy(CFG, policy, reqs, hardware=HW,
                           mem_budget=1 << 26)
    assert s_with.latencies == s_without.latencies
    assert s_with.ttfts == s_without.ttfts
    assert s_with.n_preemptions == s_without.n_preemptions
    assert len(log) > 0


def test_engine_rollup_matches_engine_stats():
    """The rollup's completion/TTFT distributions are exactly the
    engine's own latency/TTFT lists — one source of truth."""
    reqs = _small_workload(seed=7)
    log = EventLog()
    stats = run_policy(CFG, "trail", reqs, hardware=HW, event_log=log)
    rep = rollup(log)
    assert rep["requests"]["finished"] == len(stats.latencies)
    assert rep["completion"]["mean"] == pytest.approx(
        float(np.mean(stats.latencies)))
    assert rep["ttft"]["mean"] == pytest.approx(float(np.mean(stats.ttfts)))
    assert rep["completion"]["p99"] == pytest.approx(
        float(np.percentile(stats.latencies, 99.0)))


def test_engine_rollup_deterministic_bytes():
    reqs = _small_workload(seed=11)
    outs = []
    for _ in range(2):
        log = EventLog()
        run_policy(CFG, "trail", reqs, hardware=HW, event_log=log)
        outs.append(report_json(rollup(log)))
    assert outs[0] == outs[1]
    json.loads(outs[0])                     # valid JSON


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       rate=st.floats(min_value=0.3, max_value=4.0),
       policy=st.sampled_from(["trail", "fcfs", "srpt", "sjf"]),
       mem_mb=st.sampled_from([192, 1 << 30]))
def test_event_log_invariants_property(seed, rate, policy, mem_mb):
    """Monotone timestamps, arrival<=admit<=first_token<=finish,
    TTFT <= completion, and exact token accounting — across random
    workloads, policies, and memory pressure."""
    reqs = generate(WorkloadConfig(n_requests=14, request_rate=rate,
                                   seed=seed, vocab=500,
                                   split_streams=True, out_median=16.0,
                                   max_out=48))
    log = EventLog()
    stats = run_policy(CFG, policy, reqs, hardware=HW, event_log=log,
                       mem_budget=mem_mb << 20)
    check_invariants(log)
    per_req = log.per_request()
    for r in reqs:
        evs = per_req[r.rid]
        toks = sum(e.value for e in evs if e.kind == "tokens")
        assert toks == min(r.true_out_len, r.max_new_tokens)
    n_preempt = sum(1 for e in log.events if e.kind == "preempt")
    assert n_preempt == stats.n_preemptions


@pytest.mark.parametrize("seed,policy,mem_mb",
                         [(0, "trail", 192), (1, "fcfs", 1 << 30),
                          (2, "srpt", 192), (3, "trail", 1 << 30)])
def test_event_log_invariants_fixed(seed, policy, mem_mb):
    """Deterministic slice of the hypothesis sweep above, so the
    invariants run even where hypothesis is unavailable."""
    reqs = generate(WorkloadConfig(n_requests=14, request_rate=1.5,
                                   seed=seed, vocab=500,
                                   split_streams=True, out_median=16.0,
                                   max_out=48))
    log = EventLog()
    stats = run_policy(CFG, policy, reqs, hardware=HW, event_log=log,
                       mem_budget=mem_mb << 20)
    check_invariants(log)
    per_req = log.per_request()
    for r in reqs:
        toks = sum(e.value for e in per_req[r.rid] if e.kind == "tokens")
        assert toks == min(r.true_out_len, r.max_new_tokens)
    assert sum(1 for e in log.events
               if e.kind == "preempt") == stats.n_preemptions


def test_step_result_exposes_events():
    from repro.serving.engine import Engine, EngineConfig
    reqs = _small_workload(seed=5, n=6)
    log = EventLog()
    eng = Engine(CFG, EngineConfig(policy="trail", hardware=HW),
                 event_log=log)
    for r in sorted(reqs, key=lambda r: r.arrival):
        eng.submit(r)
    seen = []
    while eng.has_work():
        seen.extend(eng.step().events)
    assert seen == log.events               # step slices cover the log


def test_markdown_emitter_renders_all_sections():
    reqs = _small_workload(seed=2)
    log = EventLog()
    run_policy(CFG, "trail", reqs, hardware=HW, event_log=log)
    md = report_markdown(rollup(log), title="t")
    assert "### t" in md
    for row in ("ttft", "tbt", "completion"):
        assert f"| {row} |" in md
    assert "SLO attainment (ttft):" in md
    assert "Counters:" in md


# ---------------------------------------------------------------------------
# cluster merge + seconds-unit backlog (satellite)
# ---------------------------------------------------------------------------

def test_cluster_event_merge_and_rollup():
    from repro.cluster import run_cluster
    reqs = _small_workload(seed=9, n=20, rate=2.0)
    stats = run_cluster(CFG, reqs, router_policy="jspw", n_replicas=2,
                        policy="trail", seed=5, hardware=HW,
                        record_events=True)
    assert stats.event_log is not None
    check_invariants(stats.event_log)
    rep = rollup(stats.event_log)
    assert rep["requests"]["finished"] == len(stats.latencies)
    assert rep["completion"]["mean"] == pytest.approx(
        float(np.mean(stats.latencies)))


def test_backlog_seconds_is_rate_normalized_backlog():
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(CFG, EngineConfig(policy="trail", hardware=HW))
    reqs = _small_workload(seed=4, n=8)
    for r in sorted(reqs, key=lambda r: r.arrival):
        eng.submit(r)
    for _ in range(4):
        eng.step()
    rate = CostModel(CFG, HW).decode_token_rate()
    assert eng.backlog_seconds() == pytest.approx(eng.backlog() / rate)
    assert eng.backlog_seconds(truncate=10.0) == pytest.approx(
        eng.backlog(truncate=10.0) / rate)


def test_jspw_dispatch_identical_across_backlog_units():
    """Satellite pin: with identical replicas, seconds-unit backlog is a
    shared positive rescale of tokens-unit backlog — the jspw dispatch
    sequence (and every latency) must be unchanged."""
    from repro.cluster import run_cluster
    reqs = _small_workload(seed=13, n=30, rate=2.5)
    runs = {}
    for unit in ("tokens", "seconds"):
        s = run_cluster(CFG, reqs, router_policy="jspw", n_replicas=3,
                        policy="trail", seed=5, hardware=HW,
                        backlog_unit=unit)
        runs[unit] = (s.dispatch_counts, sorted(s.latencies))
    assert runs["tokens"] == runs["seconds"]


def test_router_rejects_unknown_backlog_unit():
    from repro.cluster.router import Router, RouterConfig
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(CFG, EngineConfig(policy="trail", hardware=HW))
    with pytest.raises(ValueError, match="backlog_unit"):
        Router([eng], RouterConfig(n_replicas=1, backlog_unit="minutes"))


# ---------------------------------------------------------------------------
# tail counters + per-tenant splits (PR 8)
# ---------------------------------------------------------------------------

def test_rollup_max_wait_tracks_worst_first_token():
    log = EventLog()
    log.emit(0.0, 1, "arrival")
    log.emit(2.0, 1, "first_token")
    log.emit(2.0, 1, "tokens", 1)
    log.emit(2.5, 1, "finish")
    log.emit(1.0, 2, "arrival")
    log.emit(9.0, 2, "first_token")     # worst wait: 8s
    log.emit(9.0, 2, "tokens", 1)
    log.emit(9.5, 2, "finish")
    rep = rollup(log)
    assert rep["counters"]["max_wait_s"] == 8.0


def test_rollup_max_wait_charges_unstarted_requests():
    """A never-started request's wait runs to the log's last event —
    otherwise a starving request would vanish from the starvation
    metric exactly while it starves."""
    log = EventLog()
    log.emit(0.0, 1, "arrival")
    log.emit(1.0, 1, "first_token")
    log.emit(1.0, 1, "tokens", 1)
    log.emit(2.0, 1, "finish")
    log.emit(0.5, 2, "arrival")         # still waiting at t_end=12
    log.emit(12.0, 3, "arrival")
    rep = rollup(log)
    assert rep["counters"]["max_wait_s"] == 11.5


def test_rollup_preemptions_per_request():
    log = EventLog()
    for rid in (1, 2):
        log.emit(0.0, rid, "arrival")
        log.emit(1.0, rid, "first_token")
        log.emit(1.0, rid, "tokens", 1)
    log.emit(2.0, 1, "preempt")
    log.emit(3.0, 1, "preempt")
    log.emit(4.0, 1, "preempt")
    rep = rollup(log)
    assert rep["counters"]["preemptions"] == 3
    assert rep["counters"]["preemptions_per_request"] == 1.5


def test_rollup_per_tenant_split():
    log = EventLog()
    for rid, (t0, t1) in {1: (0.0, 2.0), 2: (0.0, 10.0),
                          3: (1.0, 4.0)}.items():
        log.emit(t0, rid, "arrival")
        log.emit(t1, rid, "first_token")
        log.emit(t1, rid, "tokens", 1)
        log.emit(t1 + 1.0, rid, "finish")
    rep = rollup(log, tenants={1: "chat", 2: "batch", 3: "chat"})
    per = rep["per_tenant"]
    assert set(per) == {"chat", "batch"}
    assert per["chat"]["ttft"]["n"] == 2
    assert per["batch"]["completion"]["mean"] == 11.0
    # absent by default: existing report structure is untouched
    assert "per_tenant" not in rollup(log)
