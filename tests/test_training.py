"""Training substrate: optimizer math, data pipeline, checkpoint, probe."""

import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.config import ProbeConfig, get_smoke_config
from repro.core.bins import bin_index
from repro.models.model import Model
from repro.training import optimizer as opt_mod
from repro.training.checkpoint import restore, save
from repro.training.data import (DataConfig, batches, harvest_probe_data,
                                 sample_example, topic_median_len)
from repro.training.train import (ProbeTrainConfig, probe_mae, train_lm,
                                  train_probe)


def test_lr_schedule():
    c = opt_mod.AdamWConfig(lr=0.01, warmup_steps=10, total_steps=110)
    assert float(opt_mod.lr_at(c, 0)) == 0.0
    assert float(opt_mod.lr_at(c, 10)) == pytest.approx(0.01)
    assert float(opt_mod.lr_at(c, 60)) == pytest.approx(0.005, rel=1e-3)
    assert float(opt_mod.lr_at(c, 110)) == pytest.approx(0.0, abs=1e-9)


def test_adamw_converges_quadratic():
    c = opt_mod.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=300,
                            weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([[5.0, -3.0]])}
    state = opt_mod.init(c, params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, m = opt_mod.update(c, g, state, params)
    assert float(loss(params)) < 1e-3


def test_adamw_bf16_moments():
    c = opt_mod.AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = opt_mod.init(c, params)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    p2, s2, _ = opt_mod.update(c, g, state, params)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_data_pipeline_shapes_and_labels():
    dc = DataConfig(vocab=512, seq_len=128, batch=4, prompt_mean=10,
                    max_out=64, seed=0)
    for batch in batches(dc, 3):
        assert batch["tokens"].shape == (4, 128)
        # labels are next-token shifted where defined
        t, l = batch["tokens"], batch["labels"]
        for b in range(4):
            idx = np.where(l[b] >= 0)[0]
            assert len(idx) > 0
            np.testing.assert_array_equal(l[b, idx], t[b, idx + 1])
        # remaining counts decrease by 1 along the response
        r = batch["remaining"]
        for b in range(4):
            idx = np.where(r[b] >= 0)[0]
            diffs = np.diff(r[b, idx])
            assert np.all(diffs == -1)
            assert r[b, idx[-1]] == 0


def test_topic_determines_length_regime():
    dc = DataConfig(seed=1)
    assert topic_median_len(0, dc) < topic_median_len(7, dc)
    rng = np.random.default_rng(0)
    lens = {0: [], 7: []}
    for _ in range(300):
        topic, _, resp = sample_example(rng, dc)
        if topic in lens:
            lens[topic].append(len(resp))
    assert np.mean(lens[0]) < np.mean(lens[7])


def test_checkpoint_roundtrip_nested():
    tree = {"a": jnp.ones((3,), jnp.bfloat16),
            "layers": ({"w": jnp.arange(6.).reshape(2, 3)},
                       {"w": jnp.zeros((1,))}),
            "step": jnp.asarray(7)}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "c.npz")
        save(p, tree)
        r = restore(p)
    assert isinstance(r["layers"], tuple)
    assert r["a"].dtype == jnp.bfloat16
    assert jax.tree.all(jax.tree.map(
        lambda x, y: jnp.allclose(jnp.asarray(x, jnp.float32),
                                  jnp.asarray(y, jnp.float32)), tree, r))


def test_lm_training_reduces_loss():
    cfg = get_smoke_config("trail-llama")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab_size, seq_len=64, batch=4,
                    prompt_mean=8, max_out=32, seed=0)
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    _, _, hist = train_lm(m, params, batches(dc, 40), ocfg, 40, log_every=39)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_probe_learns_signal():
    """The probe trained on real taps must beat the uniform-prior MAE."""
    cfg = get_smoke_config("trail-llama")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab_size, seq_len=96, batch=8,
                    prompt_mean=10, max_out=60, seed=3)
    taps, rem = harvest_probe_data(m, params, dc, 5)
    pc = cfg.probe
    pp, hist = train_probe(taps, rem, pc, cfg.d_model,
                           ProbeTrainConfig(epochs=5))
    assert hist[-1]["loss"] < hist[0]["loss"]
    mae = probe_mae(pp, taps, rem, pc)
    # uniform prediction MAE baseline
    from repro.core.bins import bin_means
    uni = float(np.mean(np.abs(np.mean(bin_means(pc)) - rem)))
    assert mae < uni
