"""The paper's Section 3.1 pipeline end-to-end at CPU scale:

  1. train a small LM on the synthetic instruction corpus (~100 steps);
  2. profile: harvest tap-layer embeddings + remaining-length labels;
  3. train the probe MLP (CE over 10 bins, AdamW + cosine — paper recipe);
  4. report MAE: refined probe vs raw probe vs prompt-only baseline.

    PYTHONPATH=src python examples/train_probe.py [--steps 150]
"""

import argparse

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.models.model import build_model
from repro.training import optimizer as opt_mod
from repro.training.data import DataConfig, batches, harvest_probe_data
from repro.training.train import (ProbeTrainConfig, probe_mae, train_lm,
                                  train_probe)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
args = ap.parse_args()

cfg = get_smoke_config("trail-llama")
model = build_model(cfg)
params = model.init(jax.random.key(0))

dc = DataConfig(vocab=cfg.vocab_size, seq_len=96, batch=8, prompt_mean=10,
                max_out=60, seed=0)
ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
print("== step 1: train the serving model ==")
params, _, hist = train_lm(model, params, batches(dc, args.steps), ocfg,
                           args.steps,
                           callback=lambda r: print(f"  step {r['step']:4d} "
                                                    f"loss {r['loss']:.3f}"))

print("== step 2: profile tap embeddings ==")
taps, rem = harvest_probe_data(
    model, params, DataConfig(vocab=cfg.vocab_size, seq_len=96, batch=8,
                              prompt_mean=10, max_out=60, seed=77), 8)
print(f"  harvested {taps.shape[0]} (embedding, remaining-length) pairs")

print("== step 3: train the probe (paper: AdamW, cosine, CE over bins) ==")
probe_params, phist = train_probe(
    taps, rem, cfg.probe, cfg.d_model, ProbeTrainConfig(epochs=8),
    log=lambda r: print(f"  epoch {r['epoch']:2d} loss {r['loss']:.3f} "
                        f"acc {r['acc']:.3f}"))

print("== step 4: evaluate ==")
mae = probe_mae(probe_params, taps, rem, cfg.probe)
from repro.core.bins import bin_means
uniform = float(np.mean(np.abs(np.mean(bin_means(cfg.probe)) - rem)))
print(f"  probe MAE      : {mae:.2f} tokens")
print(f"  uniform prior  : {uniform:.2f} tokens")
print(f"  improvement    : {uniform / mae:.2f}x")
