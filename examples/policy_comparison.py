"""Paper Figure 6 in miniature: the four serving systems across request
rates on a Llama-8B-class model under the v5e roofline cost model.

    PYTHONPATH=src python examples/policy_comparison.py [--rates 10 14 18]
"""

import argparse

from repro.config import get_config
from repro.serving.engine import run_policy
from repro.serving.predictors import OraclePredictor
from repro.serving.workload import WorkloadConfig, generate

ap = argparse.ArgumentParser()
ap.add_argument("--rates", type=float, nargs="+", default=[10.0, 14.0, 18.0])
ap.add_argument("--n", type=int, default=250)
args = ap.parse_args()

cfg = get_config("granite-3-8b")
print(f"arch: {cfg.name} ({cfg.param_count()/1e9:.1f}B params), "
      "cost model: TPU v5e")
header = f"{'rate':>5} | " + " | ".join(
    f"{s:>22}" for s in ("vllm-fcfs", "vllm-sjf-bert", "trail-bert", "trail"))
print(header)
print("-" * len(header))
for rate in args.rates:
    wc = WorkloadConfig(n_requests=args.n, request_rate=rate, seed=1,
                        vocab=cfg.vocab_size)
    reqs = generate(wc)
    cells = []
    for name, pol in (("vllm-fcfs", "fcfs"), ("vllm-sjf-bert", "sjf"),
                      ("trail-bert", "trail-bert"), ("trail", "trail")):
        pred = OraclePredictor(cfg.probe, seed=2, refine=(name == "trail"))
        r = run_policy(cfg, pol, reqs, c_limit=0.8, max_batch=16,
                       mode="sim", seed=2, predictor=pred).summary()
        cells.append(f"lat {r['mean_latency']:6.2f}s ttft {r['mean_ttft']:5.2f}s")
    print(f"{rate:5.1f} | " + " | ".join(f"{c:>22}" for c in cells))
print("(mean latency / mean TTFT; lower is better)")
