"""Quickstart: build a model, prefill a prompt, decode with the fused TRAIL
probe, and watch the refined remaining-length prediction evolve.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_smoke_config
from repro.core.bins import bin_means
from repro.core.smoothing import bayes_update, transition_matrix
from repro.models.model import build_model

cfg = get_smoke_config("trail-llama")
model = build_model(cfg)
params = model.init(jax.random.key(0))
print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
      f"probe tap=layer {cfg.probe.tap_layer}")

# --- prefill a batch of two prompts -----------------------------------------
B, P = 2, 12
prompts = jax.random.randint(jax.random.key(1), (B, P), 4, cfg.vocab_size)
cache = model.init_cache(B, max_len=64)
logits, cache, tap_sum, n_tok = model.prefill_chunk(params, cache, prompts)
print(f"prefill: cache lengths = {np.asarray(cache['lengths'])}")

# prompt-phase probe input: mean of prompt-token taps (paper Section 3.1)
from repro.core.predictor import apply_probe
tap_mean = tap_sum / n_tok[:, None]
q = jax.nn.softmax(apply_probe(params["probe"], tap_mean), -1)

# --- decode 8 tokens, refining the posterior each iteration ------------------
T = jnp.asarray(transition_matrix(cfg.probe), jnp.float32)
m = jnp.asarray(bin_means(cfg.probe), jnp.float32)
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
for step in range(8):
    logits, cache, tap, probe_logits = model.decode_step(params, cache, tok)
    p = jax.nn.softmax(probe_logits, -1)
    q = bayes_update(q, p, T)                       # Bayesian refinement
    pred_remaining = q @ m
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    print(f"step {step}: tokens={np.asarray(tok[:, 0])} "
          f"pred_remaining={np.round(np.asarray(pred_remaining), 1)}")

print("done — predictions refine every iteration at ~0.03% extra FLOPs")
