"""End-to-end serving driver (deliverable b): trains a small model, then
serves a batched Poisson workload REALLY running the model on CPU —
continuous batching, chunked prefill, SPRPT-limited-preemption scheduling,
and the fused embedding probe — comparing TRAIL against vLLM-style FCFS.

    PYTHONPATH=src python examples/serve_trail.py [--n 16]
"""

import argparse

import jax

from repro.config import get_smoke_config
from repro.models.model import build_model
from repro.serving.engine import run_policy
from repro.serving.predictors import ProbePredictor
from repro.serving.workload import WorkloadConfig, generate
from repro.training import optimizer as opt_mod
from repro.training.data import DataConfig, batches, harvest_probe_data
from repro.training.train import ProbeTrainConfig, train_lm, train_probe

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=16)
ap.add_argument("--rate", type=float, default=60.0)
ap.add_argument("--train-steps", type=int, default=60)
args = ap.parse_args()

cfg = get_smoke_config("trail-llama")
model = build_model(cfg)
params = model.init(jax.random.key(0))

print("== training the serving model briefly ==")
dc = DataConfig(vocab=cfg.vocab_size, seq_len=64, batch=8, prompt_mean=8,
                max_out=24, seed=0)
ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=5,
                           total_steps=args.train_steps)
params, _, _ = train_lm(model, params, batches(dc, args.train_steps), ocfg,
                        args.train_steps)

print("== training the probe on harvested embeddings ==")
taps, rem = harvest_probe_data(model, params, dc, 5)
probe_params, _ = train_probe(taps, rem, cfg.probe, cfg.d_model,
                              ProbeTrainConfig(epochs=5))
params = dict(params)
params["probe"] = probe_params

wc = WorkloadConfig(n_requests=args.n, request_rate=args.rate, seed=3,
                    vocab=cfg.vocab_size, prompt_mean=8.0, out_median=8.0,
                    max_out=24)
reqs = generate(wc)
print(f"== serving {args.n} requests (real decode on CPU) ==")
for pol in ("fcfs", "trail"):
    pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                          embed_table=params["embed"])
    s = run_policy(cfg, pol, reqs, max_batch=4, mode="real", model=model,
                   params=params, predictor=pred)
    r = s.summary()
    print(f"  {pol:6s}: mean_latency {r['mean_latency']*1e3:8.2f} ms "
          f"mean_ttft {r['mean_ttft']*1e3:7.2f} ms "
          f"preemptions {r['preemptions']:3d} "
          f"(simulated v5e clock; {r['iterations']} iterations)")
print("done — TRAIL ranks by refined predictions and limits preemption")
