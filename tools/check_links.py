#!/usr/bin/env python3
"""Markdown link checker for the repo docs (CI gate).

Verifies, for every ``[text](target)`` in the given markdown files:

* relative file targets exist (resolved against the file's directory);
* ``#anchor`` fragments resolve to a heading in the target file, using
  GitHub's slug rules (lowercase, strip punctuation, spaces -> hyphens);
* bare ``#anchor`` targets resolve within the same file.

External (``http(s)://``) links are skipped — CI has no network.

    python tools/check_links.py README.md docs/ARCHITECTURE.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->hyphens."""
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)       # linked headings
    h = h.lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    text = CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(path: Path) -> list[str]:
    errors = []
    text = CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        dest = path if not target else (path.parent / target).resolve()
        if not dest.exists():
            errors.append(f"{path}: broken link -> {m.group(1)}")
            continue
        if frag is not None:
            if dest.is_dir() or dest.suffix.lower() not in (".md", ".markdown"):
                continue            # can't anchor-check non-markdown
            if github_slug(frag) not in anchors_of(dest):
                errors.append(f"{path}: missing anchor -> {m.group(1)}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]")
        return 2
    errors = []
    for name in argv:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        errors.extend(check_file(p))
    for e in errors:
        print(e)
    if not errors:
        print(f"link-check OK ({len(argv)} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
