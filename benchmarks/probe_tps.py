"""Paper Table 1: probe inference time per sample (TPS).

The paper reports CPU and CUDA microseconds/sample for batch 512/1024/2048.
Here: real CPU wall-clock for the jit'd probe (the paper's CPU column
analogue) plus the fused probe+Bayes kernel in interpret mode (semantics
check; on-TPU timing is left to real hardware).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json, timed
from repro.config import ProbeConfig
from repro.core import predictor as probe_mod
from repro.core.smoothing import transition_matrix


def run(quick: bool = True):
    pc = ProbeConfig()            # paper probe: d=4096 -> 512 -> 10
    d = 4096
    params = probe_mod.init_probe(jax.random.key(0), d, pc)
    T = jnp.asarray(transition_matrix(pc), jnp.float32)
    apply = jax.jit(lambda p, x: probe_mod.apply_probe(p, x))
    results = {}
    for batch in (512, 1024, 2048):
        x = jax.random.normal(jax.random.key(1), (batch, d), jnp.float32)
        out, dt = timed(lambda: jax.block_until_ready(apply(params, x)),
                        iters=3 if quick else 10)
        us = dt / batch * 1e6
        results[f"cpu_b{batch}"] = us
        emit(f"table1.probe_tps_cpu_b{batch}", us, f"batch={batch}")
    # overhead vs an 8B serving model: probe params / model params
    probe_params = d * pc.hidden + pc.hidden * pc.num_bins
    frac = probe_params / 8e9
    results["flop_overhead_frac"] = frac
    emit("table1.probe_flop_overhead", frac,
         f"{frac:.5%} of an 8B model per token")
    save_json("probe_tps", results)
    return results


if __name__ == "__main__":
    run(quick=False)
