"""Tail curves: rank-aging x C-limit x rate-scale on the bundled trace.

BENCH_trace_replay.json shows the classic SRPT starvation tail: TRAIL
beats FCFS 1.9x on mean completion at rate-scale 24 while the
completion-*p99* ranking inverts toward FCFS — preemptive
shortest-work-first trades its extreme tail for the mean. This benchmark
sweeps the two tail knobs that un-invert it:

* ``age_boost`` / ``age_delay_s`` — hinge rank aging
  (``aged rank = rank - age_boost * max(waited - age_delay, 0)``):
  inside the grace window ordering stays pure SRPT (keeping the mean
  win), past it a request's rank falls linearly with waiting time so it
  eventually undercuts any finite rank and cannot starve.
* ``c_limit`` — the paper's limited-preemption dial; a *lower* C pins
  running requests sooner, protecting in-flight work.

The winning tail recipe also runs under ``kv_layout="paged"``: page
retention makes preemption nearly free (no discard-and-recompute), which
is the final lever that lets aggressive aging keep the 1.5x mean win.

In-script gates (the script exits non-zero if any fails):

1. **Determinism pin** — the tail headline cell runs twice and its
   metrics JSON must be byte-identical.
2. **Off-is-free** — every zero-knob cell must be byte-identical to the
   committed BENCH_trace_replay.json grid cell (the new knobs at their
   defaults change nothing).
3. **Tail gate** — at rate-scale 24 the tail cell's completion-p99 must
   be <= fcfs's p99 (un-inverted) while its mean completion stays
   >= 1.5x better than fcfs.

Writes ``experiments/results/tail_curves.json`` and ``BENCH_tail.json``.

    PYTHONPATH=src python -m benchmarks.tail_curves          # artifact
    PYTHONPATH=src python -m benchmarks.tail_curves --smoke  # CI
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import emit, save_json
from benchmarks.trace_replay import (HEADLINE_SCALE, HW, SEED, _cell_summary,
                                     _make_cfg)
from repro.metrics import (EventLog, check_invariants, ideal_service_times,
                           report_json, rollup)
from repro.serving.costmodel import CostModel
from repro.serving.engine import Engine, EngineConfig
from repro.traces import ReplayConfig, load_trace, replay, requests_from_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: The winning tail recipe (also `serve.py --tail`): aggressive hinge
#: aging after a ~20 s grace window, an early C-limit pin, and paged KV
#: so preemption keeps its pages instead of recomputing.
TAIL_RECIPE = dict(age_boost=3072.0, age_delay_s=20.5, c_limit=0.2,
                   kv_layout="paged")

#: (age_boost, age_delay_s) points for the contig sweep; 0 = aging off.
BOOSTS = ((0.0, 0.0), (3072.0, 20.5))
C_LIMITS = (0.8, 0.2)
RATE_SCALES = (16.0, 24.0)


def _run_cell(cfg, trace, policy: str, rate_scale: float,
              limit: int | None = None, **knobs) -> tuple[dict, str]:
    """Replay one cell with tail knobs; returns (report, json_bytes)."""
    rcfg = ReplayConfig(rate_scale=rate_scale, seed=SEED,
                        vocab=cfg.vocab_size, limit=limit)
    reqs = requests_from_trace(trace, rcfg)
    log = EventLog()
    eng = Engine(cfg, EngineConfig(policy=policy, hardware=HW, seed=SEED,
                                   **knobs), event_log=log)
    replay(eng, reqs)
    check_invariants(log)
    service = ideal_service_times(CostModel(cfg, HW), reqs)
    report = rollup(log, service_times=service)
    return report, report_json(report)


def _gate(ok: bool, name: str, detail: str) -> bool:
    emit(f"tail_curves.gate.{name}", 0.0, f"ok={ok};{detail}")
    if not ok:
        print(f"GATE FAIL [{name}]: {detail}")
    return ok


def run(smoke: bool = False):
    """Run the sweep + gates; returns the artifact dict (written to disk)."""
    cfg = _make_cfg()
    trace = load_trace("sample")
    limit = 60 if smoke else None
    scales = (16.0,) if smoke else RATE_SCALES

    results = {}

    def cell(key, policy, scale, **knobs):
        report, js = _run_cell(cfg, trace, policy, scale, limit=limit,
                               **knobs)
        row = _cell_summary(report)
        row["max_wait_s"] = report["counters"]["max_wait_s"]
        row["preemptions_per_request"] = \
            report["counters"]["preemptions_per_request"]
        results[key] = row
        emit(f"tail_curves.{key}", row["completion"]["mean"] * 1e6,
             f"p99={row['completion']['p99']:.2f};"
             f"max_wait={row['max_wait_s']:.2f};"
             f"finished={row['finished']}")
        return report, js

    # contig sweep: aging x C-limit x rate-scale under trail
    for scale in scales:
        for boost, delay in BOOSTS:
            for c in C_LIMITS:
                key = (f"scale={scale}.trail.boost={boost:g}"
                       f".c={c:g}.contig")
                cell(key, "trail", scale, age_boost=boost,
                     age_delay_s=delay, c_limit=c)
        # the tail recipe (paged) and the fcfs reference at each scale
        cell(f"scale={scale}.trail.tail", "trail", scale, **TAIL_RECIPE)
        cell(f"scale={scale}.fcfs", "fcfs", scale)

    ok = True

    # gate 1: determinism — tail headline cell twice, byte-identical
    h_scale = scales[-1]
    _, js1 = _run_cell(cfg, trace, "trail", h_scale, limit=limit,
                       **TAIL_RECIPE)
    _, js2 = _run_cell(cfg, trace, "trail", h_scale, limit=limit,
                       **TAIL_RECIPE)
    ok &= _gate(js1 == js2, "determinism", f"bit_identical={js1 == js2}")

    # gate 2: off-is-free — zero-knob cells byte-identical to the
    # committed BENCH_trace_replay.json grid (skipped in smoke: the
    # committed grid has no limit=60 cells to compare against)
    if not smoke:
        with open(os.path.join(ROOT, "BENCH_trace_replay.json")) as f:
            committed = json.load(f)["grid"]
        for scale in scales:
            for pol, knobs in (("trail", dict(age_boost=0.0, age_delay_s=0.0,
                                              c_limit=0.8)), ("fcfs", {})):
                report, _ = _run_cell(cfg, trace, pol, scale, **knobs)
                got = json.dumps(_cell_summary(report), sort_keys=True)
                want = json.dumps(committed[f"scale={scale}.{pol}"],
                                  sort_keys=True)
                ok &= _gate(got == want, f"off_is_free.{scale}.{pol}",
                            f"identical={got == want}")

    # gate 3: the tail cell un-inverts p99 while keeping the mean win.
    # Full runs only — the 60-request smoke slice never develops the
    # overload tail the gate is about; smoke still checks the mean win.
    tail = results[f"scale={h_scale}.trail.tail"]["completion"]
    fcfs = results[f"scale={h_scale}.fcfs"]["completion"]
    mean_ratio = fcfs["mean"] / tail["mean"]
    p99_ok = tail["p99"] <= fcfs["p99"]
    gate_ok = mean_ratio >= 1.0 if smoke else (p99_ok and mean_ratio >= 1.5)
    ok &= _gate(gate_ok, "tail",
                f"p99={tail['p99']:.3f}<=fcfs_p99={fcfs['p99']:.3f}:{p99_ok};"
                f"mean_ratio={mean_ratio:.4f};smoke={smoke}")

    headline = {
        "operating_point": f"bundled trace @ rate-scale {h_scale} "
                           f"({trace.mean_rate * h_scale:.2f} req/s), "
                           f"{HW.name}",
        "recipe": {k: v for k, v in TAIL_RECIPE.items()},
        "tail_mean": tail["mean"], "fcfs_mean": fcfs["mean"],
        "tail_vs_fcfs_mean": mean_ratio,
        "tail_p99": tail["p99"], "fcfs_p99": fcfs["p99"],
        "p99_uninverted": p99_ok,
        "gates_ok": bool(ok),
    }
    emit("tail_curves.headline", 0.0,
         f"mean={mean_ratio:.2f}x;p99_uninverted={p99_ok};gates_ok={ok}")

    payload = {
        "config": {"model": "granite-3-8b", "trace": "azure_llm_sample",
                   "hardware": HW.name, "seed": SEED,
                   "rate_scales": list(scales),
                   "boosts": [list(b) for b in BOOSTS],
                   "c_limits": list(C_LIMITS),
                   "tail_recipe": dict(TAIL_RECIPE)},
        "headline": headline,
        "grid": results,
    }
    if not smoke:
        save_json("tail_curves", results)
        with open(os.path.join(ROOT, "BENCH_tail.json"), "w") as f:
            json.dump(payload, f, indent=1)
    if not ok:
        raise SystemExit("tail_curves gates failed")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke: 60 requests @ scale 16, "
                         "no artifact rewrite, relaxed mean gate")
    args = ap.parse_args()
    out = run(smoke=args.smoke)
    print(json.dumps(out["headline"], indent=1))
