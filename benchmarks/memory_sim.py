"""Paper Appendix D (+ Lemma 1): M/G/1 SPRPT-LP — response time and memory
across arrival rates and C, simulation vs the closed form."""

from __future__ import annotations

from benchmarks.common import emit, save_json
from repro.core.queueing import MG1Config, mean_response
from repro.core.simulation import simulate


def run(quick: bool = True):
    n_jobs = 30000 if quick else 100000
    results = {}
    for pred in ("perfect", "exponential"):
        for lam in (0.5, 0.7, 0.85):
            for C in (0.2, 0.5, 0.8, 1.0):
                sim = simulate("sprpt-lp", lam, C=C, n_jobs=n_jobs,
                               prediction=pred, seed=7)
                th = mean_response(MG1Config(lam=lam, C=C, prediction=pred),
                                   n_xr=16 if quick else 32)
                key = f"{pred}.lam={lam}.C={C}"
                results[key] = {
                    "sim_mean_response": sim.mean_response,
                    "theory_mean_response": th,
                    "peak_memory": sim.peak_memory,
                    "mean_memory": sim.mean_memory,
                    "preemptions": sim.preemptions,
                }
                emit(f"appD.{key}", sim.mean_response * 1e6,
                     f"theory={th:.3f};ratio={sim.mean_response/th:.3f};"
                     f"peak_mem={sim.peak_memory:.2f};"
                     f"mean_mem={sim.mean_memory:.3f}")
    save_json("memory_sim", results)
    return results


if __name__ == "__main__":
    run(quick=False)
