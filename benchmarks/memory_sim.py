"""Memory studies: (a) paper Appendix D (+ Lemma 1) M/G/1 SPRPT-LP —
response time and memory across arrival rates and C, simulation vs the
closed form; (b) paged vs contiguous KV under the serving engine — at the
same ``mem_budget``, block-granular preemption (retain/evict/swap pages)
must beat whole-sequence discard-and-recompute on ``recomputed_tokens``.

    PYTHONPATH=src python -m benchmarks.memory_sim --quick          # (a)
    PYTHONPATH=src python -m benchmarks.memory_sim --quick --paged  # (b)
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, save_json
from repro.core.queueing import MG1Config, mean_response
from repro.core.simulation import simulate


def run(quick: bool = True):
    n_jobs = 30000 if quick else 100000
    results = {}
    for pred in ("perfect", "exponential"):
        for lam in (0.5, 0.7, 0.85):
            for C in (0.2, 0.5, 0.8, 1.0):
                sim = simulate("sprpt-lp", lam, C=C, n_jobs=n_jobs,
                               prediction=pred, seed=7)
                th = mean_response(MG1Config(lam=lam, C=C, prediction=pred),
                                   n_xr=16 if quick else 32)
                key = f"{pred}.lam={lam}.C={C}"
                results[key] = {
                    "sim_mean_response": sim.mean_response,
                    "theory_mean_response": th,
                    "peak_memory": sim.peak_memory,
                    "mean_memory": sim.mean_memory,
                    "preemptions": sim.preemptions,
                }
                emit(f"appD.{key}", sim.mean_response * 1e6,
                     f"theory={th:.3f};ratio={sim.mean_response/th:.3f};"
                     f"peak_mem={sim.peak_memory:.2f};"
                     f"mean_mem={sim.mean_memory:.3f}")
    save_json("memory_sim", results)
    return results


def run_paged(quick: bool = True, page_size: int = 16):
    """Engine-level paged-vs-contiguous comparison at equal mem_budget.

    Uses a paper-scale dense GQA config (pure global attention, so paged
    preemption retains pages) under SPRPT-LP at a load that forces
    preemptions both by rank and by memory pressure.
    """
    from repro.config import get_config
    from repro.serving.engine import run_policy
    from repro.serving.kv_cache import bytes_for_context
    from repro.serving.workload import WorkloadConfig, generate

    cfg = get_config("granite-3-8b")
    n = 100 if quick else 300
    wc = WorkloadConfig(n_requests=n, request_rate=20.0, seed=4,
                        vocab=cfg.vocab_size)
    reqs = generate(wc)
    results = {}
    budgets = {"slack": 1 << 62,
               "tight": 10 * bytes_for_context(cfg, 256)}
    for bname, budget in budgets.items():
        for layout in ("contig", "paged"):
            for oom in ("discard", "swap"):
                s = run_policy(cfg, "trail", reqs, mode="sim", seed=5,
                               mem_budget=budget, max_batch=16,
                               oom_mode=oom, kv_layout=layout,
                               page_size=page_size)
                d = s.summary()
                key = f"{bname}.{layout}.{oom}"
                results[key] = {
                    "finished": len(s.latencies),
                    "preemptions": s.n_preemptions,
                    "recomputed_tokens": s.recomputed_tokens,
                    "swapped_gb": d["swapped_gb"],
                    "peak_mem_gb": d["peak_mem_gb"],
                    "mean_latency": d["mean_latency"],
                }
                emit(f"paged_kv.{key}", d["mean_latency"] * 1e6,
                     f"preempt={s.n_preemptions};"
                     f"recomputed={s.recomputed_tokens};"
                     f"swapped_gb={d['swapped_gb']:.3f};"
                     f"peak_gb={d['peak_mem_gb']:.4f}")
        gain = (results[f"{bname}.contig.discard"]["recomputed_tokens"]
                - results[f"{bname}.paged.discard"]["recomputed_tokens"])
        emit(f"paged_kv.{bname}.recompute_saved_tokens", float(gain))
    save_json("memory_sim_paged", results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small job counts (CI smoke)")
    ap.add_argument("--paged", action="store_true",
                    help="run the paged-vs-contiguous engine comparison")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()
    if args.paged:
        run_paged(quick=args.quick, page_size=args.page_size)
    else:
        run(quick=args.quick)
