"""Beyond-paper extensions (paper Section 6 'future research directions'):

  * probe-interval throttling — compute the embedding prediction every k-th
    token instead of every token ("A potential optimization is to compute
    embedding predictions at specific intervals"). Sweep k and show the
    latency cost of stale predictions vs the k× probe-cost saving.
  * logarithmic bins — "experimenting with logarithmic bin sizes for the
    linear classifier could offer further benefits": compare remaining-length
    MAE of equal-width vs log-width bins on harvested embeddings.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.config import get_config, get_smoke_config
from repro.core import predictor as probe_mod
from repro.core.bins import bin_index, bin_index_log, log_bin_edges, bin_means
from repro.serving.engine import run_policy
from repro.serving.workload import WorkloadConfig, generate
from repro.training import optimizer as opt_mod
from repro.training.data import DataConfig, batches, harvest_probe_data
from repro.training.train import ProbeTrainConfig, train_lm, train_probe


def probe_interval_sweep(quick: bool = True):
    cfg = get_config("granite-3-8b")
    n = 200 if quick else 600
    wc = WorkloadConfig(n_requests=n, request_rate=14.0, seed=11,
                        vocab=cfg.vocab_size)
    reqs = generate(wc)
    results = {}
    for k in (1, 2, 4, 8, 16):
        s = run_policy(cfg, "trail", reqs, mode="sim", seed=12,
                       probe_interval=k)
        r = s.summary()
        results[k] = r
        emit(f"ext.probe_interval.k={k}", r["mean_latency"] * 1e6,
             f"mean_ttft={r['mean_ttft']:.3f};probe_cost=1/{k}")
    base = results[1]["mean_latency"]
    worst = max(r["mean_latency"] for r in results.values())
    emit("ext.probe_interval.headline", 0.0,
         f"latency_spread={(worst/base-1)*100:.1f}% across k=1..16 "
         f"(probe cost cut up to 16x)")
    save_json("probe_interval", {str(k): v for k, v in results.items()})
    return results


def log_bins_compare(quick: bool = True):
    cfg = get_smoke_config("trail-llama")
    model_cfg = dataclasses.replace(cfg, num_layers=4, layer_kinds=())
    from repro.models.model import Model
    model = Model(model_cfg)
    params = model.init(jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab_size, seq_len=96, batch=8,
                    prompt_mean=10, max_out=60, seed=21)
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=60)
    params, _, _ = train_lm(model, params, batches(dc, 60), ocfg, 60)
    taps, rem = harvest_probe_data(model, params, dc, 5)
    pc = model_cfg.probe
    epochs = 5 if quick else 12

    import jax.numpy as jnp
    results = {}
    for name, idx_fn, means in (
            ("equal", bin_index, bin_means(pc)),
            ("log", bin_index_log,
             (log_bin_edges(pc)[:-1] + log_bin_edges(pc)[1:]) / 2.0)):
        labels = np.asarray(idx_fn(rem, pc))
        pp = probe_mod.init_probe(jax.random.key(1), model_cfg.d_model, pc)
        # reuse the trainer but with custom labels: quick inline CE loop
        from repro.training.train import train_probe as _tp
        # train_probe re-derives labels from remaining; train manually:
        tc = ProbeTrainConfig(epochs=epochs)
        o = opt_mod.AdamWConfig(lr=tc.lr, warmup_steps=0,
                                total_steps=epochs * (len(rem) // tc.batch),
                                clip_norm=0.0)
        ostate = opt_mod.init(o, pp)

        @jax.jit
        def step(p, s, x, y):
            loss, g = jax.value_and_grad(probe_mod.probe_loss)(p, x, y)
            p, s, _ = opt_mod.update(o, g, s, p)
            return p, s, loss

        rng = np.random.default_rng(0)
        for _ in range(epochs):
            perm = rng.permutation(len(rem))
            for i in range(len(rem) // tc.batch):
                sel = perm[i * tc.batch:(i + 1) * tc.batch]
                pp, ostate, _ = step(pp, ostate, jnp.asarray(taps[sel]),
                                     jnp.asarray(labels[sel]))
        probs = np.asarray(jax.nn.softmax(
            probe_mod.apply_probe(pp, jnp.asarray(taps)), -1))
        pred = probs @ np.asarray(means)
        mae = float(np.mean(np.abs(pred - rem)))
        results[name] = mae
        emit(f"ext.bins.{name}", 0.0, f"mae={mae:.2f}")
    emit("ext.bins.headline", 0.0,
         f"log_over_equal={results['equal'] / results['log']:.2f}x "
         "(>1 means log bins better on this right-skewed workload)")
    save_json("log_bins", results)
    return results


def mlfq_and_oom_modes(quick: bool = True):
    """Two more baselines beyond the paper's four systems:
    * FastServe-style MLFQ (related work, prediction-free preemption);
    * swap-to-host OOM mode vs the paper's discard-and-recompute, under a
      tight KV budget where preemption cost dominates."""
    from repro.serving.kv_cache import bytes_for_context
    cfg = get_config("granite-3-8b")
    n = 200 if quick else 600
    wc = WorkloadConfig(n_requests=n, request_rate=14.0, seed=31,
                        vocab=cfg.vocab_size)
    reqs = generate(wc)
    budget = 10 * bytes_for_context(cfg, 320)
    results = {}
    for name, kw in (
            ("mlfq", dict(policy="mlfq")),
            ("trail-discard", dict(policy="trail", oom_mode="discard")),
            ("trail-swap", dict(policy="trail", oom_mode="swap")),
            ("fcfs", dict(policy="fcfs"))):
        s = run_policy(cfg, kw.pop("policy"), reqs, mode="sim", seed=32,
                       max_batch=48, mem_budget=budget, **kw)
        r = s.summary()
        results[name] = r
        emit(f"ext.oom.{name}", r["mean_latency"] * 1e6,
             f"mean_ttft={r['mean_ttft']:.3f};preempt={r['preemptions']};"
             f"recompute={r['recomputed_tokens']};"
             f"swapped_gb={r['swapped_gb']:.2f}")
    save_json("oom_modes", results)
    return results


def run(quick: bool = True):
    probe_interval_sweep(quick)
    log_bins_compare(quick)
    mlfq_and_oom_modes(quick)


if __name__ == "__main__":
    run(quick=False)
