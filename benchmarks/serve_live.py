"""Closed-loop serving benchmark: trail vs fcfs under rising concurrency.

The front-door counterpart of ``trace_replay.py``: instead of replaying
a fixed open-loop arrival tape, pools of think-time users
(`repro.clients`) drive a single engine closed-loop — each user waits
for their stream to finish before thinking up the next request — so the
offered load self-throttles with latency, the regime an online server
actually lives in. Policy x concurrency cells report user-perceived
completion / TTFT / TBT percentiles and goodput; admission-watermark
cells add the 429/shed backpressure path with client retries.

What it shows: closed loops *compress* the policy gap at low
concurrency (users can't pile up work they are still waiting on) and
reopen it as the pool grows — at the headline concurrency TRAIL's
predicted-SRPT ordering beats FCFS on mean completion while FCFS keeps
its no-preemption p99 edge, the same inversion the open-loop trace
shows.

In-script gates (any failure refuses to write artifacts):

1. **off-is-free** — the committed ``BENCH_trace_replay.json`` headline
   cells must be byte-identical when re-run on this engine, and a run
   with a no-op ``on_token`` subscriber on every request must match a
   subscriber-free run byte-for-byte (the new streaming hooks cost
   nothing when unused and change nothing when used).
2. **determinism** — the headline closed-loop cell runs twice and must
   produce byte-identical summaries (the virtual-time path is exact).
3. **termination** — every issued logical request ends in exactly one
   terminal outcome (``finish`` xor ``lost``), counts reconcile, and
   the event log passes ``check_invariants``.
4. **policy gate** — trail strictly beats fcfs on mean completion at
   the headline concurrency.
5. **watermark gate** — shed events appear only above the admission
   watermark: zero at the low-concurrency admission cell (and in every
   watermark-free cell), nonzero at the headline admission cell.

Writes ``experiments/results/serve_live.json`` and the headline
``BENCH_serve_live.json``.

    PYTHONPATH=src python -m benchmarks.serve_live --quick
    PYTHONPATH=src python -m benchmarks.serve_live --smoke   # CI
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import emit, save_json
from benchmarks.trace_replay import (HEADLINE_SCALE, HW, SEED, _cell_summary,
                                     _make_cfg, _run_cell)
from repro.clients import ClientPoolConfig, run_closed_loop
from repro.metrics import EventLog, check_invariants
from repro.serving.engine import Engine, EngineConfig
from repro.traces import load_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

POLICIES = ("trail", "fcfs")
#: Pool sizes bracketing the knee: 8 users barely queue, 96 saturate
#: the decode batch on this hardware (tpu-v5e, granite-3-8b).
CONCURRENCIES = (8, 32, 96)
HEADLINE_CLIENTS = 96
#: Predicted-token admission watermark for the backpressure cells —
#: far above the 8-user backlog, far below the 96-user peak.
WATERMARK = 3000.0
THINK_S = 2.0
REQUESTS_PER_CLIENT = 4


def _pool_cfg(n_clients: int, rpc: int, **kw) -> ClientPoolConfig:
    """The benchmark's pool shape at ``n_clients`` users."""
    return ClientPoolConfig(n_clients=n_clients, requests_per_client=rpc,
                            think_time_s=THINK_S, seed=SEED, **kw)


def _run_pool_cell(cfg, policy: str, pool: ClientPoolConfig,
                   watermark: float = 0.0) -> tuple[dict, object]:
    """One closed-loop cell; returns (summary dict, engine stats)."""
    log = EventLog()
    eng = Engine(cfg, EngineConfig(policy=policy, hardware=HW, seed=SEED,
                                   shed_watermark=watermark,
                                   admission_control=watermark > 0),
                 event_log=log)
    stats = run_closed_loop(eng, pool)
    check_invariants(log)
    summary = stats.summary()
    # gate 3: termination — exactly one terminal outcome per request
    expected = pool.n_clients * pool.requests_per_client
    bad = [r for r in stats.records if r.outcome not in ("finish", "lost")]
    if bad or summary["issued"] != expected:
        raise SystemExit(
            f"termination violated at {policy}/{pool.n_clients}: "
            f"{len(bad)} unterminated, issued {summary['issued']} != "
            f"{expected}")
    if summary["finished"] + summary["lost"] != summary["issued"]:
        raise SystemExit("outcome counts do not reconcile")
    summary["shed_events"] = eng.stats.n_shed
    if watermark == 0.0 and eng.stats.n_shed:
        raise SystemExit("shed events without an admission watermark")
    return summary, eng.stats


def _identity_gate(cfg, trace, cells, limit, committed) -> None:
    """Gate 1: streaming hooks leave trace-replay cells byte-identical.

    Each cell runs twice through the trace-replay pipeline: once plain,
    once with a no-op ``on_token`` subscriber attached to every
    submitted request (so the ``_notify`` dispatch actually runs). Both
    must match each other — and the committed artifact, when present —
    byte-for-byte.
    """
    from repro.metrics import ideal_service_times, rollup
    from repro.serving.costmodel import CostModel
    from repro.traces import ReplayConfig, replay, requests_from_trace
    for scale, pol in cells:
        base, _ = _run_cell(cfg, trace, pol, scale, limit=limit)
        log = EventLog()
        eng = Engine(cfg, EngineConfig(policy=pol, hardware=HW, seed=SEED),
                     event_log=log)
        submit = eng.submit

        def subscribe_submit(req, _s=submit, _e=eng):
            _s(req)
            _e.on_token(req.rid, lambda t, k, v: None)

        eng.submit = subscribe_submit
        rcfg = ReplayConfig(rate_scale=scale, seed=SEED,
                            vocab=cfg.vocab_size, limit=limit)
        reqs = requests_from_trace(trace, rcfg)
        replay(eng, reqs)
        check_invariants(log)
        service = ideal_service_times(CostModel(cfg, HW), reqs)
        sub_cell = _cell_summary(rollup(log, service_times=service))
        base_cell = _cell_summary(base)
        fresh = (json.dumps(base_cell, sort_keys=True)
                 == json.dumps(sub_cell, sort_keys=True))
        vs_committed = True
        if committed is not None:
            vs_committed = (json.dumps(committed[f"scale={scale}.{pol}"],
                                       sort_keys=True)
                            == json.dumps(base_cell, sort_keys=True))
        emit(f"serve_live.identity.scale={scale}.{pol}", 0.0,
             f"fresh={fresh};committed={vs_committed}")
        if not (fresh and vs_committed):
            raise SystemExit(
                "off-is-free violated: on_token hooks changed a "
                f"trace-replay cell (scale={scale}, {pol}, "
                f"fresh={fresh}, committed={vs_committed})")


def run(quick: bool = True, smoke: bool = False):
    """Run the gated closed-loop sweep; returns the artifact payload."""
    cfg = _make_cfg()
    trace = load_trace("sample")
    results: dict = {}

    # -- gate 1: off-is-free ------------------------------------------
    if smoke:
        identity_cells, limit, committed = [(16.0, "trail")], 60, None
    else:
        identity_cells = [(HEADLINE_SCALE, "trail"),
                          (HEADLINE_SCALE, "fcfs")]
        limit = None
        bench_path = os.path.join(ROOT, "BENCH_trace_replay.json")
        committed = None
        if os.path.exists(bench_path):
            with open(bench_path) as f:
                committed = json.load(f)["grid"]
    _identity_gate(cfg, trace, identity_cells, limit, committed)

    # -- closed-loop policy x concurrency grid -------------------------
    concs = (8,) if smoke else CONCURRENCIES
    rpc = 2 if smoke else REQUESTS_PER_CLIENT
    headline_n = concs[-1]
    for n in concs:
        for pol in POLICIES:
            summary, _ = _run_pool_cell(cfg, pol, _pool_cfg(n, rpc))
            key = f"clients={n}.{pol}"
            results[key] = summary
            emit(f"serve_live.{key}", summary["completion_s"]["mean"] * 1e6,
                 f"goodput={summary['goodput_rps']};"
                 f"p99={summary['completion_s']['p99']}")

    # -- gate 2: virtual-time determinism ------------------------------
    again, _ = _run_pool_cell(cfg, "trail", _pool_cfg(headline_n, rpc))
    if (json.dumps(again, sort_keys=True)
            != json.dumps(results[f"clients={headline_n}.trail"],
                          sort_keys=True)):
        raise SystemExit("closed-loop headline cell is nondeterministic")

    # -- gate 4: trail beats fcfs at the headline concurrency ----------
    t_mean = results[f"clients={headline_n}.trail"]["completion_s"]["mean"]
    f_mean = results[f"clients={headline_n}.fcfs"]["completion_s"]["mean"]
    if not smoke and not t_mean < f_mean:
        raise SystemExit(f"policy gate violated: trail mean {t_mean} !< "
                         f"fcfs mean {f_mean} at {headline_n} clients")

    # -- gate 5: shed only above the watermark -------------------------
    admission = {}
    for n in (concs[0], headline_n) if not smoke else (concs[0],):
        summary, _ = _run_pool_cell(
            cfg, "trail", _pool_cfg(n, rpc, max_retries=2), WATERMARK)
        admission[f"clients={n}"] = summary
        emit(f"serve_live.admission.clients={n}", 0.0,
             f"shed={summary['shed_events']};lost={summary['lost']}")
    low = admission[f"clients={concs[0]}"]
    if low["shed_events"] != 0:
        raise SystemExit(f"watermark gate violated: {low['shed_events']} "
                         f"shed events below the watermark")
    if not smoke:
        high = admission[f"clients={headline_n}"]
        if high["shed_events"] == 0:
            raise SystemExit("watermark gate violated: overloaded "
                             "admission cell never shed")
    results["admission"] = admission

    headline = {
        "clients": headline_n,
        "trail_mean_completion_s": t_mean,
        "fcfs_mean_completion_s": f_mean,
        "speedup": round(f_mean / t_mean, 3) if t_mean else 0.0,
        "trail_goodput_rps":
            results[f"clients={headline_n}.trail"]["goodput_rps"],
    }
    payload = {
        "meta": {"model": "granite-3-8b", "hardware": "tpu-v5e",
                 "seed": SEED, "think_time_s": THINK_S,
                 "requests_per_client": rpc, "watermark": WATERMARK,
                 "concurrencies": list(concs)},
        "headline": headline,
        "grid": results,
    }
    if not smoke:
        save_json("serve_live", results)
        if quick:
            with open(os.path.join(ROOT, "BENCH_serve_live.json"),
                      "w") as f:
                json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="the checked-in artifact grid (the default)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke (no artifact rewrite)")
    args = ap.parse_args()
    out = run(quick=not args.smoke, smoke=args.smoke)
    print(json.dumps(out["headline"], indent=1))
