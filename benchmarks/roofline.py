"""Roofline table: reads experiments/dryrun/*.json (written by
repro.launch.dryrun) and prints the per-(arch x shape x mesh) three-term
roofline with the dominant bottleneck — EXPERIMENTS.md section Roofline."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, save_json

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def load_reports(d: str = DRYRUN_DIR) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run(quick: bool = True):
    reports = load_reports()
    ok = skipped = failed = 0
    rows = {}
    for rep in reports:
        tag = f"{rep['arch']}_{rep['shape']}_{rep['mesh']}"
        if "skipped" in rep:
            skipped += 1
            continue
        if "error" in rep:
            failed += 1
            emit(f"roofline.{tag}", 0.0, "ERROR")
            continue
        ok += 1
        r = rep["roofline"]
        rows[tag] = r
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline.{tag}", dom * 1e6,
             f"bottleneck={r['bottleneck']};compute={r['compute_s']:.2e};"
             f"memory={r['memory_s']:.2e};coll={r['collective_s']:.2e};"
             f"useful={r['useful_flops_ratio']:.2f};"
             f"hbm_gb={rep['memory']['peak_per_device_gb']:.2f}")
    emit("roofline.summary", 0.0, f"ok={ok};skipped={skipped};failed={failed}")
    save_json("roofline_table", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
