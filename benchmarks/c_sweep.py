"""Paper Figure 5: mean latency and TTFT across C (0.2/0.5/0.8/1.0) at
request rate 14, plus the memory axis that motivates limited preemption.

Run under a finite KV budget so preemption cost (discard-and-recompute) is
visible — the regime where the paper's C=0.8 beats C=1.
"""

from __future__ import annotations

from benchmarks.common import emit, save_json
from repro.config import get_config
from repro.serving.engine import run_policy
from repro.serving.kv_cache import bytes_for_context
from repro.serving.workload import WorkloadConfig, generate


def run(quick: bool = True):
    cfg = get_config("granite-3-8b")
    n = 200 if quick else 600
    wc = WorkloadConfig(n_requests=n, request_rate=14.0, seed=1,
                        vocab=cfg.vocab_size)
    reqs = generate(wc)
    # tight budget: preemption's discard-and-recompute cost must bite for
    # the paper's "limit preemption" effect (Fig 5) to be visible
    budget = 10 * bytes_for_context(cfg, 320)
    results = {}
    for c in (0.2, 0.5, 0.8, 1.0):
        s = run_policy(cfg, "trail", reqs, c_limit=c, max_batch=48,
                       mem_budget=budget, mode="sim", seed=2)
        r = s.summary()
        results[c] = r
        emit(f"fig5.c={c}", r["mean_latency"] * 1e6,
             f"mean_ttft={r['mean_ttft']:.3f};preempt={r['preemptions']};"
             f"recompute={r['recomputed_tokens']}")
    save_json("c_sweep", results)
    return results


if __name__ == "__main__":
    run(quick=False)
