"""Render the §Dry-run and §Roofline markdown tables into EXPERIMENTS.md
from experiments/dryrun/*.json. Run after both dry-run sweeps."""

from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "experiments", "dryrun")


def fmt(v, unit=""):
    return f"{v:.3g}{unit}"


def load(mesh):
    out = []
    for f in sorted(glob.glob(os.path.join(DRY, f"*_{mesh}.json"))):
        out.append(json.load(open(f)))
    return out


def dryrun_table():
    lines = ["| arch × shape | mesh | HBM GB/dev | lower s | compile s | status |",
             "|---|---|---|---|---|---|"]
    for mesh in ("16x16", "2x16x16"):
        for r in load(mesh):
            tag = f"{r['arch']} × {r['shape']}"
            if "skipped" in r:
                lines.append(f"| {tag} | {mesh} | — | — | — | skip (long_500k "
                             "rule) |")
            elif "error" in r:
                lines.append(f"| {tag} | {mesh} | — | — | — | ERROR |")
            else:
                lines.append(
                    f"| {tag} | {mesh} | "
                    f"{r['memory']['peak_per_device_gb']:.2f} | "
                    f"{r['lower_s']} | {r['compile_s']} | ok |")
    return "\n".join(lines)


def roofline_table():
    lines = ["| arch × shape | compute s | memory s | collective s | "
             "bottleneck | MODEL_FLOPS | useful | note |",
             "|---|---|---|---|---|---|---|---|"]
    notes = {
        ("qwen1.5-32b", "decode_32k"): "cache 21.5 GB/dev: > v5e HBM (§Perf C)",
        ("arctic-480b", "train_4k"): "FSDP-bandwidth-bound (§Perf A3)",
    }
    for r in load("16x16"):
        if "skipped" in r or "error" in r:
            continue
        rl = r["roofline"]
        note = notes.get((r["arch"], r["shape"]), "")
        lines.append(
            f"| {r['arch']} × {r['shape']} | {rl['compute_s']:.2e} | "
            f"{rl['memory_s']:.2e} | {rl['collective_s']:.2e} | "
            f"**{rl['bottleneck']}** | {rl['model_flops']:.2e} | "
            f"{rl['useful_flops_ratio']:.2f} | {note} |")
    lines.append("")
    lines.append(REMEDIES)
    return "\n".join(lines)


REMEDIES = """\
**What would move each dominant term down** (per case class; `useful` > 1
reflects the while-loop undercount in raw HLO flops — DESIGN.md §9b —
while < 1 flags remat/dispatch overhead):

* *collective-bound train/prefill (all dense + MoE archs)*: FSDP per-layer
  weight all-gather + f32 grad all-reduce dominates — remedies in order of
  leverage: (1) reduce-scatter grads instead of all-reduce (GSPMD emits the
  2×-worse form here; §Perf A3), (2) larger global batch amortizes weight
  traffic linearly, (3) overlap gathers with the previous layer's compute
  (XLA latency-hiding scheduler on real TPU), (4) bf16 grads with f32
  accumulation halves reduce bytes.
* *collective-bound MoE (arctic/olmoe)*: above plus the token<->expert
  all-to-all; shard-local dispatch already applied (§Perf A1); the next
  step is a shard_map hand-written a2a that skips GSPMD's resharding pair.
* *collective-bound decode (gemma3/hymba/mamba2/paligemma/gemma2)*: small
  absolute terms (ms); dominated by TP all-reduces of per-layer outputs —
  fuse QKV+O projections per block or widen to per-arch TP degree < 16.
* *memory-bound decode (qwen/olmoe/granite w/ int8)*: cache-resident floor;
  int8 KV (§Perf C2) halves it, further wins need smaller batch shards or
  KV windowing.
* *memory-bound SSM train (mamba2 38 GB/dev)*: the chunked-SSD decay tensor
  (B,nc,Q,Q,nh) is the live set — recompute it in the backward (remat over
  the chunk loop) or drop Q to 64.
* *memory-bound long_500k (gemma2 21.7 GB/dev)*: global-layer KV at 500k,
  batch=1 prevents data sharding — int8 KV brings it under HBM; or ring
  attention over the pod axis.
* *multi-pod anomaly*: olmoe prefill/train regress at 2 pods (31/92 s
  collective vs 11/31 s single-pod): with 32-way batch shards the per-shard
  expert capacity drops below the load-balance floor and GSPMD re-gathers
  dispatch buffers across pods — fix is pod-local dispatch with a pod-level
  combine, left as the next iteration."""


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = re.sub(r"<!-- DRYRUN-TABLE -->", dryrun_table(), text)
    text = re.sub(r"<!-- ROOFLINE-TABLE -->", roofline_table(), text)
    open(path, "w").write(text)
    print("tables written")


if __name__ == "__main__":
    main()
