"""Predictor bake-off: strategy x quality x rate-scale on the bundled trace.

Replays the bundled Azure-LLM-inference-style sample trace through the
single-engine simulator at the paper's memory-bound TPU-v5e operating
point, sweeping the *length-prediction strategy*
(`repro.serving.predictors`) instead of the scheduling policy: the
analysis oracles (exact / noisy / bucketed), the BERT-style prompt-only
baseline, the paper's recycled-embedding trail-probe, the
learning-to-rank ordinal strategy (paired with the rank-aware scheduler
path), and the ELIS-style iterative re-predictor — each with its quality
dial — plus the three legacy policy cells (trail / fcfs / srpt with the
built-in probe) for cross-benchmark anchoring.

What it shows: scheduling gain is monotone in prediction quality — the
exact oracle upper-bounds every learned strategy, the noisy oracle
degrades smoothly with sigma, and k-bin bucketing recovers most of the
gain with tiny k (the paper's Sec. 4 claim that coarse bins suffice).
The trail-probe rides the decode megastep so its *predictor overhead is
exactly zero*, while the prompt-only and iterative baselines pay their
proxy FLOPs on the simulated clock (`CostModel.predictor_time`) — the
overhead column is the paper's core selling point made visible.

Two hard pins, enforced before any artifact is written:

* the legacy cells (empty predictor spec) must be *byte-identical* to
  the corresponding ``BENCH_trace_replay.json`` grid cells — the
  strategy layer must not perturb the pre-existing results;
* the exact oracle must strictly upper-bound the trail-probe on mean
  completion time at every swept rate-scale.

Writes ``experiments/results/pred_bakeoff.json`` and the headline
``BENCH_pred_bakeoff.json``.

    PYTHONPATH=src python -m benchmarks.pred_bakeoff --quick
    PYTHONPATH=src python -m benchmarks.pred_bakeoff --smoke   # CI
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import emit, save_json
from repro.metrics import (EventLog, check_invariants, ideal_service_times,
                           report_json, rollup)
from repro.metrics.emitters import METRIC_ROWS, SUMMARY_COLS
from repro.serving.costmodel import CostModel, HardwareSpec
from repro.serving.engine import Engine, EngineConfig
from repro.traces import ReplayConfig, load_trace, replay, requests_from_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Same operating point as benchmarks/trace_replay.py — the legacy cells
#: here must be byte-comparable against that benchmark's grid.
HW = HardwareSpec()
SEED = 0
HEADLINE_SCALE = 24.0

#: The bake-off grid: (cell label, predictor spec, scheduling policy).
#: An empty spec is the legacy path — engine-internal trail probe, used
#: for the byte-identity anchor against BENCH_trace_replay.json.
STRATEGY_GRID = (
    ("trail", "", "trail"),
    ("fcfs", "", "fcfs"),
    ("srpt", "", "srpt"),
    ("trail-probe", "trail-probe", "trail"),
    ("oracle", "oracle", "trail"),
    ("noisy-oracle:sigma=0.3", "noisy-oracle:sigma=0.3", "trail"),
    ("noisy-oracle:sigma=0.6", "noisy-oracle:sigma=0.6", "trail"),
    ("noisy-oracle:sigma=1.2", "noisy-oracle:sigma=1.2", "trail"),
    ("bucketed:bins=4", "bucketed:bins=4", "trail"),
    ("bucketed:bins=10", "bucketed:bins=10", "trail"),
    ("prompt-only", "prompt-only", "trail-bert"),
    ("rank-only", "rank-only", "rank"),
    ("rank-only:noise=0.5", "rank-only:noise=0.5", "rank"),
    ("iterative:period=4", "iterative:period=4", "trail"),
    ("iterative:period=16", "iterative:period=16", "trail"),
)
#: CI subset: the zero-cost anchor pair plus one costed strategy and the
#: ordinal path, so the smoke still exercises every engine code path.
SMOKE_LABELS = ("trail", "trail-probe", "oracle", "prompt-only", "rank-only")


def _make_cfg():
    from repro.config import get_config
    return get_config("granite-3-8b")


def _run_cell(cfg, trace, policy: str, predictor: str, rate_scale: float,
              limit: int | None = None) -> tuple[dict, str, dict]:
    """Replay one cell; returns (report, json_bytes, engine_summary)."""
    rcfg = ReplayConfig(rate_scale=rate_scale, seed=SEED,
                        vocab=cfg.vocab_size, limit=limit)
    reqs = requests_from_trace(trace, rcfg)
    log = EventLog()
    eng = Engine(cfg, EngineConfig(policy=policy, hardware=HW, seed=SEED,
                                   predictor=predictor),
                 event_log=log)
    stats = replay(eng, reqs)
    check_invariants(log)
    service = ideal_service_times(CostModel(cfg, HW), reqs)
    report = rollup(log, service_times=service)
    return report, report_json(report), stats.summary()


def _cell_summary(report: dict, engine_summary: dict) -> dict:
    """Per-cell artifact row: percentiles + SLOs + predictor overhead.

    The metric keys mirror benchmarks/trace_replay.py exactly so the
    legacy cells byte-compare; the predictor overhead keys are appended
    on top (and stripped again before the cross-benchmark comparison).
    """
    keep = {}
    for metric in METRIC_ROWS:
        s = report.get(metric)
        if s:
            keep[metric] = {k: s[k] for k in SUMMARY_COLS if k in s}
    keep["slo_attainment"] = report["slo_attainment"]
    keep["finished"] = report["requests"]["finished"]
    keep["preemptions"] = report["counters"]["preemptions"]
    keep["predictor_time_s"] = engine_summary["predictor_time_s"]
    keep["predictor_calls"] = engine_summary["predictor_calls"]
    return keep


OVERHEAD_KEYS = ("predictor_time_s", "predictor_calls")


def _check_legacy_identity(results: dict) -> dict:
    """Byte-compare the legacy cells against BENCH_trace_replay.json.

    Only keys present in both grids are compared (the full sweep visits
    rate-scales the trace-replay quick artifact doesn't). Comparison is
    on the canonical JSON bytes of the cell with the predictor-overhead
    keys stripped — those columns are new here by construction.
    """
    path = os.path.join(ROOT, "BENCH_trace_replay.json")
    if not os.path.exists(path):
        return {"compared": 0, "identical": None}
    with open(path) as f:
        anchor = json.load(f)["grid"]
    compared, mismatched = 0, []
    for key, cell in results.items():
        if key not in anchor:
            continue
        compared += 1
        stripped = {k: v for k, v in cell.items() if k not in OVERHEAD_KEYS}
        a = json.dumps(anchor[key], sort_keys=True)
        b = json.dumps(stripped, sort_keys=True)
        if a != b:
            mismatched.append(key)
    return {"compared": compared, "identical": not mismatched,
            "mismatched": mismatched}


def run(quick: bool = True, smoke: bool = False):
    """Run the sweep; returns the artifact dict (also written to disk)."""
    cfg = _make_cfg()
    trace = load_trace("sample")
    if smoke:
        rate_scales, limit = (16.0,), 60
        grid = tuple(c for c in STRATEGY_GRID if c[0] in SMOKE_LABELS)
    elif quick:
        rate_scales, limit, grid = (16.0, 24.0), None, STRATEGY_GRID
    else:
        rate_scales, limit, grid = (8.0, 16.0, 24.0, 32.0), None, STRATEGY_GRID

    results = {}
    for scale in rate_scales:
        for label, spec, pol in grid:
            report, _, es = _run_cell(cfg, trace, pol, spec, scale,
                                      limit=limit)
            cell = _cell_summary(report, es)
            key = f"scale={scale}.{label}" if spec else f"scale={scale}.{pol}"
            results[key] = cell
            emit(f"pred_bakeoff.{key}", cell["completion"]["mean"] * 1e6,
                 f"p99={cell['completion']['p99']:.2f};"
                 f"pred_s={cell['predictor_time_s']:.4f};"
                 f"calls={cell['predictor_calls']};"
                 f"finished={cell['finished']}")

    # determinism pin: one costed + one seeded-noise cell, run twice,
    # byte-identical JSON both times
    h_scale = rate_scales[-1] if HEADLINE_SCALE not in rate_scales \
        else HEADLINE_SCALE
    deterministic = True
    for spec, pol in (("noisy-oracle:sigma=0.6", "trail"),
                      ("iterative:period=4", "trail")):
        _, js1, _ = _run_cell(cfg, trace, pol, spec, h_scale, limit=limit)
        _, js2, _ = _run_cell(cfg, trace, pol, spec, h_scale, limit=limit)
        deterministic = deterministic and js1 == js2
    emit("pred_bakeoff.determinism", 0.0, f"bit_identical={deterministic}")

    # the strategy layer must not perturb pre-existing results; a
    # truncated smoke replay is not comparable to the full-trace anchor
    legacy = (_check_legacy_identity(results) if limit is None
              else {"compared": 0, "identical": None, "mismatched": []})
    emit("pred_bakeoff.legacy_identity", 0.0,
         f"compared={legacy['compared']};identical={legacy['identical']}")

    # quality dial: the exact oracle must upper-bound the trail-probe on
    # mean completion at every swept scale
    oracle_bound = {}
    for scale in rate_scales:
        orc = results.get(f"scale={scale}.oracle")
        prb = results.get(f"scale={scale}.trail-probe")
        if orc and prb:
            oracle_bound[f"scale={scale}"] = (
                orc["completion"]["mean"] < prb["completion"]["mean"])

    headline = None
    orc = results.get(f"scale={h_scale}.oracle")
    prb = results.get(f"scale={h_scale}.trail-probe")
    if orc and prb:
        pronly = results.get(f"scale={h_scale}.prompt-only")
        headline = {
            "operating_point": f"bundled trace @ rate-scale {h_scale} "
                               f"({trace.mean_rate * h_scale:.2f} req/s), "
                               f"{HW.name}",
            "oracle_mean": orc["completion"]["mean"],
            "trail_probe_mean": prb["completion"]["mean"],
            "oracle_vs_trail_probe_mean": (prb["completion"]["mean"]
                                           / orc["completion"]["mean"]),
            "trail_probe_overhead_s": prb["predictor_time_s"],
            "prompt_only_overhead_s": (pronly or {}).get("predictor_time_s"),
            "oracle_upper_bounds_probe": all(oracle_bound.values()),
            "legacy_cells_identical": legacy["identical"],
            "replay_bit_identical": deterministic,
        }
        emit("pred_bakeoff.headline", 0.0,
             f"oracle_vs_probe={headline['oracle_vs_trail_probe_mean']:.3f}x;"
             f"probe_overhead={headline['trail_probe_overhead_s']:.4f}s;"
             f"legacy_identical={legacy['identical']};"
             f"deterministic={deterministic}")

    if not deterministic:
        raise SystemExit("bake-off determinism violated: same trace + seed "
                         "produced different metrics JSON")
    if legacy["identical"] is False:
        raise SystemExit("legacy byte-identity violated: predictor layer "
                         f"perturbed cells {legacy['mismatched']}")
    if not smoke and oracle_bound and not all(oracle_bound.values()):
        raise SystemExit("oracle failed to upper-bound trail-probe on mean "
                         f"completion: {oracle_bound}")
    if not smoke:
        save_json("pred_bakeoff", results)
    payload = {
        "config": {"model": "granite-3-8b", "trace": "azure_llm_sample",
                   "trace_stats": trace.stats(), "hardware": HW.name,
                   "peak_flops": HW.peak_flops, "seed": SEED,
                   "rate_scales": list(rate_scales),
                   "strategies": [c[0] for c in grid]},
        "headline": headline,
        "oracle_upper_bounds_by_scale": oracle_bound,
        "grid": results,
    }
    if quick and not smoke:
        # the checked-in artifact is the --quick grid (same convention
        # as BENCH_trace_replay.json: smoke never rewrites it)
        with open(os.path.join(ROOT, "BENCH_pred_bakeoff.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="2 rate scales x 15 strategy cells (the "
                         "checked-in artifact; the default)")
    ap.add_argument("--full", action="store_true",
                    help="4 rate scales x 15 strategy cells (does not "
                         "refresh the checked-in BENCH artifact)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke (no artifact rewrite)")
    args = ap.parse_args()
    out = run(quick=not (args.full or args.smoke), smoke=args.smoke)
    if out["headline"]:
        print(json.dumps(out["headline"], indent=1))
