"""Paper Figure 7: burst scenario — every request arrives at t=0.

The paper's observation: TRAIL still wins (it ranks running+waiting by
predicted remaining length) but preemption stops mattering, so C=0.8 and
C=1 coincide."""

from __future__ import annotations

from benchmarks.common import emit, save_json
from repro.config import get_config
from repro.serving.engine import run_policy
from repro.serving.workload import WorkloadConfig, generate


def run(quick: bool = True):
    cfg = get_config("granite-3-8b")
    n = 150 if quick else 400
    wc = WorkloadConfig(n_requests=n, request_rate=1.0, burst=True, seed=5,
                        vocab=cfg.vocab_size)
    reqs = generate(wc)
    systems = [("vllm-fcfs", "fcfs", 0.8), ("vllm-sjf-bert", "sjf", 0.8),
               ("trail-c0.8", "trail", 0.8), ("trail-c1.0", "trail", 1.0)]
    results = {}
    for name, pol, c in systems:
        s = run_policy(cfg, pol, reqs, c_limit=c, max_batch=16,
                       mode="sim", seed=6)
        r = s.summary()
        results[name] = r
        emit(f"fig7.{name}", r["mean_latency"] * 1e6,
             f"med_lat={r['median_latency']:.3f};"
             f"mean_ttft={r['mean_ttft']:.3f};preempt={r['preemptions']}")
    same = abs(results["trail-c0.8"]["mean_latency"]
               - results["trail-c1.0"]["mean_latency"])
    rel = same / max(results["trail-c1.0"]["mean_latency"], 1e-9)
    emit("fig7.c08_vs_c10_gap", 0.0,
         f"relative_gap={rel:.3f} (paper: ~0 under burst)")
    save_json("burst", results)
    return results


if __name__ == "__main__":
    run(quick=False)
