"""Trace-driven policy evaluation: policy x rate-scale on the bundled trace.

Replays the bundled Azure-LLM-inference-style sample trace (three
tenants with *correlated* prompt/output lengths — chat long-begets-long,
RAG long-prompt/short-output) through the single-engine simulator at the
paper's memory-bound TPU-v5e operating point, sweeping scheduling policy
(trail / fcfs / srpt) x arrival rate-scale. Unlike the synthetic
-scenario benchmarks, every cell reports the full distributional picture
from the metrics layer: TTFT / TBT / completion-time p50/p90/p99,
slowdown, and SLO-attainment curves.

What it shows (the effect the metrics layer exists to observe — cf.
"Efficient LLM Scheduling by Learning to Rank", whose policy rankings
invert between mean and p99): on this correlated trace TRAIL beats FCFS
~1.9x on mean and ~9x on median completion time and edges out pure SRPT,
while the completion-time *p99* ranking inverts — FCFS's no-preemption
discipline protects the extreme tail that SRPT-style policies trade for
the mean. A mean-only benchmark would call this a uniform TRAIL win; the
percentile/SLO report shows where it is and isn't.

Also pins the replay-determinism guarantee: the headline cell runs
twice and its metrics JSON must be byte-identical.

Writes ``experiments/results/trace_replay.json`` and the headline
``BENCH_trace_replay.json``.

    PYTHONPATH=src python -m benchmarks.trace_replay --quick
    PYTHONPATH=src python -m benchmarks.trace_replay --smoke   # CI
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import emit, save_json   # shared with cluster/prefix
from repro.metrics import (EventLog, check_invariants, ideal_service_times,
                           report_json, rollup)
from repro.metrics.emitters import METRIC_ROWS, SUMMARY_COLS
from repro.serving.costmodel import CostModel, HardwareSpec
from repro.serving.engine import Engine, EngineConfig
from repro.traces import (ReplayConfig, load_trace, replay,
                          requests_from_trace)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: The paper's testbed regime: memory-bound decode on one TPU-v5e chip.
#: (The compute-bound 2 TFLOP/s point of cluster_curves.py is wrong for
#: *policy* comparison — there prefill compute dominates, so every
#: preemption's discard-and-recompute overwhelms the SRPT ordering win
#: and FCFS leads uniformly.)
HW = HardwareSpec()

POLICIES = ("trail", "fcfs", "srpt")
SEED = 0
#: Native trace rate is ~0.5 req/s; x16/x24 land at 8 and 12 req/s,
#: bracketing the paper's Figure-5 operating range on this hardware.
HEADLINE_SCALE = 24.0


def _make_cfg():
    from repro.config import get_config
    return get_config("granite-3-8b")


def _run_cell(cfg, trace, policy: str, rate_scale: float,
              limit: int | None = None) -> tuple[dict, str]:
    """Replay one (policy, rate-scale) cell; returns (report, json_bytes)."""
    rcfg = ReplayConfig(rate_scale=rate_scale, seed=SEED,
                        vocab=cfg.vocab_size, limit=limit)
    reqs = requests_from_trace(trace, rcfg)
    log = EventLog()
    eng = Engine(cfg, EngineConfig(policy=policy, hardware=HW, seed=SEED),
                 event_log=log)
    replay(eng, reqs)
    check_invariants(log)
    service = ideal_service_times(CostModel(cfg, HW), reqs)
    report = rollup(log, service_times=service)
    return report, report_json(report)


def _cell_summary(report: dict) -> dict:
    """The compact per-cell artifact row (full percentiles + SLOs)."""
    keep = {}
    for metric in METRIC_ROWS:
        s = report.get(metric)
        if s:
            keep[metric] = {k: s[k] for k in SUMMARY_COLS if k in s}
    keep["slo_attainment"] = report["slo_attainment"]
    keep["finished"] = report["requests"]["finished"]
    keep["preemptions"] = report["counters"]["preemptions"]
    return keep


def run(quick: bool = True, smoke: bool = False):
    """Run the sweep; returns the artifact dict (also written to disk)."""
    cfg = _make_cfg()
    trace = load_trace("sample")
    if smoke:
        rate_scales, policies, limit = (16.0,), ("trail", "fcfs"), 60
    elif quick:
        rate_scales, policies, limit = (16.0, 24.0), POLICIES, None
    else:
        rate_scales, policies, limit = (8.0, 16.0, 24.0, 32.0), POLICIES, None

    results = {}
    for scale in rate_scales:
        for pol in policies:
            report, _ = _run_cell(cfg, trace, pol, scale, limit=limit)
            cell = _cell_summary(report)
            key = f"scale={scale}.{pol}"
            results[key] = cell
            emit(f"trace_replay.{key}", cell["completion"]["mean"] * 1e6,
                 f"p99={cell['completion']['p99']:.2f};"
                 f"ttft_p99={cell['ttft']['p99']:.2f};"
                 f"tbt_p99={cell['tbt']['p99']:.3f};"
                 f"finished={cell['finished']}")

    # determinism pin: the headline cell twice, byte-identical JSON
    h_scale = rate_scales[-1] if HEADLINE_SCALE not in rate_scales \
        else HEADLINE_SCALE
    _, js1 = _run_cell(cfg, trace, "trail", h_scale, limit=limit)
    _, js2 = _run_cell(cfg, trace, "trail", h_scale, limit=limit)
    deterministic = js1 == js2
    emit("trace_replay.determinism", 0.0, f"bit_identical={deterministic}")

    headline = None
    trail = results.get(f"scale={h_scale}.trail")
    fcfs = results.get(f"scale={h_scale}.fcfs")
    if trail and fcfs:
        headline = {
            "operating_point": f"bundled trace @ rate-scale {h_scale} "
                               f"({trace.mean_rate * h_scale:.2f} req/s), "
                               f"{HW.name}",
            "trail_mean": trail["completion"]["mean"],
            "fcfs_mean": fcfs["completion"]["mean"],
            "trail_vs_fcfs_mean": (fcfs["completion"]["mean"]
                                   / trail["completion"]["mean"]),
            "trail_vs_fcfs_p50": (fcfs["completion"]["p50"]
                                  / trail["completion"]["p50"]),
            "trail_p99": trail["completion"]["p99"],
            "fcfs_p99": fcfs["completion"]["p99"],
            "trail_vs_fcfs_p99": (fcfs["completion"]["p99"]
                                  / trail["completion"]["p99"]),
            # the observable the metrics layer was built for: does the
            # mean-vs-p99 policy ranking invert on this trace?
            "mean_tail_ranking_inverts": (
                fcfs["completion"]["mean"] > trail["completion"]["mean"]
                and fcfs["completion"]["p99"] < trail["completion"]["p99"]),
            "replay_bit_identical": deterministic,
        }
        emit("trace_replay.headline", 0.0,
             f"mean={headline['trail_vs_fcfs_mean']:.2f}x;"
             f"p50={headline['trail_vs_fcfs_p50']:.2f}x;"
             f"p99={headline['trail_vs_fcfs_p99']:.2f}x;"
             f"deterministic={deterministic}")

    if not deterministic:
        # refuse to write any artifact from a known-nondeterministic run
        raise SystemExit("replay determinism violated: same trace + seed "
                         "produced different metrics JSON")
    if not smoke:
        # smoke never rewrites the checked-in experiments artifact either
        save_json("trace_replay", results)
    payload = {
        "config": {"model": "granite-3-8b", "trace": "azure_llm_sample",
                   "trace_stats": trace.stats(), "hardware": HW.name,
                   "peak_flops": HW.peak_flops, "seed": SEED,
                   "rate_scales": list(rate_scales),
                   "policies": list(policies)},
        "headline": headline,
        "grid": results,
    }
    if quick and not smoke:
        # the checked-in artifact is the --quick grid (same convention
        # as BENCH_cluster.json: smoke never rewrites it)
        with open(os.path.join(ROOT, "BENCH_trace_replay.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="2 rate scales x 3 policies (the checked-in "
                         "artifact; the default)")
    ap.add_argument("--full", action="store_true",
                    help="4 rate scales x 3 policies (does not refresh "
                         "the checked-in BENCH artifact)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke (no artifact rewrite)")
    args = ap.parse_args()
    out = run(quick=not (args.full or args.smoke), smoke=args.smoke)
    if out["headline"]:
        print(json.dumps(out["headline"], indent=1))
