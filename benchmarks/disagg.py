"""Prefill/decode disaggregation: paged KV-page shipping on the trace.

Splits a 4-replica fleet into dedicated prefill and decode pools
(``run_cluster(prefill_replicas=P)``): a request prefills on a prefill
replica, then its paged KV ships to a router-chosen decode replica over
the interconnect (host-bounce, one batched transfer per handoff, charged
as *delayed availability* so it overlaps decode megasteps). The
comparison replays the bundled Azure-style trace at rate-scale 24
(~12 req/s) against colocated fleets of the same total size.

Operating point: the tpu-v5e roofline with ``peak_flops=50e12`` — a
compute-visible regime where chunked-prefill FLOPs stretch iteration
time. That is exactly the interference disaggregation removes: colocated
replicas interleave prefill chunks into every decode batch and the
inter-token gap (TBT) inherits the stall; a disaggregated decode pool
never runs a prefill chunk. At the default memory-bound point the
~20 ms parameter-stream floor hides prefill compute and the split has
nothing to win — the same reason cluster_curves.py pins a compute-bound
point for routing-quality visibility.

In-script gates (the script exits non-zero if any fails):

1. **Off-is-free** — rerunning every committed BENCH_trace_replay.json
   grid cell through the unchanged single-engine path must be
   byte-identical (the disaggregation machinery at ``P=0`` / engine
   defaults changes nothing).
2. **Determinism pin** — the headline disagg cell runs twice and its
   metrics JSON must be byte-identical.
3. **TBT-p99 win** — at equal total replicas, the best disaggregated
   split must beat the best colocated fleet on TBT p99 at rate-scale 24.
4. **Zero leaks** — every cell (handoff cells especially) must end with
   zero pages still allocated on every replica, prefill and decode alike.

Writes ``experiments/results/disagg.json`` and ``BENCH_disagg.json``.

    PYTHONPATH=src python -m benchmarks.disagg           # artifact
    PYTHONPATH=src python -m benchmarks.disagg --smoke   # CI
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import emit, save_json
from benchmarks.trace_replay import (HEADLINE_SCALE, SEED, _cell_summary,
                                     _make_cfg)
from benchmarks.trace_replay import _run_cell as _engine_cell
from repro.cluster import run_cluster
from repro.metrics import (check_invariants, ideal_service_times,
                           report_json, rollup)
from repro.serving.costmodel import CostModel, HardwareSpec
from repro.serving.engine import EngineConfig
from repro.traces import ReplayConfig, load_trace, requests_from_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Compute-visible operating point (see module docstring); every other
#: roofline constant keeps the tpu-v5e default, including the 25 GB/s
#: interconnect the handoffs cross.
HW = HardwareSpec(name="tpu-v5e-50tf", peak_flops=50e12)

N_TOTAL = 4                     # equal-fleet comparison: P + D = 4
SPLITS = ((1, 3), (2, 2))       # (prefill, decode) splits
COLOCATED_ROUTERS = ("jspw", "round-robin")


def _cluster_cell(cfg, reqs, n_replicas: int,
                  prefill_replicas: int, router: str):
    """One cluster cell; returns (report row, json bytes, ClusterStats)."""
    stats = run_cluster(cfg, reqs, router_policy=router,
                        n_replicas=n_replicas, seed=SEED, policy="trail",
                        kv_layout="paged", hardware=HW, record_events=True,
                        prefill_replicas=prefill_replicas)
    check_invariants(stats.event_log)
    service = ideal_service_times(
        CostModel(cfg, HW, page_size=EngineConfig().page_size), reqs)
    report = rollup(stats.event_log, service_times=service)
    row = _cell_summary(report)
    row["handoffs"] = stats.n_handoffs
    row["handoff_pages"] = stats.handoff_pages
    row["leaked_pages"] = sum(stats.leaked_pages)
    return row, report_json(report), stats


def _gate(ok: bool, name: str, detail: str) -> bool:
    emit(f"disagg.gate.{name}", 0.0, f"ok={ok};{detail}")
    if not ok:
        print(f"GATE FAIL [{name}]: {detail}")
    return ok


def run(smoke: bool = False):
    """Run the comparison + gates; returns the artifact dict."""
    cfg = _make_cfg()
    trace = load_trace("sample")
    scale = HEADLINE_SCALE
    limit = 60 if smoke else None
    n_total = 2 if smoke else N_TOTAL
    splits = ((1, 1),) if smoke else SPLITS
    routers = ("jspw",) if smoke else COLOCATED_ROUTERS

    rcfg = ReplayConfig(rate_scale=scale, seed=SEED, vocab=cfg.vocab_size,
                        limit=limit)
    reqs = requests_from_trace(trace, rcfg)

    results = {}

    def cell(key, p, router):
        row, js, stats = _cluster_cell(cfg, reqs, n_total, p, router)
        results[key] = row
        emit(f"disagg.{key}", row["tbt"]["p99"] * 1e6,
             f"tbt_p99={row['tbt']['p99']:.4f};"
             f"ttft_p99={row['ttft']['p99']:.3f};"
             f"handoffs={row['handoffs']};"
             f"leaked={row['leaked_pages']};"
             f"finished={row['finished']}")
        return row, js

    for router in routers:
        cell(f"scale={scale}.colocated.{router}", 0, router)
    for p, d in splits:
        cell(f"scale={scale}.P={p}D={d}.jspw", p, "jspw")

    ok = True

    # gate 1: off-is-free — every committed BENCH_trace_replay.json grid
    # cell reruns byte-identical through the untouched single-engine path
    # (skipped in smoke: the committed grid has no limit=60 cells)
    if not smoke:
        with open(os.path.join(ROOT, "BENCH_trace_replay.json")) as f:
            committed = json.load(f)["grid"]
        for key, want_row in sorted(committed.items()):
            prefix, pol = key.rsplit(".", 1)
            cell_scale = float(prefix.split("=", 1)[1])
            report, _ = _engine_cell(cfg, trace, pol, cell_scale)
            got = json.dumps(_cell_summary(report), sort_keys=True)
            want = json.dumps(want_row, sort_keys=True)
            ok &= _gate(got == want, f"off_is_free.{key}",
                        f"identical={got == want}")

    # gate 2: determinism — the headline disagg cell twice, byte-identical
    p_h, d_h = splits[0]
    _, js1, _ = _cluster_cell(cfg, reqs, n_total, p_h, "jspw")
    _, js2, _ = _cluster_cell(cfg, reqs, n_total, p_h, "jspw")
    ok &= _gate(js1 == js2, "determinism", f"bit_identical={js1 == js2}")

    # gate 3: the disaggregation win — best split beats best colocated
    # fleet on TBT p99 at equal total replicas. Full runs only: the
    # 60-request smoke slice never develops the steady decode load the
    # gate is about; smoke instead checks the handoff path end-to-end
    # (every request migrated, every request finished).
    best_col = min(results[f"scale={scale}.colocated.{r}"]["tbt"]["p99"]
                   for r in routers)
    best_key = min((f"scale={scale}.P={p}D={d}.jspw" for p, d in splits),
                   key=lambda k: results[k]["tbt"]["p99"])
    best_dis = results[best_key]["tbt"]["p99"]
    if smoke:
        hrow = results[f"scale={scale}.P={p_h}D={d_h}.jspw"]
        gate_ok = (hrow["finished"] == len(reqs)
                   and hrow["handoffs"] == len(reqs))
        ok &= _gate(gate_ok, "tbt_win",
                    f"smoke=True;finished={hrow['finished']}/{len(reqs)};"
                    f"handoffs={hrow['handoffs']}")
    else:
        ok &= _gate(best_dis < best_col, "tbt_win",
                    f"disagg_tbt_p99={best_dis:.4f}<"
                    f"colocated_tbt_p99={best_col:.4f}")

    # gate 4: zero leaked pages on every replica of every cell — the
    # export/import pair must conserve pages across both fleets
    for key, row in results.items():
        ok &= _gate(row["leaked_pages"] == 0, f"zero_leak.{key}",
                    f"leaked_pages={row['leaked_pages']}")

    headline = {
        "operating_point": f"bundled trace @ rate-scale {scale} "
                           f"({trace.mean_rate * scale:.2f} req/s), "
                           f"{HW.name}, {n_total} replicas",
        "best_split": best_key,
        "disagg_tbt_p99": best_dis,
        "colocated_tbt_p99": best_col,
        "colocated_vs_disagg_tbt_p99": (best_col / best_dis
                                        if best_dis > 0 else 0.0),
        "disagg_ttft_p99": results[best_key]["ttft"]["p99"],
        "colocated_ttft_p99": min(
            results[f"scale={scale}.colocated.{r}"]["ttft"]["p99"]
            for r in routers),
        "handoffs": results[best_key]["handoffs"],
        "handoff_pages": results[best_key]["handoff_pages"],
        "gates_ok": bool(ok),
    }
    emit("disagg.headline", 0.0,
         f"tbt_p99={headline['colocated_vs_disagg_tbt_p99']:.2f}x;"
         f"handoffs={headline['handoffs']};gates_ok={ok}")

    payload = {
        "config": {"model": "granite-3-8b", "trace": "azure_llm_sample",
                   "hardware": HW.name, "peak_flops": HW.peak_flops,
                   "link_bw": HW.link_bw, "seed": SEED,
                   "rate_scale": scale, "n_replicas": n_total,
                   "splits": [list(s) for s in splits],
                   "colocated_routers": list(routers)},
        "headline": headline,
        "grid": results,
    }
    if not smoke:
        save_json("disagg", results)
        with open(os.path.join(ROOT, "BENCH_disagg.json"), "w") as f:
            json.dump(payload, f, indent=1)
    if not ok:
        raise SystemExit("disagg gates failed")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke: 60 requests, 1P+1D vs 2x "
                         "colocated, no artifact rewrite, handoff "
                         "end-to-end gate instead of the TBT-p99 gate")
    args = ap.parse_args()
    out = run(smoke=args.smoke)
    print(json.dumps(out["headline"], indent=1))
