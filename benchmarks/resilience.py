"""Failure & overload resilience: shedding at overload + chaos failover.

Three gated experiments on top of the PR 5 trace/metrics stack:

1. **Off-is-free** — every resilience knob at its default must leave the
   trace-replay grid *byte-identical*: the zero-knob cells here are
   compared against the committed ``BENCH_trace_replay.json`` cells (and
   against a freshly-run baseline in smoke mode). A mismatch means the
   resilience machinery leaked into the gated-off path, and the run
   refuses to write any artifact.
2. **Predicted-work load shedding at 1.5x overload** — the bundled trace
   at rate-scale 36 (1.5x the trace-replay headline's 24) with the TRAIL
   backlog watermark: shedding the worst-ranked waiting requests must
   *strictly* improve the p99 completion time and the completion SLO
   attainment of the requests actually served, at every threshold.
   PR 6's predictor-quality dial rides along: a degraded predictor
   (noisy-oracle) sheds on a blurrier ranking, quantifying how much of
   the win needs prediction quality.
3. **Chaos failover** — a 2-replica paged jspw cluster under
   deterministic fault schedules (crash, crash+recover, straggler,
   flaky submits): the router redispatches drained requests under the
   retry budget, and after every run each replica's BlockManager must
   report ``used_pages() == 0`` — the zero-leak invariant.

Writes ``experiments/results/resilience.json`` and the headline
``BENCH_resilience.json``.

    PYTHONPATH=src python -m benchmarks.resilience --quick
    PYTHONPATH=src python -m benchmarks.resilience --smoke   # CI
"""

from __future__ import annotations

import argparse
import copy
import json
import os

from benchmarks.common import emit, save_json
from benchmarks.trace_replay import (HEADLINE_SCALE, HW, SEED, _cell_summary,
                                     _make_cfg, _run_cell)
from repro.cluster.faults import parse_chaos
from repro.cluster.router import Router, RouterConfig
from repro.metrics import (EventLog, check_invariants, ideal_service_times,
                           report_json, rollup)
from repro.serving.costmodel import CostModel
from repro.serving.engine import Engine, EngineConfig
from repro.serving.workload import generate, scenario_config
from repro.traces import ReplayConfig, load_trace, replay, requests_from_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: 1.5x the trace-replay headline operating point: overloaded enough
#: that serving everything blows the tail, the regime shedding is for.
OVERLOAD_SCALE = 1.5 * HEADLINE_SCALE
#: Predicted-token backlog watermark for the headline shedding cell
#: (sheds ~30% of the overload stream; see the sweep in the grid).
WATERMARK = 20000.0

#: The chaos schedules (2-replica cluster; times in virtual seconds).
CHAOS_SPECS = {
    "crash": "crash:1@5",
    "crash_recover": "crash:1@5-20",
    "straggler": "slow:1@2-12*4",
    "flaky": "flaky:0@0-8%0.5",
}


def _run_shed_cell(cfg, trace, rate_scale: float, limit=None,
                   policy: str = "trail", **knobs) -> tuple[dict, str]:
    """One trace-replay cell with resilience knobs threaded into the
    engine (the `_run_cell` twin; zero knobs = the identical pipeline)."""
    rcfg = ReplayConfig(rate_scale=rate_scale, seed=SEED,
                        vocab=cfg.vocab_size, limit=limit)
    reqs = requests_from_trace(trace, rcfg)
    log = EventLog()
    eng = Engine(cfg, EngineConfig(policy=policy, hardware=HW, seed=SEED,
                                   **knobs), event_log=log)
    replay(eng, copy.deepcopy(reqs))
    check_invariants(log)
    service = ideal_service_times(CostModel(cfg, HW), reqs)
    report = rollup(log, service_times=service)
    return report, report_json(report)


def _shed_summary(report: dict) -> dict:
    """Cell row: served-request percentiles + the goodput accounting."""
    cell = _cell_summary(report)
    cell["goodput"] = report["requests"]["goodput"]
    cell["shed"] = report["counters"]["shed"]
    return cell


def _run_chaos_cell(cfg, reqs, spec: str) -> dict:
    """One fault-injected cluster run; returns the summary row and
    enforces the zero-leak invariant on every replica."""
    replicas = [Engine(cfg, EngineConfig(policy="trail", hardware=HW,
                                         kv_layout="paged", seed=SEED + i),
                       event_log=EventLog()) for i in range(2)]
    router = Router(replicas, RouterConfig(n_replicas=2, policy="jspw",
                                           seed=SEED),
                    faults=parse_chaos(spec, seed=SEED),
                    event_log=EventLog())
    stats = router.run(copy.deepcopy(reqs))
    check_invariants(stats.event_log)
    leaks = [eng.blocks.used_pages() for eng in replicas]
    if any(leaks):
        raise SystemExit(f"KV page leak after chaos {spec!r}: {leaks}")
    s = stats.summary()
    return {"spec": spec, "finished": s["finished"],
            "goodput": s["goodput"], "retries": s["retries"],
            "lost": s["lost"], "replica_crashes": s["replica_crashes"],
            "p99_latency": s["p99_latency"], "makespan": s["makespan"],
            "leaked_pages": sum(leaks)}


def run(quick: bool = True, smoke: bool = False):
    """Run the gated sweep; returns the artifact dict (written to disk
    unless smoke)."""
    cfg = _make_cfg()
    trace = load_trace("sample")
    results: dict = {}

    # -- gate 1: off-is-free byte identity --------------------------------
    if smoke:
        identity_cells = [(16.0, "trail")]
        limit = 60
    else:
        identity_cells = [(HEADLINE_SCALE, "trail"), (HEADLINE_SCALE, "fcfs")]
        limit = None
    committed = None
    bench_path = os.path.join(ROOT, "BENCH_trace_replay.json")
    if not smoke and os.path.exists(bench_path):
        with open(bench_path) as f:
            committed = json.load(f)["grid"]
    identical = True
    for scale, pol in identity_cells:
        base_report, _ = _run_cell(cfg, trace, pol, scale, limit=limit)
        off_report, _ = _run_shed_cell(cfg, trace, scale, limit=limit,
                                       policy=pol, deadline_s=0.0,
                                       ttft_deadline_s=0.0,
                                       shed_watermark=0.0,
                                       admission_control=False)
        fresh = json.dumps(_cell_summary(base_report), sort_keys=True) == \
            json.dumps(_cell_summary(off_report), sort_keys=True)
        vs_committed = True
        if committed is not None:
            vs_committed = json.dumps(committed[f"scale={scale}.{pol}"],
                                      sort_keys=True) == \
                json.dumps(_cell_summary(off_report), sort_keys=True)
        identical = identical and fresh and vs_committed
        emit(f"resilience.identity.scale={scale}.{pol}", 0.0,
             f"fresh={fresh};committed={vs_committed}")
    if not identical:
        raise SystemExit("off-by-default violated: resilience knobs at "
                         "zero changed a trace-replay cell")

    # -- gate 2: shedding strictly improves the served tail ---------------
    shed_scale = OVERLOAD_SCALE
    shed_cfgs = [("no_shed", {}),
                 ("shed", {"shed_watermark": WATERMARK}),
                 ("shed_admission", {"shed_watermark": WATERMARK,
                                     "admission_control": True}),
                 ("shed_noisy_pred", {"shed_watermark": WATERMARK,
                                      "predictor":
                                          "noisy-oracle:sigma=1.0"})]
    if smoke:
        shed_cfgs = shed_cfgs[:2]
    shed_rows = {}
    for name, knobs in shed_cfgs:
        report, js = _run_shed_cell(cfg, trace, shed_scale, limit=limit,
                                    **knobs)
        if name == "shed":
            _, js2 = _run_shed_cell(cfg, trace, shed_scale, limit=limit,
                                    **knobs)
            if js != js2:
                raise SystemExit("shed cell is nondeterministic")
        shed_rows[name] = report
        cell = _shed_summary(report)
        results[f"overload.{name}"] = cell
        emit(f"resilience.overload.{name}",
             cell["completion"]["mean"] * 1e6,
             f"p99={cell['completion']['p99']:.2f};"
             f"shed={cell['shed']};goodput={cell['goodput']:.3f}")
    base, shed = shed_rows["no_shed"], shed_rows["shed"]
    p99_gain = (base["completion"]["p99"] / shed["completion"]["p99"]
                if shed["completion"]["p99"] else 0.0)
    att_base = {a["slo_s"]: a["attainment"]
                for a in base["slo_attainment"]["completion"]}
    att_shed = {a["slo_s"]: a["attainment"]
                for a in shed["slo_attainment"]["completion"]}
    slo_ok = all(att_shed[s] >= att_base[s] - 1e-12 for s in att_base)
    if not smoke:
        if shed["completion"]["p99"] >= base["completion"]["p99"]:
            raise SystemExit(
                "shedding did not improve served p99 completion: "
                f"{shed['completion']['p99']:.2f} vs "
                f"{base['completion']['p99']:.2f}")
        if not slo_ok:
            raise SystemExit("shedding lowered a completion SLO "
                             "attainment point")

    # -- gate 3: chaos failover with zero page leaks ----------------------
    wc = scenario_config("bursty", n_requests=40 if smoke else 120,
                         request_rate=3.0, seed=SEED,
                         vocab=cfg.vocab_size)
    reqs = generate(wc)
    specs = (dict(list(CHAOS_SPECS.items())[:1]) if smoke else CHAOS_SPECS)
    for name, spec in specs.items():
        row = _run_chaos_cell(cfg, reqs, spec)
        results[f"chaos.{name}"] = row
        emit(f"resilience.chaos.{name}", 0.0,
             f"goodput={row['goodput']:.3f};retries={row['retries']};"
             f"lost={row['lost']};leaked={row['leaked_pages']}")

    headline = {
        "operating_point": f"bundled trace @ rate-scale {shed_scale} "
                           f"(1.5x the trace-replay headline), {HW.name}",
        "off_is_byte_identical": identical,
        "shed_watermark_tokens": WATERMARK,
        "no_shed_p99": base["completion"]["p99"],
        "shed_p99": shed["completion"]["p99"],
        "shed_p99_gain": p99_gain,
        "shed_goodput": shed["requests"]["goodput"],
        "shed_slo_attainment_never_worse": slo_ok,
        "chaos_zero_page_leaks": True,      # enforced per cell above
        "chaos_goodput_min": min(
            (results[k]["goodput"] for k in results
             if k.startswith("chaos.")), default=None),
    }
    emit("resilience.headline", 0.0,
         f"p99_gain={p99_gain:.2f}x;goodput={headline['shed_goodput']:.3f};"
         f"identity={identical};slo_ok={slo_ok}")

    payload = {
        "config": {"model": "granite-3-8b", "trace": "azure_llm_sample",
                   "hardware": HW.name, "seed": SEED,
                   "overload_scale": shed_scale, "watermark": WATERMARK,
                   "chaos_specs": CHAOS_SPECS,
                   "cluster": {"replicas": 2, "router": "jspw",
                               "kv_layout": "paged"}},
        "headline": headline,
        "grid": results,
    }
    if not smoke:
        save_json("resilience", results)
        if quick:
            with open(os.path.join(ROOT, "BENCH_resilience.json"), "w") as f:
                json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="the checked-in artifact grid (the default)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke (no artifact rewrite)")
    args = ap.parse_args()
    out = run(quick=not args.smoke, smoke=args.smoke)
    print(json.dumps(out["headline"], indent=1))
