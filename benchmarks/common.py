"""Shared helpers for the paper-artifact benchmarks."""

from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "results")


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line, flush=True)
    return line


def save_json(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1)


def timed(fn, *args, warmup: int = 1, iters: int = 5):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / iters
    return out, dt
