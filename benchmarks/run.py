"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit) and
writes JSON payloads under experiments/results/.

  pred_accuracy   — Figures 2/3/4 (MAE per layer, refined vs BERT, heatmap)
  probe_tps       — Table 1 (probe inference microseconds/sample)
  c_sweep         — Figure 5 (C = 0.2/0.5/0.8/1.0 at rate 14)
  serving_curves  — Figure 6 (4 systems x request rates)
  burst           — Figure 7 (burst arrivals)
  memory_sim      — Appendix D + Lemma 1 (sim vs closed form)
  roofline        — section Roofline table from the dry-run artifacts
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (burst, c_sweep, extensions, memory_sim,
                        pred_accuracy, probe_tps, roofline, serving_curves)
from benchmarks.common import emit

MODULES = [
    ("probe_tps", probe_tps.run),
    ("memory_sim", memory_sim.run),
    ("c_sweep", c_sweep.run),
    ("serving_curves", serving_curves.run),
    ("burst", burst.run),
    ("pred_accuracy", pred_accuracy.run),
    ("extensions", extensions.run),
    ("roofline", roofline.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized workloads (slow on CPU)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failures = 0
    for name, fn in MODULES:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            fn(quick=not args.full)
            emit(f"{name}.wall_s", (time.time() - t0) * 1e6, "ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            emit(f"{name}.wall_s", (time.time() - t0) * 1e6,
                 f"FAILED:{type(e).__name__}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
