"""Cross-request KV prefix caching: hit-rate x policy sweep.

Serves the ``shared-prefix`` tenant mix (per-tenant system prompts:
192/384/96 tokens for chat/code/summarize) on a 2-replica cluster at the
PR-3 compute-bound operating point (2 bf16 TFLOP/s per replica, 0.9
aggregate req/s) — the regime where prefill compute is the bottleneck, so
serving a prompt prefix from the cache (block-table link, no compute)
shows up directly in completion time.

Grid: prefix hit rate (the workload dial) x {no-sharing baseline,
prefix_cache under round-robin / jspw / prefix-affinity routing}, each
cell averaged over workload seeds. A small real-mode section runs the
actual model (CPU-sized ``trail_llama``) with identical prompts through
the paged engine and checks the prefilled-token drop end to end.

Writes ``experiments/results/prefix_cache.json`` and the headline
``BENCH_prefix_cache.json``: at hit rate 1.0, prefix caching must cut
mean completion time by >= 1.3x vs the no-sharing baseline (it lands far
above that), with prefilled tokens/request dropping accordingly.

    PYTHONPATH=src python -m benchmarks.prefix_cache --quick
    PYTHONPATH=src python -m benchmarks.prefix_cache --smoke   # CI
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.cluster_curves import HW       # the PR-3 compute-bound
from benchmarks.common import emit, save_json  # operating point, shared so
from repro.cluster import run_cluster          # the benchmarks cannot drift
from repro.config import get_config, get_smoke_config
from repro.serving.workload import generate, scenario_config

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RATE = 0.9                  # aggregate req/s (the PR-3 headline rate)
N_REPLICAS = 2
ROUTERS = ("round-robin", "jspw", "prefix-affinity")
HEADLINE_HIT = 1.0


def _cell(cfg, reqs_by_seed, *, router, prefix_cache):
    """Average one grid cell over the workload seeds."""
    means, p99s, pf, hits, fins = [], [], [], [], []
    for reqs in reqs_by_seed:
        s = run_cluster(cfg, reqs, router_policy=router,
                        n_replicas=N_REPLICAS, policy="trail", seed=5,
                        max_batch=16, hardware=HW, kv_layout="paged",
                        prefix_cache=prefix_cache)
        d = s.summary()
        means.append(d["mean_latency"])
        p99s.append(d["p99_latency"])
        pf.append(d["prefilled_tokens"] / max(d["finished"], 1))
        hits.append(d["prefix_hit_tokens"] / max(d["finished"], 1))
        fins.append(d["finished"])
    return {"mean_latency": float(np.mean(means)),
            "p99_latency": float(np.mean(p99s)),
            "prefilled_tokens_per_req": float(np.mean(pf)),
            "prefix_hit_tokens_per_req": float(np.mean(hits)),
            "finished": int(np.sum(fins)),
            "per_seed_mean": [float(m) for m in means]}


def run_real(n: int = 8, seed: int = 1) -> dict:
    """Real-mode check on a CPU-sized model: identical shared prompts
    through the paged device pool, prefix caching off vs on. The clock is
    real wall time on whatever machine runs this, so the comparison that
    matters is prefilled tokens (the compute actually spent), not
    latency."""
    import jax

    from repro.models.model import Model
    from repro.serving.engine import run_policy
    from repro.serving.predictors import ProbePredictor
    from repro.serving.workload import WorkloadConfig

    cfg = get_smoke_config("trail-llama")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    wc = WorkloadConfig(n_requests=n, request_rate=50.0, seed=seed,
                        vocab=cfg.vocab_size, prompt_mean=6.0,
                        out_median=6.0, max_out=12, split_streams=True,
                        prefix_len=16, prefix_hit=1.0)
    reqs = generate(wc)
    out = {}
    for flag in (False, True):
        pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                              embed_table=params["embed"])
        s = run_policy(cfg, "trail", reqs, max_batch=4, mode="real",
                       model=m, params=params, predictor=pred,
                       kv_layout="paged", page_size=8, max_len=64,
                       prefix_cache=flag)
        key = "prefix_cache" if flag else "baseline"
        out[key] = {"finished": len(s.latencies),
                    "prefilled_tokens": s.prefilled_tokens,
                    "prefix_hit_tokens": s.prefix_hit_tokens}
        emit(f"prefix_cache.real.{key}", float(s.prefilled_tokens),
             f"hits={s.prefix_hit_tokens};finished={len(s.latencies)}")
    out["prefill_drop"] = (
        out["baseline"]["prefilled_tokens"]
        - out["prefix_cache"]["prefilled_tokens"])
    return out


def run(quick: bool = True, smoke: bool = False):
    """Run the grid; returns the results dict (also written to disk)."""
    cfg = get_config("granite-3-8b")
    if smoke:
        hit_rates, seeds, n, routers = (1.0,), (3,), 60, ("round-robin",)
        real = False
    elif quick:
        hit_rates, seeds, n = (0.0, 0.5, 1.0), (3, 11, 23), 150
        routers, real = ROUTERS, True
    else:
        hit_rates, seeds, n = (0.0, 0.25, 0.5, 0.75, 1.0), (3, 11, 23, 42), 300
        routers, real = ROUTERS, True

    results = {}
    for hr in hit_rates:
        reqs_by_seed = [
            generate(scenario_config("shared-prefix", n_requests=n,
                                     request_rate=RATE, seed=s,
                                     vocab=cfg.vocab_size, prefix_hit=hr))
            for s in seeds]
        cells = {"no-sharing": _cell(cfg, reqs_by_seed,
                                     router="round-robin",
                                     prefix_cache=False)}
        for router in routers:
            cells[router] = _cell(cfg, reqs_by_seed, router=router,
                                  prefix_cache=True)
        for name, cell in cells.items():
            key = f"hit={hr}.{name}"
            results[key] = cell
            emit(f"prefix_cache.{key}", cell["mean_latency"] * 1e6,
                 f"p99={cell['p99_latency']:.2f};"
                 f"pf/req={cell['prefilled_tokens_per_req']:.0f};"
                 f"hit/req={cell['prefix_hit_tokens_per_req']:.0f}")

    base = results.get(f"hit={HEADLINE_HIT}.no-sharing")
    cached_cells = {r: results[f"hit={HEADLINE_HIT}.{r}"] for r in routers
                    if f"hit={HEADLINE_HIT}.{r}" in results}
    headline = None
    if base and cached_cells:
        best_router = min(cached_cells,
                          key=lambda r: cached_cells[r]["mean_latency"])
        cached = cached_cells[best_router]
        headline = {
            "operating_point": f"shared-prefix @ {RATE} aggregate req/s, "
                               f"hit rate {HEADLINE_HIT}, {N_REPLICAS} "
                               f"replicas, compute-bound 2 TFLOP/s",
            "router": best_router,
            "no_sharing_mean": base["mean_latency"],
            "prefix_cache_mean": cached["mean_latency"],
            "speedup": base["mean_latency"] / cached["mean_latency"],
            "prefilled_per_req_no_sharing":
                base["prefilled_tokens_per_req"],
            "prefilled_per_req_prefix_cache":
                cached["prefilled_tokens_per_req"],
            "meets_1_3x": base["mean_latency"]
                          >= 1.3 * cached["mean_latency"],
        }
        emit("prefix_cache.headline", 0.0,
             f"speedup={headline['speedup']:.2f}x;"
             f"pf/req={headline['prefilled_per_req_no_sharing']:.0f}->"
             f"{headline['prefilled_per_req_prefix_cache']:.0f}")

    real_out = run_real() if real else None
    save_json("prefix_cache", results)
    payload = {
        "config": {"model": "granite-3-8b", "engine_policy": "trail",
                   "scenario": "shared-prefix", "hardware": HW.name,
                   "peak_flops": HW.peak_flops, "rate": RATE,
                   "n_replicas": N_REPLICAS, "max_batch": 16,
                   "n_requests": n, "seeds": list(seeds)},
        "headline": headline,
        "real_mode": real_out,
        "grid": results,
    }
    if quick and not smoke:
        # the checked-in artifact is the --quick grid; smoke never
        # rewrites it (same convention as BENCH_cluster.json)
        with open(os.path.join(ROOT, "BENCH_prefix_cache.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="3 seeds, 3 hit rates (the checked-in artifact)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke (no artifact rewrite)")
    args = ap.parse_args()
    out = run(quick=args.quick, smoke=args.smoke)
    if out["headline"]:
        print(json.dumps(out["headline"], indent=1))
