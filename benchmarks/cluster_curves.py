"""Cluster serving curves: completion time vs aggregate rate, per router
policy x scenario x replica count.

Replicas run the TRAIL engine (SPRPT-LP, ``policy="trail"``) under a
**compute-bound** hardware point (2 bf16 TFLOP/s per replica): iteration
time then scales with batch tokens, so each replica behaves like the
processor-sharing single server of the companion queueing analysis
(Mitzenmacher & Shahout, arXiv:2503.07545) and dispatch quality is visible
in completion time. (On the memory-bound TPU-v5e point, decode iteration
time is nearly occupancy-independent — every balanced-count policy ties
and routing is uninteresting.)

Grid: scenarios (poisson, bursty MMPP) x aggregate rates x replica counts
(1/2/4) x router policies (round-robin, jsq, pow2, jspw), each cell
averaged over workload seeds. Writes ``experiments/results/
cluster_curves.json`` and the headline ``BENCH_cluster.json`` at the repo
root: at matched aggregate rate on the bursty scenario, jspw (predicted
work, SRPT-truncated) must beat round-robin on mean completion time, and
2 replicas must beat 1.

    PYTHONPATH=src python -m benchmarks.cluster_curves --quick
    PYTHONPATH=src python -m benchmarks.cluster_curves --smoke   # CI
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import emit, save_json
from repro.cluster import run_cluster
from repro.config import get_config
from repro.serving.costmodel import HardwareSpec
from repro.serving.workload import generate, scenario_config

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# compute-bound replica: 2 bf16 TFLOP/s (capacity ~1 req/s on the Alpaca
# shape) — the regime where replica service rate is throughput-bound
HW = HardwareSpec(name="compute-bound-2tf", peak_flops=2e12, hbm_bw=819e9,
                  dma_bw=32e9, overhead_s=2e-4)

POLICIES = ("round-robin", "jsq", "pow2", "jspw")
HEADLINE = ("bursty", 0.9, 2)       # scenario, aggregate rate, replicas


def _cell(cfg, reqs_by_seed, policy, n_replicas, max_batch):
    """Average one grid cell over the workload seeds."""
    means, p99s, ttfts, fins = [], [], [], []
    for reqs in reqs_by_seed:
        s = run_cluster(cfg, reqs, router_policy=policy,
                        n_replicas=n_replicas, policy="trail", seed=5,
                        max_batch=max_batch, hardware=HW)
        d = s.summary()
        means.append(d["mean_latency"])
        p99s.append(d["p99_latency"])
        ttfts.append(d["mean_ttft"])
        fins.append(d["finished"])
    return {"mean_latency": float(np.mean(means)),
            "p99_latency": float(np.mean(p99s)),
            "mean_ttft": float(np.mean(ttfts)),
            "finished": int(np.sum(fins)),
            "per_seed_mean": [float(m) for m in means]}


def run(quick: bool = True, smoke: bool = False):
    """Run the grid; returns the results dict (also written to disk)."""
    cfg = get_config("granite-3-8b")
    if smoke:
        scenarios, rates, replicas = ("bursty",), (0.9,), (1, 2)
        policies, seeds, n = ("round-robin", "jspw"), (3,), 100
    elif quick:
        scenarios, rates, replicas = ("poisson", "bursty"), (0.9, 1.5), (1, 2, 4)
        policies, seeds, n = POLICIES, (3, 11, 23), 300
    else:
        scenarios, rates, replicas = ("poisson", "bursty"), (0.6, 0.9, 1.2, 1.5), (1, 2, 4)
        policies, seeds, n = POLICIES, (3, 11, 23, 42, 57), 500

    results = {}
    for scen in scenarios:
        for rate in rates:
            reqs_by_seed = [
                generate(scenario_config(scen, n_requests=n,
                                         request_rate=rate, seed=s,
                                         vocab=cfg.vocab_size))
                for s in seeds]
            for nr in replicas:
                # with one replica every policy routes identically
                pols = ("round-robin",) if nr == 1 else policies
                for pol in pols:
                    cell = _cell(cfg, reqs_by_seed, pol, nr, max_batch=16)
                    key = f"{scen}@{rate}.R{nr}.{pol}"
                    results[key] = cell
                    emit(f"cluster.{key}", cell["mean_latency"] * 1e6,
                         f"p99={cell['p99_latency']:.2f};"
                         f"ttft={cell['mean_ttft']:.2f};"
                         f"finished={cell['finished']}")

    scen, rate, nr = HEADLINE
    rr = results.get(f"{scen}@{rate}.R{nr}.round-robin")
    jspw = results.get(f"{scen}@{rate}.R{nr}.jspw")
    r1 = results.get(f"{scen}@{rate}.R1.round-robin")
    headline = None
    if rr and jspw and r1:
        headline = {
            "operating_point": f"{scen} @ {rate} aggregate req/s, "
                               f"{nr} replicas, compute-bound 2 TFLOP/s",
            "rr_mean": rr["mean_latency"],
            "jspw_mean": jspw["mean_latency"],
            "jspw_vs_rr": rr["mean_latency"] / jspw["mean_latency"],
            "r1_mean": r1["mean_latency"],
            "r2_rr_mean": rr["mean_latency"],
            "scaleup_2x": r1["mean_latency"] / rr["mean_latency"],
            "jspw_beats_rr": jspw["mean_latency"] < rr["mean_latency"],
            "two_replicas_beat_one": rr["mean_latency"] < r1["mean_latency"],
        }
        emit("cluster.headline", 0.0,
             f"jspw_vs_rr={headline['jspw_vs_rr']:.2f}x;"
             f"scaleup_2x={headline['scaleup_2x']:.2f}x")

    save_json("cluster_curves", results)
    payload = {
        "config": {"model": "granite-3-8b", "engine_policy": "trail",
                   "hardware": HW.name, "peak_flops": HW.peak_flops,
                   "max_batch": 16, "n_requests": n,
                   "seeds": list(seeds)},
        "headline": headline,
        "grid": results,
    }
    if quick and not smoke:
        # the checked-in artifact is the --quick grid (3 seeds, 2 rates);
        # smoke never writes it, and the full grid goes to
        # experiments/results only so a no-flag run can't clobber the
        # artifact with a differently-shaped grid
        with open(os.path.join(ROOT, "BENCH_cluster.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="3 seeds, 2 rates (the checked-in artifact)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke (no artifact rewrite)")
    args = ap.parse_args()
    out = run(quick=args.quick, smoke=args.smoke)
    if out["headline"]:
        print(json.dumps(out["headline"], indent=1))
