"""Real-mode decode hot path: per-token scheduling loop vs device megasteps.

Two measurement levels, both on the tiny ``trail_llama`` smoke config:

* engine — ``run_policy(mode="real")`` end to end. ``probe_interval=1`` is
  the per-token baseline (scheduler, page allocation, cost model and host
  bookkeeping consulted after every generated token); ``probe_interval=k``
  amortizes all of that over k-token device-resident megasteps. This is
  the headline ``speedup_k4`` number.

* device_loop — the raw decode loops without the engine around them. The
  baseline reproduces the pre-megastep hot path exactly: one un-donated
  ``decode_step`` jit call per token, the full (B, vocab) logits pulled to
  the host, host-side argmax + probe softmax, token fed back from Python.
  ``decode_multi(k)`` transfers only (B,k) ids + (B,k,num_bins) probe
  posteriors, so its host bytes/token are vocab-independent.

Writes ``BENCH_decode_tps.json`` at the repo root (perf trajectory seed).

    PYTHONPATH=src python -m benchmarks.decode_tps --quick
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_smoke_config
from repro.models.model import Model
from repro.serving.engine import run_policy
from repro.serving.kv_cache import donating_jit
from repro.serving.predictors import ProbePredictor
from repro.serving.workload import WorkloadConfig, generate

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KS = (1, 4, 8)


# ---------------------------------------------------------------------------
# engine level: per-token scheduling loop vs k-token megasteps
# ---------------------------------------------------------------------------

def bench_engine(quick: bool):
    cfg = get_smoke_config("trail-llama")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    wc = WorkloadConfig(n_requests=12 if quick else 24, request_rate=1e9,
                        seed=1, vocab=cfg.vocab_size, prompt_mean=8.0,
                        out_median=40.0, max_out=48)
    pred = ProbePredictor(cfg.probe, probe_params=params["probe"],
                          embed_table=params["embed"])
    reps = 2 if quick else 3

    def measure(pi):
        kw = dict(max_batch=8, mode="real", model=m, params=params,
                  predictor=pred, probe_interval=pi, max_len=128)
        run_policy(cfg, "trail", generate(wc), **kw)    # warm compiles
        best, toks = 1e9, 0
        for _ in range(reps):
            reqs = generate(wc)
            toks = sum(min(r.true_out_len, r.max_new_tokens) for r in reqs)
            t0 = time.perf_counter()
            s = run_policy(cfg, "trail", reqs, **kw)
            best = min(best, time.perf_counter() - t0)
            assert len(s.latencies) == len(reqs)
        return toks / best

    out = {}
    base = measure(1)
    out["probe_interval_1"] = {"tokens_per_s": base}
    print(f"engine  per-token loop (k=1): {base:10.1f} tok/s", flush=True)
    for k in KS[1:]:
        tps = measure(k)
        out[f"probe_interval_{k}"] = {"tokens_per_s": tps,
                                      "speedup_vs_per_token": tps / base}
        print(f"engine  megasteps     (k={k}): {tps:10.1f} tok/s  "
              f"({tps / base:.2f}x)", flush=True)
    return out


# ---------------------------------------------------------------------------
# device level: the raw loops, host-transfer accounting
# ---------------------------------------------------------------------------

def bench_device_loop(quick: bool):
    B, prompt_len, max_len = 4, 8, 128
    T = 64 if quick else 256
    cfg = get_smoke_config("trail-llama")
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (B, prompt_len), 4,
                                 cfg.vocab_size)
    cache0 = m.init_cache(B, max_len)
    logits, cache0, *_ = jax.jit(m.prefill_chunk)(params, cache0, prompts)
    tok0 = np.asarray(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    decode_step = jax.jit(m.decode_step)
    decode_multi = donating_jit(m.decode_multi,
                                static_argnames=("k", "eos_id"))

    def fresh():
        return jax.tree_util.tree_map(jnp.copy, cache0)

    def run_baseline(cache, tok, steps):
        # pre-megastep engine loop: (B, vocab) logits to host every token
        for _ in range(steps):
            lo, cache, _, pl = decode_step(params, cache, jnp.asarray(tok))
            logits_np = np.asarray(lo)
            pln = np.asarray(pl)
            p = np.exp(pln - pln.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            tok = np.argmax(logits_np, -1)[:, None].astype(np.int32)
        return cache

    def run_megastep(cache, tok, nsteps, k):
        for _ in range(nsteps):
            toks, cache, probs, n_emit = decode_multi(
                params, cache, jnp.asarray(tok), k=k)
            toks_np = np.asarray(toks)                  # (B, k) ids only
            _ = np.asarray(probs)
            _ = np.asarray(n_emit)
            tok = toks_np[:, -1:].astype(np.int32)
        return cache

    reps = 3 if quick else 5
    out = {}
    run_baseline(fresh(), tok0, 4)                      # warmup / compile
    best = 1e9
    for _ in range(reps):
        t0 = time.perf_counter()
        run_baseline(fresh(), tok0, T)
        best = min(best, time.perf_counter() - t0)
    bpt = B * (cfg.vocab_size * 4 + cfg.probe.num_bins * 4)
    out["baseline"] = {"tokens_per_s": B * T / best,
                       "host_bytes_per_token": bpt}
    print(f"device  per-token loop: {B * T / best:10.1f} tok/s  "
          f"{bpt} host B/tok (O(B*V) logits)", flush=True)
    for k in KS:
        run_megastep(fresh(), tok0, 2, k)               # warmup / compile
        best = 1e9
        for _ in range(reps):
            t0 = time.perf_counter()
            run_megastep(fresh(), tok0, T // k, k)
            best = min(best, time.perf_counter() - t0)
        tps = B * (T // k) * k / best
        bpt_k = (B * (k * 4 + k * cfg.probe.num_bins * 4 + 4)) // k
        out[f"k{k}"] = {"tokens_per_s": tps, "host_bytes_per_token": bpt_k}
        print(f"device  megastep k={k}: {tps:10.1f} tok/s  "
              f"{bpt_k} host B/tok (vocab-independent)", flush=True)
    return out


def run(quick: bool = True):
    results = {"config": "trail-llama-smoke", "mode": "real"}
    results["engine"] = bench_engine(quick)
    results["device_loop"] = bench_device_loop(quick)
    results["speedup_k4"] = \
        results["engine"]["probe_interval_4"]["speedup_vs_per_token"]
    results["transfer_reduction_k4"] = (
        results["device_loop"]["baseline"]["host_bytes_per_token"]
        / results["device_loop"]["k4"]["host_bytes_per_token"])
    with open(os.path.join(ROOT, "BENCH_decode_tps.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(f"speedup_k4={results['speedup_k4']:.2f}x  transfer_reduction_k4="
          f"{results['transfer_reduction_k4']:.0f}x", flush=True)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload / fewer steps (CI smoke)")
    args = ap.parse_args()
    run(quick=args.quick)
