"""Paper Figures 2, 3, 4: length-prediction accuracy.

  * Figure 2/3: MAE of remaining-length predictions per tap layer, for
    (a) prompt-only baseline ("BERT" regime: one-shot, decremented),
    (b) raw per-token probe, (c) Bayesian-refined probe.
  * Figure 4: log-scaled heatmap counts of ground-truth vs predicted bins.

Scale adaptation (DESIGN.md section 9): the serving model is the trained
trail-llama smoke/full config rather than Llama3-8B; the claims validated
are the relative orderings (probe < BERT on MAE; refined < raw).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.config import get_smoke_config
from repro.core.bins import bin_index, bin_means
from repro.core import predictor as probe_mod
from repro.core.smoothing import refine_sequence
from repro.models.model import Model
from repro.training import optimizer as opt_mod
from repro.training.data import DataConfig, batches
from repro.training.train import ProbeTrainConfig, train_lm, train_probe


def _setup(seed=0, steps=80):
    cfg = get_smoke_config("trail-llama")
    cfg = dataclasses.replace(cfg, num_layers=4, layer_kinds=())
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    dc = DataConfig(vocab=cfg.vocab_size, seq_len=96, batch=8,
                    prompt_mean=10, max_out=60, seed=seed)
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
    params, _, _ = train_lm(model, params, batches(dc, steps), ocfg, steps)
    return cfg, model, params, dc


def harvest_all_layers(cfg, model, params, dc, n_batches=6):
    """(layer, N, d) taps + (N,) remaining + sequence ids for refinement."""
    import jax.numpy as jnp
    taps, rems, seqs = [], [], []
    sid = 0
    for batch in batches(dataclasses.replace(dc, seed=dc.seed + 100),
                         n_batches):
        all_taps = np.asarray(model.forward_all_taps(
            params, {"tokens": jnp.asarray(batch["tokens"])}),
            np.float32)                                 # (L,B,S,d)
        rem = batch["remaining"]
        for b in range(rem.shape[0]):
            idx = np.where(rem[b] >= 0)[0]
            if len(idx) == 0:
                continue
            taps.append(all_taps[:, b, idx, :])
            rems.append(rem[b, idx])
            seqs.append(np.full(len(idx), sid))
            sid += 1
    return (np.concatenate(taps, axis=1), np.concatenate(rems),
            np.concatenate(seqs))


def run(quick: bool = True):
    cfg, model, params, dc = _setup()
    pc = cfg.probe
    taps, rem, seq = harvest_all_layers(cfg, model, params, dc,
                                        n_batches=4 if quick else 10)
    L = taps.shape[0]
    means = bin_means(pc)
    epochs = 4 if quick else 12

    results = {"layers": {}, "bert_mae": None}

    # ---- prompt-only "BERT" baseline -------------------------------------
    emb = np.asarray(params["embed"], np.float32)
    rng = np.random.default_rng(0)
    prompt_feats = emb[rng.integers(16, cfg.vocab_size,
                                    size=(len(rem), 8))].mean(1)
    bp, _ = train_probe(prompt_feats, rem, pc, cfg.d_model,
                        ProbeTrainConfig(epochs=epochs))
    import jax.numpy as jnp
    p_bert = np.asarray(jax.nn.softmax(
        probe_mod.apply_probe(bp, jnp.asarray(prompt_feats)), -1))
    # BERT predicts once at t=0 then decrements (paper's heatmap treatment)
    bert_pred = np.zeros(len(rem))
    for s in np.unique(seq):
        idx = np.where(seq == s)[0]
        first = float(p_bert[idx[0]] @ means)
        bert_pred[idx] = np.maximum(first - np.arange(len(idx)), 0.0)
    results["bert_mae"] = float(np.mean(np.abs(bert_pred - rem)))

    # ---- per-layer probes: raw + refined -----------------------------------
    heat = None
    for layer in range(L):
        pp, _ = train_probe(taps[layer], rem, pc, cfg.d_model,
                            ProbeTrainConfig(epochs=epochs))
        p = np.asarray(jax.nn.softmax(
            probe_mod.apply_probe(pp, jnp.asarray(taps[layer])), -1))
        raw_pred = p @ means
        raw_mae = float(np.mean(np.abs(raw_pred - rem)))
        # Bayesian refinement per sequence
        ref_pred = np.zeros(len(rem))
        for s in np.unique(seq):
            idx = np.where(seq == s)[0]
            qs = np.asarray(refine_sequence(jnp.asarray(p[idx]), pc))
            ref_pred[idx] = qs @ means
        ref_mae = float(np.mean(np.abs(ref_pred - rem)))
        results["layers"][layer] = {"raw_mae": raw_mae, "refined_mae": ref_mae}
        if layer == pc.tap_layer or (heat is None and layer == L - 1):
            k = pc.num_bins
            h = np.zeros((k, k))
            gt = np.asarray(bin_index(rem, pc))
            pr = np.asarray(bin_index(np.clip(ref_pred, 0, pc.max_len - 1), pc))
            for a, b in zip(gt, pr):
                h[b, a] += 1
            heat = np.log1p(h).tolist()
    results["heatmap_log_counts"] = heat

    best = min(results["layers"].items(),
               key=lambda kv: kv[1]["refined_mae"])
    ratio = results["bert_mae"] / max(best[1]["refined_mae"], 1e-9)
    results["best_layer"] = best[0]
    results["refined_vs_bert_ratio"] = ratio
    save_json("pred_accuracy", results)
    emit("fig2_3.best_refined_mae_layer", 0.0,
         f"layer={best[0]};refined_mae={best[1]['refined_mae']:.2f};"
         f"raw_mae={best[1]['raw_mae']:.2f};bert_mae={results['bert_mae']:.2f};"
         f"bert_over_refined={ratio:.2f}x")
    return results


if __name__ == "__main__":
    run(quick=False)
