"""Paper Figure 6: mean/median latency and TTFT vs request rate for the four
systems — vLLM-FCFS, vLLM-SJF_BERT, TRAIL (refined embeddings, C=0.8),
TRAIL-BERT (prompt-only predictions, C=0.8)."""

from __future__ import annotations

from benchmarks.common import emit, save_json
from repro.config import get_config
from repro.serving.engine import run_policy
from repro.serving.predictors import OraclePredictor
from repro.serving.workload import WorkloadConfig, generate

SYSTEMS = {
    "vllm-fcfs": dict(policy="fcfs"),
    "vllm-sjf-bert": dict(policy="sjf"),
    "trail": dict(policy="trail"),
    "trail-bert": dict(policy="trail-bert"),
}


def run(quick: bool = True):
    cfg = get_config("granite-3-8b")
    rates = (10.0, 14.0, 18.0) if quick else (6.0, 10.0, 14.0, 18.0, 22.0)
    n = 200 if quick else 600
    results = {}
    for rate in rates:
        wc = WorkloadConfig(n_requests=n, request_rate=rate, seed=3,
                            vocab=cfg.vocab_size)
        reqs = generate(wc)
        for name, kw in SYSTEMS.items():
            # trail-bert gets no refinement (prompt-only regime)
            pred = OraclePredictor(cfg.probe, seed=4,
                                   refine=(name == "trail"))
            s = run_policy(cfg, kw["policy"], reqs, c_limit=0.8,
                           max_batch=16, mode="sim", seed=4, predictor=pred)
            r = s.summary()
            results[f"{name}@{rate}"] = r
            emit(f"fig6.{name}.rate={rate}", r["mean_latency"] * 1e6,
                 f"med_lat={r['median_latency']:.3f};"
                 f"mean_ttft={r['mean_ttft']:.3f};"
                 f"med_ttft={r['median_ttft']:.3f}")
    # headline ratios at the paper's operating point
    base = results.get("vllm-fcfs@14.0")
    trail = results.get("trail@14.0")
    if base and trail:
        emit("fig6.headline", 0.0,
             f"latency_ratio={base['mean_latency']/trail['mean_latency']:.2f}x;"
             f"ttft_ratio={base['mean_ttft']/max(trail['mean_ttft'],1e-9):.2f}x"
             " (paper: 1.66-2.01x / 1.76-24.07x)")
    save_json("serving_curves", results)
    return results


if __name__ == "__main__":
    run(quick=False)
